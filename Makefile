# Repro toolchain entry points.
#
#   make test          — tier-1 verify (full pytest suite, 8 forced devices)
#   make bench-smoke   — quick benchmark pass: engine executor suite
#   make bench-engine  — full Sim-vs-Mesh executor benchmark -> BENCH_engine.json
#   make bench-elastic — elastic resize-event cost benchmark -> BENCH_elastic.json
#   make ci-local      — mirror the full CI matrix locally (lint, tier-1 under
#                        1 AND 8 forced devices, fresh engine bench + the
#                        regression gate) so CI failures reproduce without pushing
#   make example-mesh  — the 8-device mesh demo against the sim oracles
#   make example-elastic — the 8->4->8 elastic resharding demo

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export XLA_FLAGS ?= --xla_force_host_platform_device_count=8

.PHONY: test lint bench-smoke bench-engine bench-elastic ci-local \
        example-mesh example-elastic

test:
	$(PY) -m pytest -x -q

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not installed; skipping (CI runs it)"; \
	fi
	$(PY) -m compileall -q src tests benchmarks examples

bench-smoke:
	$(PY) -m benchmarks.run --suite engine --quick

bench-engine:
	$(PY) -m benchmarks.run --suite engine

bench-elastic:
	$(PY) -m benchmarks.run --suite elastic

ci-local: lint
	XLA_FLAGS=--xla_force_host_platform_device_count=1 $(PY) -m pytest -q
	XLA_FLAGS=--xla_force_host_platform_device_count=8 $(PY) -m pytest -q
	$(PY) -m benchmarks.run --suite engine --quick --out BENCH_engine.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_engine.json --fresh BENCH_engine.fresh.json
	$(PY) -m benchmarks.run --suite elastic --quick --out BENCH_elastic.fresh.json

example-mesh:
	$(PY) examples/mesh_vq.py

example-elastic:
	$(PY) examples/elastic_vq.py
