# Repro toolchain entry points.
#
#   make test         — tier-1 verify (full pytest suite, 8 forced devices)
#   make bench-smoke  — quick benchmark pass: engine executor suite
#   make bench-engine — full Sim-vs-Mesh executor benchmark -> BENCH_engine.json
#   make example-mesh — the 8-device mesh demo against the sim oracles

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export XLA_FLAGS ?= --xla_force_host_platform_device_count=8

.PHONY: test bench-smoke bench-engine example-mesh

test:
	$(PY) -m pytest -x -q

bench-smoke:
	$(PY) -m benchmarks.run --suite engine --quick

bench-engine:
	$(PY) -m benchmarks.run --suite engine

example-mesh:
	$(PY) examples/mesh_vq.py
