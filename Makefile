# Repro toolchain entry points.
#
#   make test          — tier-1 verify (full pytest suite, 8 forced devices)
#   make bench-smoke   — quick benchmark pass: engine executor suite
#   make bench-engine  — full Sim-vs-Mesh executor benchmark + the per-scheme
#                        fused-vs-unfused kernel legs -> BENCH_engine.json
#   make bench-elastic — elastic resize-event cost benchmark -> BENCH_elastic.json
#   make bench-serve   — serving suite (lookup/service/hot-swap) -> BENCH_serve.json
#   make bench-comm    — scheme x transport wall + measured wire bytes -> BENCH_comm.json
#   make bench-hier    — flat vs hierarchical (2x4) wall + per-tier wire bytes -> BENCH_hier.json
#   make bench-obs     — instrumented-vs-bare tracing overhead + traced 2-host
#                        run -> BENCH_obs.json (the <=1.03x obs gate input)
#   make bench-chaos   — seeded fault-injection run (kills + straggler +
#                        partition) vs the fault-free oracle -> BENCH_chaos.json
#   make bench-profile — roofline-attributed profiling: per-window cost
#                        attribution of the three schemes on the 8-device
#                        mesh -> BENCH_profile.json (the check_profile input)
#   make bench-adapt   — adaptive-communication suite: {fixed,dynamic} merge x
#                        {dense,bf16,int8} wire + the fixed-tau frontier legs
#                        -> BENCH_adapt.json (the check_adapt gate input; runs
#                        non-quick so the exact wire pins match the baseline)
#   make perf-report   — render every committed BENCH_*.json baseline plus
#                        attribution into a self-contained perf_report.html
#   make serve-smoke   — quantization service end to end: live elastic trainer
#                        hot-swapping codebooks under open-loop load
#   make trace-smoke   — 2-host traced + metered train run, then the trace
#                        invariant checker (repro.obs.check) on the export
#   make ci-local      — mirror the full CI matrix locally (lint, tier-1 under
#                        1 AND 8 forced devices, fresh engine + serve benches +
#                        the regression gates, the obs overhead gate, the
#                        chaos fault-injection gate, and the trace-invariant
#                        smoke) so CI failures reproduce without pushing
#   make example-mesh  — the 8-device mesh demo against the sim oracles
#   make example-elastic — the 8->4->8 elastic resharding demo
#   make example-serve — the train-while-serve demo (examples/serve_vq.py)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export XLA_FLAGS ?= --xla_force_host_platform_device_count=8

.PHONY: test lint bench-smoke bench-engine bench-elastic bench-serve \
        bench-comm bench-hier bench-obs bench-chaos bench-profile \
        bench-adapt perf-report serve-smoke trace-smoke ci-local \
        example-mesh example-elastic example-serve

test:
	$(PY) -m pytest -x -q

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not installed; skipping (CI runs it)"; \
	fi
	$(PY) -m compileall -q src tests benchmarks examples

bench-smoke:
	$(PY) -m benchmarks.run --suite engine --quick

bench-engine:
	$(PY) -m benchmarks.run --suite engine

bench-elastic:
	$(PY) -m benchmarks.run --suite elastic

bench-serve:
	$(PY) -m benchmarks.run --suite serve

bench-comm:
	$(PY) -m benchmarks.run --suite comm --quick

bench-hier:
	$(PY) -m benchmarks.run --suite hier --quick

bench-obs:
	$(PY) -m benchmarks.run --suite obs --quick

bench-chaos:
	$(PY) -m benchmarks.run --suite chaos --quick

bench-profile:
	$(PY) -m benchmarks.run --suite profile --quick

bench-adapt:
	$(PY) -m benchmarks.run --suite adapt

perf-report:
	$(PY) -m repro.obs.report --out perf_report.html

serve-smoke:
	$(PY) -m repro.launch.serve --mode vq --smoke --train-publish

# the checker's runpy RuntimeWarning ('repro.obs.check found in
# sys.modules') is harmless: the package __init__ imports the submodule
# before -m re-executes it as __main__
trace-smoke:
	$(PY) -m repro.launch.train --mode vq --executor mesh --scheme delta \
		--workers 8 --hosts 2 --points 400 \
		--trace ci.trace.json --metrics ci.metrics.jsonl
	$(PY) -m repro.obs.check ci.trace.json --expect-merge-tiers 0,1 \
		--expect-counter codebook_divergence --expect-counter distortion
	$(PY) -m repro.launch.train --mode vq --executor mesh --scheme delta \
		--workers 8 --points 400 --merge dynamic --divergence-thresh 1e-3 \
		--wire-quant int8 --trace ci.adapt.trace.json
	$(PY) -m repro.obs.check ci.adapt.trace.json \
		--expect-counter divergence_trigger

ci-local: lint
	XLA_FLAGS=--xla_force_host_platform_device_count=1 $(PY) -m pytest -q
	XLA_FLAGS=--xla_force_host_platform_device_count=8 $(PY) -m pytest -q
	XLA_FLAGS=--xla_force_host_platform_device_count=1 \
		$(PY) -m repro.launch.serve --mode vq --smoke --train-publish
	$(PY) -m repro.launch.serve --mode vq --smoke --train-publish
	$(PY) -m benchmarks.run --suite engine --quick --out BENCH_engine.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_engine.json --fresh BENCH_engine.fresh.json
	$(PY) -m benchmarks.run --suite serve --quick --out BENCH_serve.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_serve.json --fresh BENCH_serve.fresh.json
	$(PY) -m benchmarks.run --suite comm --quick --out BENCH_comm.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_comm.json --fresh BENCH_comm.fresh.json
	$(PY) -m benchmarks.run --suite hier --quick --out BENCH_hier.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_hier.json --fresh BENCH_hier.fresh.json
	$(PY) -m benchmarks.run --suite obs --quick --out BENCH_obs.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_obs.json --fresh BENCH_obs.fresh.json
	$(PY) -m benchmarks.run --suite chaos --quick --out BENCH_chaos.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_chaos.json --fresh BENCH_chaos.fresh.json
	$(PY) -m benchmarks.run --suite profile --quick --out BENCH_profile.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_profile.json --fresh BENCH_profile.fresh.json
	$(PY) -m benchmarks.run --suite adapt --out BENCH_adapt.fresh.json
	$(PY) -m benchmarks.check_regression \
		--baseline BENCH_adapt.json --fresh BENCH_adapt.fresh.json
	$(PY) -m repro.obs.report --out perf_report.html
	$(MAKE) trace-smoke
	$(PY) -m benchmarks.run --suite elastic --quick --out BENCH_elastic.fresh.json

example-mesh:
	$(PY) examples/mesh_vq.py

example-elastic:
	$(PY) examples/elastic_vq.py

example-serve:
	$(PY) examples/serve_vq.py
