"""Train-while-serve: a live elastic training run hot-swaps the codebook
under a quantization service taking traffic.

The paper's cloud endgame, both halves at once: an ``ElasticMeshExecutor``
runs the delta scheme (eq. 8) through an 8->4->8 worker resize and
publishes the shared prototypes into a versioned ``CodebookStore`` at
window boundaries, while a ``QuantizeService`` micro-batches an open-loop
query stream (geometric arrivals — the Section 4 cloud model) onto the
sharded lookup engine.  No request fails, served versions only move
forward, and the final responses come from the freshest codebook.

    PYTHONPATH=src python examples/serve_vq.py
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)  # must precede the first jax import

import threading  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.data import synthetic  # noqa: E402
from repro.engine import (ElasticMeshExecutor, GeometricDelayNetwork,  # noqa: E402
                          InstantNetwork, ResizeSchedule)
from repro.kernels import ref  # noqa: E402
from repro.serve import (CodebookStore, QuantizeService,  # noqa: E402
                         ShardedLookup, run_load)

M0, N, D, KAPPA, TAU = 8, 1000, 8, 16, 10


def main() -> None:
    key = jax.random.PRNGKey(0)
    kd, kw, ka = jax.random.split(key, 3)
    m0 = min(M0, len(jax.devices()))
    data = synthetic.replicate_stream(kd, m0, n=N, d=D)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)

    store = CodebookStore(w0)  # version 1: the untrained init
    n_windows = N // TAU
    schedule = ResizeSchedule([(n_windows // 3, max(1, m0 // 2)),
                               (2 * n_windows // 3, m0)])
    trainer_ex = ElasticMeshExecutor(schedule, network=InstantNetwork(),
                                     on_window=store.publisher(),
                                     publish_every=5)
    print(f"devices: {len(jax.devices())} x {jax.default_backend()} — "
          f"training M {m0}->{max(1, m0 // 2)}->{m0}, publishing every "
          f"5 windows; serving with geometric arrivals\n")

    trainer = threading.Thread(
        target=lambda: trainer_ex.run("delta", w0, data, eval_data, tau=TAU),
        name="trainer")

    lookup = ShardedLookup()
    with QuantizeService(store, lookup, max_delay_s=2e-3) as service:
        trainer.start()
        report = run_load(service, n_requests=800, d=D, rows_per_request=4,
                          network=GeometricDelayNetwork(0.5), tick_s=2e-4,
                          key=ka)
        trainer.join()

    st = service.stats
    print(f"load:  {report.summary()}")
    print(f"batch: {st.flushes} flushes, mean fill {st.mean_fill:.1f} rows "
          f"(full={st.full_flushes}, deadline={st.deadline_flushes})")
    for ev in trainer_ex.resize_events:
        print(f"       resize @window {ev.window}: M {ev.old_m} -> "
              f"{ev.new_m} under live load")
    print(f"store: {store.version} versions published; served "
          f"{report.versions_min}..{report.versions_max}")

    assert report.failed == 0, "hot-swap must not fail a single request"
    assert report.versions_monotonic, "served versions must only move forward"

    # the service's answers are the real argmin: replay one query against
    # the exact snapshot that served it
    snap = store.latest()
    z = np.asarray(jax.random.normal(ka, (5, D)), np.float32)
    with QuantizeService(store, lookup) as service:
        resp = service.quantize(z)
    a_ref, _ = ref.vq_assign_ref(z, snap.w)
    assert np.array_equal(resp.assign, np.asarray(a_ref))
    c0 = float(ref.distortion_ref(eval_data.reshape(-1, D), w0))
    c1 = float(ref.distortion_ref(eval_data.reshape(-1, D), snap.w))
    print(f"\nfinal served codebook: version {snap.version} "
          f"(distortion {c1:.5f} vs {c0:.5f} at v1) — training improved "
          f"the live service without a restart or a dropped request.")


if __name__ == "__main__":
    main()
