"""Elastic resharding: the worker set grows and shrinks mid-run.

An 8->4->8 run of the paper's delta scheme (eq. 8) where each worker-set
change is a **resharding event, not a restart**: at the scheduled window the
engine checkpoints the shared prototypes, integrates the departing workers'
in-flight deltas (eq. 8 on the stale window, damped by staleness), rebuilds
the device mesh via ``plan_remesh``, resplits the sample pool over the new
M, and resumes — compared against the fixed-M oracle on the same total
sample budget.

    PYTHONPATH=src python examples/elastic_vq.py
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)  # must precede the first jax import

import tempfile  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint.checkpointing import Checkpointer  # noqa: E402
from repro.core import schemes  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.engine import (ElasticMeshExecutor, InstantNetwork,  # noqa: E402
                          ResizeSchedule)

M0, N, D, KAPPA, TAU = 8, 2000, 8, 16, 10


def main() -> None:
    key = jax.random.PRNGKey(0)
    kd, kw = jax.random.split(key)
    data = synthetic.replicate_stream(kd, M0, n=N, d=D)
    eval_data = data[:, :500]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)

    print(f"devices: {len(jax.devices())} x {jax.default_backend()}, "
          f"M0={M0} workers, tau={TAU}, budget={M0 * N} points\n")

    oracle = schemes.scheme_delta(w0, data, eval_data, tau=TAU)

    schedule = ResizeSchedule([(60, 4), (120, 8)])
    with tempfile.TemporaryDirectory() as td:
        ex = ElasticMeshExecutor(schedule, network=InstantNetwork(),
                                 checkpointer=Checkpointer(td))
        res = ex.run("delta", w0, data, eval_data, tau=TAU)
        for ev in ex.resize_events:
            print(f"resize @window {ev.window:>3}: M {ev.old_m} -> "
                  f"{ev.new_m}  (late points merged: {ev.late_points}, "
                  f"event cost {ev.wall_s * 1e3:.1f} ms, "
                  f"checkpoint step {ev.checkpoint_step})")

    c_el, c_or = float(res.distortion[-1]), float(oracle.distortion[-1])
    print(f"\n{'':>18} {'windows':>8} {'C(final)':>10}")
    print(f"{'fixed M=8 oracle':>18} {len(oracle.distortion):>8} "
          f"{c_or:>10.5f}")
    print(f"{'elastic 8-4-8':>18} {len(res.distortion):>8} {c_el:>10.5f}")
    print(f"\nrelative gap: {abs(c_el - c_or) / c_or:.4f} "
          f"(acceptance bar: 1e-2) — a worker-set change costs a resharding "
          f"event,\nnot a restart, and the displacement merge stays on the "
          f"oracle's convergence path.")
    assert np.isfinite(c_el)


if __name__ == "__main__":
    main()
