"""Quickstart: the paper in 60 seconds.

Runs the three parallelization schemes on the synthetic mixture and prints
the wall-time distortion curves — Figures 1-3 of Durut, Patra & Rossi in one
table.

    PYTHONPATH=src python examples/quickstart.py

Next stops: ``mesh_vq.py`` (the schemes on a real device mesh),
``elastic_vq.py`` (resize the worker set mid-run), and ``serve_vq.py``
(the serving side: a live training run hot-swaps the codebook under a
micro-batched quantization service).
"""

import jax
import numpy as np

from repro.core import async_vq, schemes
from repro.data import synthetic

M, N, D, KAPPA, TAU = 10, 3000, 8, 16, 10


def main() -> None:
    key = jax.random.PRNGKey(0)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, M, n=N, d=D)
    eval_data = data[:, :1000]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)

    seq = schemes.scheme_sequential(w0, data[0], eval_data, tau=TAU)
    avg = schemes.scheme_average(w0, data, eval_data, tau=TAU)
    dlt = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
    asy = async_vq.scheme_async(w0, data, eval_data, ka, tau=TAU, p_delay=0.5)

    ticks = [100, 500, 1000, 2000, 3000]

    def at(res, t):
        i = int(np.searchsorted(np.asarray(res.wall_ticks), t))
        return float(res.distortion[min(i, len(res.distortion) - 1)])

    print(f"{'wall tick':>10} {'sequential':>11} {'averaging':>10} "
          f"{'delta':>8} {'async':>8}")
    for t in ticks:
        print(f"{t:>10} {at(seq, t):>11.4f} {at(avg, t):>10.4f} "
              f"{at(dlt, t):>8.4f} {at(asy, t):>8.4f}")
    print("\npaper's claims: averaging ~ sequential (Sec. 2, no speed-up); "
          "delta << sequential (Sec. 3); async ~ delta (Sec. 4).")


if __name__ == "__main__":
    main()
