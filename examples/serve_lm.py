"""Batched serving example: prefill a batch of prompts, then decode with the
KV cache through the serve step — the inference path the decode_32k /
long_500k dry-run cells lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.training import steps as steps_lib
from repro.models.api import get_api

BATCH, PROMPT, GEN = 4, 12, 24


def main() -> None:
    cfg = registry.get_smoke_config("granite_8b")
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)

    prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab)
    max_len = PROMPT + GEN
    serve = jax.jit(steps_lib.make_serve_step(cfg))
    prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len=max_len))

    # one forward over the whole prompt fills the KV cache (exactness vs
    # teacher-forced decode asserted by tests/test_substrates.py)
    logits, cache = prefill(params, {"tokens": prompts})

    # greedy decode
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    for _ in range(GEN):
        out.append(tok)
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prompts  {prompts.shape}: {prompts[0].tolist()}")
    print(f"generated{gen.shape}: {gen[0].tolist()}")
    print(f"decode throughput: {BATCH * GEN / dt:,.0f} tok/s "
          f"(CPU smoke model)")


if __name__ == "__main__":
    main()
