"""The paper's three schemes on a REAL 8-device JAX mesh.

One worker per device via the ``MeshExecutor`` (shard_map + collectives:
psum for the reducing phase, masked merges for the async staleness model),
checked live against the single-device ``SimExecutor`` oracles.  On CPU the
mesh comes from ``--xla_force_host_platform_device_count=8`` — the SPMD
program is the one a real 8-chip mesh runs.

    PYTHONPATH=src python examples/mesh_vq.py
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)  # must precede the first jax import

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.data import synthetic  # noqa: E402
from repro.engine import (GeometricDelayNetwork, InstantNetwork,  # noqa: E402
                          get_executor)

M, N, D, KAPPA, TAU = 8, 2000, 8, 16, 10


def main() -> None:
    key = jax.random.PRNGKey(0)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, M, n=N, d=D)
    eval_data = data[:, :500]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)

    print(f"devices: {len(jax.devices())} x {jax.default_backend()}, "
          f"M={M} workers (one per device), tau={TAU}\n")

    nets = {"average": InstantNetwork(), "delta": InstantNetwork(),
            "async_delta": GeometricDelayNetwork(p_delay=0.5)}
    print(f"{'scheme':>12} {'backend':>8} {'C(final)':>10} {'ticks':>6}  "
          f"|mesh - sim|")
    for scheme, net in nets.items():
        sim = get_executor("sim", network=net)
        mesh = get_executor("mesh", network=net)
        r_sim = sim.run(scheme, w0, data, eval_data, tau=TAU, key=ka)
        r_mesh = mesh.run(scheme, w0, data, eval_data, tau=TAU, key=ka)
        gap = float(np.max(np.abs(np.asarray(r_sim.distortion)
                                  - np.asarray(r_mesh.distortion))))
        for name, r in (("sim", r_sim), ("mesh", r_mesh)):
            print(f"{scheme:>12} {name:>8} {float(r.distortion[-1]):>10.5f} "
                  f"{int(r.wall_ticks[-1]):>6}"
                  + (f"  {gap:.2e}" if name == "mesh" else ""))

    print("\nthe mesh curves replay the paper's simulated results on real "
          "SPMD collectives;\nasync uses the Section-4 geometric-delay "
          "cloud model on both backends (same draw).")


if __name__ == "__main__":
    main()
