"""The paper's algorithm applied inside the framework: cluster a trained
token-embedding table with distributed async VQ (the original large-dataset
clustering use case), using the Pallas fused kernel for the assignment pass.

    PYTHONPATH=src python examples/embedding_vq.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import async_vq, schemes
from repro.kernels import ops
from repro.models.api import get_api
from repro.configs import registry

M, TAU, KAPPA = 8, 10, 64


def main() -> None:
    key = jax.random.PRNGKey(0)
    cfg = registry.get_smoke_config("granite_8b")
    params = get_api(cfg).init(key)
    table = np.asarray(params["embed"], np.float32)       # (V, D)
    v, d = table.shape
    print(f"clustering {v} x {d} embedding table into {KAPPA} codes")

    # split the table across M workers (the paper's data distribution)
    n = v // M * M
    data = jnp.asarray(table[:n]).reshape(M, -1, d)
    w0 = jnp.asarray(table[np.random.default_rng(0).choice(n, KAPPA,
                                                           replace=False)])

    before = float(ops.distortion(jnp.asarray(table), w0))
    res = async_vq.scheme_async(w0, data, data[:, :64], key,
                                tau=TAU, p_delay=0.5)
    after = float(ops.distortion(jnp.asarray(table), res.w_shared))
    print(f"distortion: {before:.5f} -> {after:.5f} "
          f"({(1 - after / before) * 100:.1f}% reduction)")

    # codebook assignment via the fused Pallas kernel
    assign, _ = ops.vq_assign(jnp.asarray(table), res.w_shared)
    sizes = np.bincount(np.asarray(assign), minlength=KAPPA)
    print(f"code usage: min={sizes.min()} median={int(np.median(sizes))} "
          f"max={sizes.max()} (of {v} rows)")


if __name__ == "__main__":
    main()
