"""The paper's Section-4 cloud architecture, for real: worker THREADS + a
dedicated reducer merging displacement messages through a versioned blob
store, no synchronization barrier anywhere — with an injected straggler to
demonstrate the scheme's tolerance (the reason the paper removed barriers).

    PYTHONPATH=src python examples/cloud_async_vq.py
"""

import jax
import numpy as np

from repro.core import async_runtime
from repro.data import synthetic

M, N, D, KAPPA = 8, 3000, 8, 16


def main() -> None:
    key = jax.random.PRNGKey(0)
    data = np.asarray(synthetic.replicate_stream(key, M, n=N, d=D))
    w0 = np.asarray(synthetic.kmeanspp_init(
        jax.random.fold_in(key, 1),
        jax.numpy.asarray(data.reshape(-1, D)), KAPPA))

    print(f"{M} worker threads + 1 reducer, tau=10, 2s wall clock")
    w, stats, trace = async_runtime.run_async_vq(
        data, w0, tau=10, duration_s=2.0, comm_delay_s=0.002)
    print("distortion over wall time:",
          " -> ".join(f"{d_:.4f}" for _, d_ in trace[::5]))
    print("points/worker:", [s.points for s in stats])

    print(f"\nsame run with worker 0 slowed 100x (straggler):")
    w2, stats2, trace2 = async_runtime.run_async_vq(
        data, w0, tau=10, duration_s=2.0, comm_delay_s=0.002,
        straggler={0: 100.0})
    print("distortion over wall time:",
          " -> ".join(f"{d_:.4f}" for _, d_ in trace2[::5]))
    print("points/worker:", [s.points for s in stats2])
    print("\nno barrier => the straggler only slows itself; global "
          "convergence continues (paper Section 4).")


if __name__ == "__main__":
    main()
