"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on the deterministic synthetic pipeline, with async checkpointing and a
mid-run simulated failure + restart (the fault-tolerance path).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointing import Checkpointer
from repro.data.pipeline import DataConfig, lm_batch
from repro.models.common import ModelConfig
from repro.optim import optimizers
from repro.training import steps as steps_lib


def make_100m() -> ModelConfig:
    # ~100M params: 12L x 512 x 8H, d_ff 2048, 32k vocab
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32768,
        dtype=jnp.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = make_100m()
    print(f"model: {cfg.name} ({cfg.n_params() / 1e6:.0f}M params)")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.batch)
    opt = optimizers.adamw(
        optimizers.cosine_schedule(3e-4, warmup=30, total=args.steps))
    step = jax.jit(steps_lib.make_train_step(cfg, opt), donate_argnums=(0,))
    state = steps_lib.init_train_state(cfg, opt, jax.random.PRNGKey(0))

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    ckpt = Checkpointer(ckpt_dir)
    half = args.steps // 2

    # ---- phase 1: train to the midpoint, checkpointing async -------------
    for i in range(half):
        state, metrics = step(state, lm_batch(dcfg, i))
        if (i + 1) % 20 == 0:
            print(f"step {i + 1:4d}  loss {float(metrics['loss']):.4f}")
        if (i + 1) % 50 == 0:
            ckpt.save_async(i + 1, state)
    ckpt.save(half, state)
    ckpt.wait()

    # ---- simulated node failure: throw the live state away ---------------
    print(f"\n--- simulated failure at step {half}; "
          f"restarting from {ckpt.latest_step()} ---\n")
    del state
    state = steps_lib.init_train_state(cfg, opt, jax.random.PRNGKey(1))
    state = ckpt.restore(ckpt.latest_step(), state)

    # ---- phase 2: resume; the step-indexed pipeline replays exactly ------
    final = None
    for i in range(half, args.steps):
        state, metrics = step(state, lm_batch(dcfg, i))
        final = float(metrics["loss"])
        if (i + 1) % 20 == 0:
            print(f"step {i + 1:4d}  loss {final:.4f}")
    print(f"\nfinal loss {final:.4f} (started ~{jnp.log(cfg.vocab):.2f})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
