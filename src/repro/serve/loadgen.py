"""Open-loop load generator for the quantization service.

Arrivals follow the same pluggable ``NetworkModel`` delay processes the
engine uses (``engine/network.py``): a request's inter-arrival gap is one
communication round of a tau=1 worker, so ``GeometricDelayNetwork`` gives
the paper's Section-4 cloud arrival process (1 + Geometric(p) ticks),
``InstantNetwork`` gives back-to-back saturating load, and ``tick_s``
converts ticks to seconds.

The generator is OPEN-LOOP: requests are submitted at their scheduled
times whether or not earlier ones completed, and latency is measured from
the *scheduled* arrival (not the actual submit), so a backed-up service
cannot hide queueing delay by slowing the generator down (no coordinated
omission).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.engine.network import InstantNetwork, NetworkModel
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.serve.codebook_store import CodebookStore
from repro.serve.service import QuantizeService


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """What an open-loop run observed."""

    requests: int
    rows: int
    failed: int
    wall_s: float
    qps: float                   # completed requests / wall second
    rows_per_s: float            # completed rows / wall second
    p50_ms: float                # latency percentiles from SCHEDULED arrival
    p99_ms: float
    mean_ms: float
    versions_min: int            # served codebook versions (monotonicity:
    versions_max: int            #   checked in submission order)
    versions_monotonic: bool
    n_versions: int              # distinct versions served
    staleness_max: int           # latest store version at completion - served
    staleness_mean: float

    def summary(self) -> str:
        return (f"{self.requests} req ({self.rows} rows, "
                f"{self.failed} failed) in {self.wall_s:.2f}s: "
                f"{self.qps:,.0f} q/s {self.rows_per_s:,.0f} rows/s, "
                f"p50 {self.p50_ms:.2f}ms p99 {self.p99_ms:.2f}ms, "
                f"versions {self.versions_min}..{self.versions_max}"
                f" (monotonic={self.versions_monotonic}, "
                f"max staleness {self.staleness_max})")


def arrival_gaps_s(network: NetworkModel, n: int, *, tick_s: float,
                   key: jax.Array | None = None) -> np.ndarray:
    """(n,) inter-arrival gaps in seconds from one tau=1 round per request."""
    key = jax.random.PRNGKey(0) if key is None else key
    ticks = np.asarray(network.round_lengths(key, 1, n, 1))[0]
    return ticks.astype(np.float64) * tick_s


def run_load(service: QuantizeService, *, n_requests: int, d: int,
             rows_per_request: int = 1, network: NetworkModel | None = None,
             tick_s: float = 0.0, key: jax.Array | None = None,
             store: CodebookStore | None = None,
             timeout_s: float = 120.0, tracer: Tracer | None = None,
             metrics: MetricsRegistry | None = None) -> LoadReport:
    """Drive ``service`` with ``n_requests`` open-loop requests.

    ``tick_s=0`` (or ``InstantNetwork``) submits back-to-back — the
    saturating-throughput configuration.  ``store`` defaults to the
    service's own store and feeds the staleness measurement.
    """
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    network = network or InstantNetwork()
    store = store or service.store
    tracer = tracer if tracer is not None else NULL_TRACER
    key = jax.random.PRNGKey(0) if key is None else key
    kq, ka = jax.random.split(key)
    queries = np.asarray(jax.random.normal(
        kq, (n_requests, rows_per_request, d), np.float32))
    gaps = arrival_gaps_s(network, n_requests, tick_s=tick_s, key=ka)

    futures, scheduled = [], []
    done_at = [0.0] * n_requests
    latest_at_done = [0] * n_requests

    def _mark(i):
        def cb(_fut):
            done_at[i] = time.monotonic()
            latest_at_done[i] = store.version

        return cb

    t0 = time.monotonic()
    with tracer.span("load", requests=n_requests,
                     rows_per_request=rows_per_request):
        with tracer.span("submit"):
            next_t = t0
            for i in range(n_requests):
                next_t += gaps[i]
                now = time.monotonic()
                if next_t > now:
                    time.sleep(next_t - now)
                scheduled.append(max(next_t, t0))
                fut = service.submit(queries[i])
                fut.add_done_callback(_mark(i))
                futures.append(fut)

        failed = 0
        responses = []
        with tracer.span("collect"):
            for fut in futures:
                try:
                    responses.append(fut.result(timeout=timeout_s))
                except Exception:  # noqa: BLE001 — counted, not raised
                    responses.append(None)
                    failed += 1
    wall_s = time.monotonic() - t0

    lat_ms, versions, staleness = [], [], []
    for i, resp in enumerate(responses):
        if resp is None:
            continue
        if done_at[i] == 0.0:
            # Future.result() can wake before the done-callback stamped the
            # completion time; stamping now is a tight upper bound
            done_at[i] = time.monotonic()
            latest_at_done[i] = store.version
        lat_ms.append((done_at[i] - scheduled[i]) * 1e3)
        versions.append(resp.version)
        staleness.append(max(0, latest_at_done[i] - resp.version))
    ok = len(lat_ms)
    lat = np.asarray(lat_ms) if ok else np.asarray([0.0])
    versions_arr = np.asarray(versions) if ok else np.asarray([0])
    stale = np.asarray(staleness) if ok else np.asarray([0])
    if metrics is not None:
        h = metrics.histogram("serve_latency_ms")
        for v in lat_ms:
            h.observe(v)
        metrics.counter("serve_requests").inc(n_requests)
        if failed:
            metrics.counter("serve_load_failed").inc(failed)
        g = metrics.gauge("serve_staleness")
        for s in staleness:
            g.set(s)
    return LoadReport(
        requests=n_requests,
        rows=n_requests * rows_per_request,
        failed=failed,
        wall_s=wall_s,
        qps=ok / wall_s if wall_s > 0 else 0.0,
        rows_per_s=ok * rows_per_request / wall_s if wall_s > 0 else 0.0,
        p50_ms=float(np.percentile(lat, 50)),
        p99_ms=float(np.percentile(lat, 99)),
        mean_ms=float(np.mean(lat)),
        versions_min=int(versions_arr.min()),
        versions_max=int(versions_arr.max()),
        versions_monotonic=bool(np.all(np.diff(versions_arr) >= 0)),
        n_versions=int(len(np.unique(versions_arr))),
        staleness_max=int(stale.max()),
        staleness_mean=float(stale.mean()),
    )
