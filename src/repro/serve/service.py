"""``QuantizeService`` — batched nearest-prototype lookup as a service.

The serving analogue of the paper's cloud regime: queries arrive one vector
at a time (slow, unpredictable network), but the hardware wants MXU-aligned
batches.  A micro-batching scheduler coalesces incoming requests into one
lookup call — padded to a multiple of ``batch_align=128`` rows — under a
deadline-driven flush:

    submit(z) ──► pending queue ──► flush when EITHER
                                      * coalesced rows >= max_batch, OR
                                      * oldest request age >= max_delay_s
                  ──► pad to batch_align ──► ShardedLookup.assign(batch, w)
                  ──► split results back onto per-request futures

Every flush reads ONE immutable ``CodebookStore`` snapshot, so all rows of
a batch are served by the same ``(version, w)`` pair — a hot-swap mid-batch
can never tear a response — and single-vector requests ride the exact same
``kernels/ops.vq_assign`` hot path as bulk ones.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np

from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.serve.codebook_store import CodebookStore
from repro.serve.lookup import ShardedLookup


@dataclasses.dataclass(frozen=True)
class QuantizeRequest:
    """One pending query: ``rows`` vectors awaiting assignment."""

    z: np.ndarray                   # (rows, d) float32
    rows: int
    submitted_at: float             # time.monotonic()
    future: Future = dataclasses.field(repr=False, compare=False,
                                       default_factory=Future)


@dataclasses.dataclass(frozen=True)
class QuantizeResponse:
    """Assignments for one request, stamped with the codebook that served it."""

    assign: np.ndarray              # (rows,) int32 nearest-prototype indices
    mindist: np.ndarray             # (rows,) float32 squared distances
    version: int                    # CodebookStore version served
    latency_s: float                # submit -> response (service-internal)
    batch_rows: int                 # real rows of the coalesced flush batch


@dataclasses.dataclass
class ServiceStats:
    """Counters the flush loop maintains (read them after ``stop``)."""

    requests: int = 0
    rows: int = 0
    flushes: int = 0
    full_flushes: int = 0           # flushed because max_batch filled up
    deadline_flushes: int = 0       # flushed because the deadline expired
    padded_rows: int = 0            # alignment rows added across all flushes
    failed: int = 0

    @property
    def mean_fill(self) -> float:
        """Mean real rows per flush (how well coalescing worked)."""
        return self.rows / self.flushes if self.flushes else 0.0


class QuantizeService:
    """Deadline-driven micro-batching front end over ``ShardedLookup``.

    Parameters
    ----------
    store:       the ``CodebookStore`` serving reads (hot-swappable).
    lookup:      a ``ShardedLookup`` (default: one over all devices).
    max_batch:   flush as soon as this many rows are pending (default:
                 ``batch_align`` rows per lookup shard — one MXU block per
                 device).
    max_delay_s: flush a partial batch once the oldest pending request has
                 waited this long (the latency bound batching may add).
    batch_align: MXU row alignment for the coalesced batch (NOT a kernel
                 tile size — the lookup's Pallas tiles come from
                 ``kernels.autotune``).
    warmup:      compile the two hot flush shapes (one aligned block and a
                 full ``max_batch``) against the current codebook inside
                 ``start()`` — otherwise the FIRST flush pays the lookup
                 compile and every request queued behind it eats it as
                 latency.
    """

    def __init__(self, store: CodebookStore, lookup: ShardedLookup | None = None,
                 *, max_batch: int | None = None, max_delay_s: float = 2e-3,
                 batch_align: int = 128, warmup: bool = True,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.store = store
        self.lookup = lookup if lookup is not None else ShardedLookup()
        if batch_align < 1:
            raise ValueError(f"batch_align must be >= 1, got {batch_align}")
        if batch_align % self.lookup.batch_multiple():
            raise ValueError(
                f"batch_align={batch_align} must be a multiple of the "
                f"lookup's {self.lookup.batch_multiple()} shards so padded "
                f"batches land one aligned block per device")
        self.batch_align = batch_align
        self.max_batch = max_batch if max_batch is not None else (
            batch_align * self.lookup.n_shards)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        self.max_delay_s = max_delay_s
        self.warmup = warmup
        # flush spans ride the tracer's wall timeline on the flush thread's
        # own track; fill/queue-depth land on the registry per flush
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.stats = ServiceStats()
        self._cond = threading.Condition()
        self._queue: list[QuantizeRequest] = []
        self._pending_rows = 0
        self._running = False
        self._thread: threading.Thread | None = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "QuantizeService":
        with self._cond:
            if self._running:
                raise RuntimeError("service already running")
            self._running = True
        if self.warmup and self.store.version:
            snap = self.store.latest()
            d = snap.w.shape[1]
            align = self.batch_align
            for rows in sorted({align, -(-self.max_batch // align) * align}):
                jax.block_until_ready(self.lookup.assign(
                    np.zeros((rows, d), np.float32), snap.w))
        self._thread = threading.Thread(target=self._flush_loop,
                                        name="quantize-flush", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue (every accepted request gets a response), then
        stop the flush thread."""
        with self._cond:
            if not self._running:
                return
            self._running = False
            self._cond.notify_all()
        assert self._thread is not None
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "QuantizeService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request path -------------------------------------------------------

    def submit(self, z) -> Future:
        """Queue ``z`` ((d,) or (rows, d)); resolves to ``QuantizeResponse``."""
        arr = np.asarray(z, np.float32)
        if arr.ndim == 1:
            arr = arr[None, :]
        if arr.ndim != 2 or arr.shape[0] < 1:
            raise ValueError(f"query must be (d,) or (rows, d), "
                             f"got shape {np.shape(z)}")
        req = QuantizeRequest(z=arr, rows=arr.shape[0],
                              submitted_at=time.monotonic())
        with self._cond:
            if not self._running:
                raise RuntimeError("service is not running (use start() or "
                                   "the context manager)")
            self._queue.append(req)
            self._pending_rows += req.rows
            self._cond.notify_all()
        return req.future

    def quantize(self, z, timeout: float | None = 30.0) -> QuantizeResponse:
        """Synchronous convenience wrapper around ``submit``."""
        return self.submit(z).result(timeout=timeout)

    # -- flush loop ---------------------------------------------------------

    def _take_batch_locked(self) -> tuple[list[QuantizeRequest], bool]:
        """Pop requests up to ``max_batch`` rows (always at least one)."""
        take: list[QuantizeRequest] = [self._queue[0]]
        rows = take[0].rows
        while (len(take) < len(self._queue)
               and rows + self._queue[len(take)].rows <= self.max_batch):
            rows += self._queue[len(take)].rows
            take.append(self._queue[len(take)])
        del self._queue[:len(take)]
        self._pending_rows -= rows
        return take, rows >= self.max_batch

    def _flush_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and self._running:
                    self._cond.wait()
                if not self._queue:
                    return  # stopped and drained
                deadline = self._queue[0].submitted_at + self.max_delay_s
                while (self._running
                       and self._pending_rows < self.max_batch):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cond.wait(left)
                depth = self._pending_rows      # queue depth at flush time
                batch, full = self._take_batch_locked()
            self._execute(batch, full, depth)

    def _execute(self, batch: list[QuantizeRequest], full: bool,
                 depth: int = 0) -> None:
        # claim every future first: a client may have cancel()ed while the
        # request was queued, and resolving a cancelled future would raise
        # InvalidStateError and kill the flush thread; once claimed
        # (RUNNING), cancellation can no longer race the set_result below
        batch = [r for r in batch if r.future.set_running_or_notify_cancel()]
        if not batch:
            return
        rows = sum(r.rows for r in batch)
        t_flush = time.perf_counter()
        try:
            with self.tracer.span("flush", rows=rows,
                                  requests=len(batch), full=full,
                                  queue_depth=depth):
                snap = self.store.latest()
                z = (batch[0].z if len(batch) == 1
                     else np.concatenate([r.z for r in batch]))
                pad = (-z.shape[0]) % self.batch_align
                if pad:
                    z = np.concatenate([z, np.zeros((pad, z.shape[1]),
                                                    np.float32)])
                assign, mind = self.lookup.assign(z, snap.w)
                assign = np.asarray(assign)
                mind = np.asarray(mind)
        except Exception as e:  # noqa: BLE001 — fault goes to the callers
            for r in batch:
                r.future.set_exception(e)
            self.stats.failed += len(batch)
            if self.metrics is not None:
                self.metrics.counter("serve_failed").inc(len(batch))
            return
        if self.metrics is not None:
            mt = self.metrics
            mt.histogram("serve_flush_wall_s").observe(
                time.perf_counter() - t_flush)
            mt.histogram("serve_batch_fill").observe(rows / self.max_batch)
            mt.gauge("serve_queue_depth").set(depth)
            mt.counter("serve_flushes",
                       kind="full" if full else "deadline").inc()
            mt.counter("serve_rows").inc(rows)
            mt.counter("serve_padded_rows").inc(pad)
        now = time.monotonic()
        off = 0
        for r in batch:
            r.future.set_result(QuantizeResponse(
                assign=assign[off:off + r.rows],
                mindist=mind[off:off + r.rows],
                version=snap.version,
                latency_s=now - r.submitted_at,
                batch_rows=rows))
            off += r.rows
        self.stats.requests += len(batch)
        self.stats.rows += rows
        self.stats.flushes += 1
        self.stats.padded_rows += pad
        if full:
            self.stats.full_flushes += 1
        else:
            self.stats.deadline_flushes += 1
