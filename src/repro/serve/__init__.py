"""Online quantization serving — the engine's first read path.

Training executors publish versioned codebooks into a hot-swappable
``CodebookStore``; a ``QuantizeService`` micro-batches incoming
nearest-prototype queries onto the sharded ``ShardedLookup`` engine (same
``kernels/ops.vq_assign`` hot path as training); ``loadgen`` drives it with
the engine's ``NetworkModel`` arrival processes and reports latency
percentiles, throughput, and served-codebook staleness.

    store   = CodebookStore(w0)
    ex      = ElasticMeshExecutor(sched, on_window=store.publisher())
    service = QuantizeService(store, ShardedLookup()).start()
    resp    = service.quantize(z)          # rides a coalesced MXU batch
"""

from repro.serve.codebook_store import CodebookSnapshot, CodebookStore
from repro.serve.loadgen import LoadReport, arrival_gaps_s, run_load
from repro.serve.lookup import ShardedLookup
from repro.serve.service import (QuantizeRequest, QuantizeResponse,
                                 QuantizeService, ServiceStats)

__all__ = [
    "CodebookSnapshot", "CodebookStore",
    "ShardedLookup",
    "QuantizeRequest", "QuantizeResponse", "QuantizeService", "ServiceStats",
    "LoadReport", "arrival_gaps_s", "run_load",
]
