"""Versioned, hot-swappable codebook store — the read side of the engine.

CloudDALVQ's asynchronous architecture separates the write path (workers
publishing displacement merges) from the read path (anyone downloading the
current shared prototypes).  ``CodebookStore`` is that read/write seam for
serving: training executors publish ``(version, w)`` snapshots at window
boundaries (``MeshExecutor``/``ElasticMeshExecutor`` ``on_window`` hook),
and lookup readers always see a *consistent* snapshot.

Guarantees:

  * **no torn reads** — a snapshot is an immutable ``CodebookSnapshot``
    (read-only numpy codebook) swapped in atomically under a lock; a reader
    holds a complete ``(version, w)`` pair or the previous one, never a mix;
  * **strictly monotonic versions** — the store owns the version counter;
    concurrent publishers serialize on the lock and each gets a fresh
    version, so served versions can only move forward;
  * **mesh-agnostic** — ``publish`` device_gets the array, so a codebook
    computed on any device mesh (or a mesh that no longer exists, elastic
    case) is servable from the host.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, NamedTuple

import jax
import numpy as np


class CodebookSnapshot(NamedTuple):
    """One immutable published codebook."""

    version: int          # store-assigned, strictly monotonic
    w: np.ndarray         # (kappa, d) read-only prototypes
    step: int             # publisher tag (training window index; -1 unknown)
    published_at: float   # time.monotonic() at publish


class CodebookStore:
    """Thread-safe versioned codebook snapshots with atomic hot-swap."""

    def __init__(self, w0: jax.Array | np.ndarray | None = None, *,
                 keep: int = 16):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self._cond = threading.Condition()
        self._latest: CodebookSnapshot | None = None
        self._history: collections.OrderedDict[int, CodebookSnapshot] = (
            collections.OrderedDict())
        self._keep = keep
        if w0 is not None:
            self.publish(w0, step=0)

    def publish(self, w: jax.Array | np.ndarray, *,
                step: int = -1) -> CodebookSnapshot:
        """Swap in a new codebook; returns its snapshot (fresh version)."""
        # copy, don't alias: ascontiguousarray would return the CALLER'S
        # array for a contiguous ndarray input, and the setflags below
        # would freeze it under them
        arr = np.array(jax.device_get(w))
        if arr.ndim != 2:
            raise ValueError(f"codebook must be (kappa, d), got {arr.shape}")
        arr.setflags(write=False)
        with self._cond:
            version = (self._latest.version + 1) if self._latest else 1
            snap = CodebookSnapshot(version=version, w=arr, step=step,
                                    published_at=time.monotonic())
            self._latest = snap
            self._history[version] = snap
            while len(self._history) > self._keep:
                self._history.popitem(last=False)
            self._cond.notify_all()
        return snap

    def latest(self) -> CodebookSnapshot:
        """The current snapshot (atomic); raises if nothing was published."""
        with self._cond:
            if self._latest is None:
                raise LookupError("no codebook published yet")
            return self._latest

    def get(self, version: int) -> CodebookSnapshot | None:
        """A retained historical snapshot, or None if evicted/never existed."""
        with self._cond:
            return self._history.get(version)

    @property
    def version(self) -> int:
        """Latest published version (0 = empty store)."""
        with self._cond:
            return self._latest.version if self._latest else 0

    def __len__(self) -> int:
        with self._cond:
            return len(self._history)

    def wait_for(self, version: int, timeout: float | None = None) -> bool:
        """Block until ``self.version >= version``; False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._latest is None or self._latest.version < version:
                left = None if deadline is None else deadline - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cond.wait(left)
            return True

    def publisher(self, *,
                  skip_stale: bool = False) -> Callable[[int, jax.Array], None]:
        """An ``on_window(window, w)`` callback that publishes into this
        store — plug it into ``MeshExecutor``/``ElasticMeshExecutor``.

        ``skip_stale=True`` drops publishes whose global window is <= the
        latest published step: when a preempted trainer resumes from a
        checkpoint it replays windows the store has already served, and
        re-publishing them would march the serving codebook BACKWARD
        mid-query.  Fresh windows after the replayed prefix publish
        normally, so serve-while-train resumes without failing queries."""

        def on_window(window: int, w: jax.Array) -> None:
            if skip_stale:
                with self._cond:
                    latest = self._latest
                if latest is not None and window <= latest.step:
                    return
            self.publish(w, step=window)

        return on_window
