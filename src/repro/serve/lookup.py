"""Sharded batched codebook lookup behind the mesh machinery.

Three execution plans for ``argmin_l ||z - w_l||^2`` over a query batch,
picked per codebook by the VMEM routing helper in ``kernels.ops``:

  * ``direct``      — one device: the blocked ``vq_assign`` Pallas kernel.
  * ``shard_batch`` — the codebook fits one device's VMEM budget: replicate
    w, shard the query batch over the mesh, no collectives (the serving
    analogue of the paper's data-parallel split).
  * ``shard_kappa`` — ``kappa*d`` exceeds the budget: shard the CODEBOOK
    rows over the mesh, each device runs the blocked kernel on its slice,
    and a cross-shard argmin combines ``(min, global index)`` with two
    ``lax.pmin`` collectives (ties resolve to the lowest global index, the
    same first-occurrence rule as the reference oracle).

All plans route through ``kernels/ops.vq_assign`` — the serving read path
and the training hot path share one kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.engine.mesh import make_worker_mesh
from repro.kernels import ops

MODES = ("auto", "direct", "shard_batch", "shard_kappa")

# sentinel fill for codebook pad rows in the shard_kappa plan: far enough
# that a padded row can never win the argmin, small enough that ||w||^2
# stays finite in f32 for any practical d (d * 1e30 << 3.4e38)
_PAD_FILL = 1.0e15


class ShardedLookup:
    """Batched nearest-prototype lookup over a 1-D device mesh.

    Parameters
    ----------
    n_devices:     devices to spread the lookup over (default: all).
    mode:          'auto' routes per codebook via the VMEM budget; or force
                   one of 'direct' / 'shard_batch' / 'shard_kappa'.
    budget_bytes:  VMEM budget for the auto routing (None = ops default /
                   ``REPRO_VMEM_BUDGET_BYTES``).
    bm, bk:        kernel block sizes; None (default) defers to the
                   ``kernels.autotune`` roofline pick for each shard shape.
    """

    def __init__(self, n_devices: int | None = None, axis: str = "shards", *,
                 mode: str = "auto", budget_bytes: int | None = None,
                 bm: int | None = None, bk: int | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown lookup mode {mode!r}; "
                             f"choose from {MODES}")
        avail = len(jax.devices())
        self.n_shards = avail if n_devices is None else n_devices
        if not 1 <= self.n_shards <= avail:
            raise ValueError(
                f"need 1 <= n_devices <= {avail}, got {self.n_shards} "
                f"(hint: --xla_force_host_platform_device_count)")
        if mode in ("shard_batch", "shard_kappa") and self.n_shards < 2:
            raise ValueError(f"mode {mode!r} needs >= 2 devices, "
                             f"got {self.n_shards}")
        self.axis = axis
        self.mode = mode
        self.budget_bytes = budget_bytes
        self.bm = bm
        self.bk = bk
        self.mesh = (make_worker_mesh(self.n_shards, axis)
                     if self.n_shards > 1 else None)
        self._compiled: dict[tuple, object] = {}

    # -- planning -----------------------------------------------------------

    def plan(self, kappa: int, d: int) -> str:
        """Which execution plan a (kappa, d) codebook gets."""
        if self.mode != "auto":
            return self.mode
        if self.n_shards == 1:
            return "direct"
        if ops.codebook_fits_vmem(kappa, d, budget_bytes=self.budget_bytes):
            return "shard_batch"
        return "shard_kappa"

    def batch_multiple(self) -> int:
        """Query batches must be padded to a multiple of this row count
        (the micro-batcher's padding target)."""
        return self.n_shards

    # -- execution ----------------------------------------------------------

    def assign(self, z: jax.Array, w: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
        """(batch, d), (kappa, d) -> (assign (batch,) i32, mind (batch,) f32).

        Same contract as ``kernels.ref.vq_assign_ref``; batch must be a
        multiple of ``batch_multiple()`` for the sharded plans.
        """
        z = jnp.asarray(z)
        w = jnp.asarray(w)
        if z.ndim != 2 or w.ndim != 2 or z.shape[1] != w.shape[1]:
            raise ValueError(
                f"want z (batch, d) and w (kappa, d) with matching d, "
                f"got {z.shape} vs {w.shape}")
        plan = self.plan(*w.shape)
        if plan == "direct":
            return ops.vq_assign(z, w, bm=self.bm, bk=self.bk)
        if z.shape[0] % self.n_shards:
            raise ValueError(
                f"batch {z.shape[0]} must be a multiple of "
                f"{self.n_shards} shards for the {plan!r} plan "
                f"(pad the batch — the service's micro-batcher does)")
        if plan == "shard_batch":
            return self._shard_batch(z, w)
        return self._shard_kappa(z, w)

    def _shard_batch(self, z, w):
        key = ("shard_batch", z.shape, w.shape, z.dtype, w.dtype)
        if key not in self._compiled:
            bm, bk = self.bm, self.bk

            def body(z_l, w_l):
                return ops.vq_assign(z_l, w_l, bm=bm, bk=bk)

            self._compiled[key] = jax.jit(compat.shard_map(
                body, self.mesh, in_specs=(P(self.axis), P()),
                out_specs=(P(self.axis), P(self.axis)),
                axis_names=frozenset({self.axis}), check_vma=False))
        return self._compiled[key](z, w)

    def _shard_kappa(self, z, w):
        kappa = w.shape[0]
        k_local = -(-kappa // self.n_shards)  # ceil
        pad = k_local * self.n_shards - kappa
        if pad:
            # sentinel rows are strictly worse than any real prototype, so
            # they never win the local argmin on the last shard
            w = jnp.concatenate(
                [w, jnp.full((pad, w.shape[1]), _PAD_FILL, w.dtype)])
        key = ("shard_kappa", z.shape, w.shape, z.dtype, w.dtype)
        if key not in self._compiled:
            axis, bm, bk = self.axis, self.bm, self.bk

            def body(z_l, w_l):
                a_l, m_l = ops.vq_assign(z_l[0], w_l, bm=bm, bk=bk)
                gidx = a_l + jax.lax.axis_index(axis) * w_l.shape[0]
                gmin = jax.lax.pmin(m_l, axis)
                # among shards tied at the global min, the LOWEST global
                # index wins — the oracle's first-occurrence argmin rule
                cand = jnp.where(m_l == gmin, gidx,
                                 jnp.iinfo(jnp.int32).max)
                garg = jax.lax.pmin(cand, axis)
                return garg[None], gmin[None]

            self._compiled[key] = jax.jit(compat.shard_map(
                body, self.mesh,
                in_specs=(P(self.axis), P(self.axis)),
                out_specs=(P(self.axis), P(self.axis)),
                axis_names=frozenset({self.axis}), check_vma=False))
        # replicate z by stacking one copy per shard: in_spec P(axis) hands
        # each device its own full copy without relying on partial-manual
        # replication (unsupported on the jax-0.4.x fallback toolchain)
        zr = jnp.broadcast_to(z, (self.n_shards, *z.shape))
        garg, gmin = self._compiled[key](zr, w)
        return garg[0], gmin[0]
