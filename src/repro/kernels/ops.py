"""Jit'd public wrappers around the Pallas VQ kernels.

Handles padding to MXU-aligned block multiples, picks interpret mode
automatically off-TPU (the kernel body then runs as pure-python/jnp on CPU —
bit-identical semantics, which is what the allclose tests exercise), and
exposes the same signatures as the ``ref.py`` oracles.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import vq_assign as _k

# Conservative per-core VMEM budget for kernel residency planning.  TPU cores
# have ~16 MiB of VMEM (pallas guide §Memory Spaces); half of it is left for
# double-buffered input blocks, scratch, and the compiler's own staging.
DEFAULT_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def vmem_budget_bytes(budget_bytes: int | None = None) -> int:
    """The VMEM budget used to route between kernels.

    Explicit argument > ``REPRO_VMEM_BUDGET_BYTES`` env var > the default.
    """
    if budget_bytes is not None:
        if budget_bytes <= 0:
            raise ValueError(f"vmem budget must be > 0, got {budget_bytes}")
        return budget_bytes
    env = os.environ.get("REPRO_VMEM_BUDGET_BYTES", "")
    return int(env) if env else DEFAULT_VMEM_BUDGET_BYTES


def delta_vmem_bytes(kappa: int, d: int, *, bm: int = 128) -> int:
    """f32 VMEM residency of the fused ``vq_delta`` kernel for one grid step:
    codebook + zsum accumulator (both (kappa, d)), the counts column, one
    (bm, d) batch block, and the (bm, kappa) distance/one-hot tiles."""
    return 4 * (2 * kappa * d + kappa + bm * d + 2 * bm * kappa)


def delta_fits_vmem(kappa: int, d: int, *, bm: int = 128,
                    budget_bytes: int | None = None) -> bool:
    """Can the full-codebook ``vq_delta`` kernel hold ``kappa*d`` in VMEM?"""
    return delta_vmem_bytes(kappa, d, bm=bm) <= vmem_budget_bytes(budget_bytes)


def codebook_fits_vmem(kappa: int, d: int, *,
                       budget_bytes: int | None = None) -> bool:
    """Does a replicated (kappa, d) f32 codebook fit one device's budget?
    (The serving lookup shards kappa across devices when it does not.)"""
    return 4 * kappa * d <= vmem_budget_bytes(budget_bytes)


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def vq_assign(z: jax.Array, w: jax.Array, *, bm: int = 128, bk: int = 128,
              interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Nearest-prototype assignment; same contract as ``ref.vq_assign_ref``."""
    interpret = _interpret_default() if interpret is None else interpret
    batch, kappa = z.shape[0], w.shape[0]
    bm_ = min(bm, max(8, batch))
    zp = _pad_rows(z, bm_)
    wp = _pad_rows(w, bk)
    assign, mind = _k.vq_assign_pallas(zp, wp, bm=bm_, bk=min(bk, wp.shape[0]),
                                       kappa_valid=kappa, interpret=interpret)
    return assign[:batch], mind[:batch]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def vq_delta(z: jax.Array, w: jax.Array, *, bm: int = 128,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused minibatch displacement stats; contract of ``ref.vq_delta_ref``."""
    interpret = _interpret_default() if interpret is None else interpret
    batch = z.shape[0]
    bm_ = min(bm, max(8, batch))
    zp = _pad_rows(z, bm_)
    counts, zsum, _ = _k.vq_delta_pallas(zp, w, bm=bm_, n_valid=batch,
                                         interpret=interpret)
    return counts, zsum


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def distortion(z: jax.Array, w: jax.Array, *, bm: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Mean min-distance (paper eq. 2 per worker) via the fused kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    batch = z.shape[0]
    bm_ = min(bm, max(8, batch))
    zp = _pad_rows(z, bm_)
    _, _, mind = _k.vq_delta_pallas(zp, w, bm=bm_, n_valid=batch,
                                    interpret=interpret)
    return jnp.sum(mind) / batch


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def _delta_via_assign(z: jax.Array, w: jax.Array, *, bm: int, bk: int,
                      interpret: bool | None) -> tuple[jax.Array, jax.Array]:
    """(counts, zsum) through the blocked assignment kernel + a segment sum.

    The blocked ``vq_assign`` kernel streams the codebook in (bk, d) tiles, so
    it works for ANY kappa*d; the scatter-add back to (kappa, d) happens in
    XLA (HBM-resident accumulators) instead of the fused kernel's VMEM ones.
    """
    assign, _ = vq_assign(z, w, bm=bm, bk=bk, interpret=interpret)
    kappa, d = w.shape
    z32 = z.astype(jnp.float32)
    counts = jnp.zeros((kappa,), jnp.float32).at[assign].add(1.0)
    zsum = jnp.zeros((kappa, d), jnp.float32).at[assign].add(z32)
    return counts, zsum


def vq_delta_routed(z: jax.Array, w: jax.Array, *, bm: int = 128,
                    bk: int = 128, budget_bytes: int | None = None,
                    interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """``vq_delta`` with VMEM-aware routing (same contract as ``vq_delta``).

    When the codebook fits the VMEM budget, the fused full-codebook kernel
    runs; when ``kappa*d`` is too large, the blocked ``vq_assign`` kernel +
    an XLA segment sum computes the identical (counts, zsum).
    """
    kappa, d = w.shape
    if delta_fits_vmem(kappa, d, bm=min(bm, max(8, z.shape[0])),
                       budget_bytes=budget_bytes):
        return vq_delta(z, w, bm=bm, interpret=interpret)
    return _delta_via_assign(z, w, bm=bm, bk=bk, interpret=interpret)


def vq_minibatch_step(z: jax.Array, w: jax.Array, eps: jax.Array,
                      *, interpret: bool | None = None) -> jax.Array:
    """One fused minibatch VQ update: w <- w - (eps/|B|) * (counts*w - zsum)."""
    counts, zsum = vq_delta(z, w, interpret=interpret)
    delta = counts[:, None] * w.astype(jnp.float32) - zsum
    return (w.astype(jnp.float32) - (eps / z.shape[0]) * delta).astype(w.dtype)
