"""Jit'd public wrappers around the Pallas VQ kernels.

Handles padding to MXU-aligned block multiples, picks interpret mode
automatically off-TPU (the kernel body then runs as pure-python/jnp on CPU —
bit-identical semantics, which is what the allclose tests exercise), and
exposes the same signatures as the ``ref.py`` oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import vq_assign as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def vq_assign(z: jax.Array, w: jax.Array, *, bm: int = 128, bk: int = 128,
              interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Nearest-prototype assignment; same contract as ``ref.vq_assign_ref``."""
    interpret = _interpret_default() if interpret is None else interpret
    batch, kappa = z.shape[0], w.shape[0]
    bm_ = min(bm, max(8, batch))
    zp = _pad_rows(z, bm_)
    wp = _pad_rows(w, bk)
    assign, mind = _k.vq_assign_pallas(zp, wp, bm=bm_, bk=min(bk, wp.shape[0]),
                                       kappa_valid=kappa, interpret=interpret)
    return assign[:batch], mind[:batch]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def vq_delta(z: jax.Array, w: jax.Array, *, bm: int = 128,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused minibatch displacement stats; contract of ``ref.vq_delta_ref``."""
    interpret = _interpret_default() if interpret is None else interpret
    batch = z.shape[0]
    bm_ = min(bm, max(8, batch))
    zp = _pad_rows(z, bm_)
    counts, zsum, _ = _k.vq_delta_pallas(zp, w, bm=bm_, n_valid=batch,
                                         interpret=interpret)
    return counts, zsum


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def distortion(z: jax.Array, w: jax.Array, *, bm: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Mean min-distance (paper eq. 2 per worker) via the fused kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    batch = z.shape[0]
    bm_ = min(bm, max(8, batch))
    zp = _pad_rows(z, bm_)
    _, _, mind = _k.vq_delta_pallas(zp, w, bm=bm_, n_valid=batch,
                                    interpret=interpret)
    return jnp.sum(mind) / batch


def vq_minibatch_step(z: jax.Array, w: jax.Array, eps: jax.Array,
                      *, interpret: bool | None = None) -> jax.Array:
    """One fused minibatch VQ update: w <- w - (eps/|B|) * (counts*w - zsum)."""
    counts, zsum = vq_delta(z, w, interpret=interpret)
    delta = counts[:, None] * w.astype(jnp.float32) - zsum
    return (w.astype(jnp.float32) - (eps / z.shape[0]) * delta).astype(w.dtype)
