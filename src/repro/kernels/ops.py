"""Jit'd public wrappers around the Pallas VQ kernels.

Handles padding to MXU-aligned block multiples, picks interpret mode
automatically off-TPU (the kernel body then runs as pure-python/jnp on CPU —
bit-identical semantics, which is what the allclose tests exercise), and
exposes the same signatures as the ``ref.py`` oracles.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import vq_assign as _k
from repro.kernels import vq_fused as _f

# Conservative per-core VMEM budget for kernel residency planning.  TPU cores
# have ~16 MiB of VMEM (pallas guide §Memory Spaces); half of it is left for
# double-buffered input blocks, scratch, and the compiler's own staging.
DEFAULT_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def vmem_budget_bytes(budget_bytes: int | None = None) -> int:
    """The VMEM budget used to route between kernels.

    Explicit argument > ``REPRO_VMEM_BUDGET_BYTES`` env var > the default.
    """
    if budget_bytes is not None:
        if budget_bytes <= 0:
            raise ValueError(f"vmem budget must be > 0, got {budget_bytes}")
        return budget_bytes
    env = os.environ.get("REPRO_VMEM_BUDGET_BYTES", "")
    return int(env) if env else DEFAULT_VMEM_BUDGET_BYTES


def delta_vmem_bytes(kappa: int, d: int, *, bm: int = 128,
                     bk: int | None = None, batch: int | None = None,
                     dtype_bytes: int = 4) -> int:
    """VMEM residency of one delta-kernel grid step — the ONE cost model the
    runtime router and the autotuner share.

    ``bk=None`` (or ``bk >= kappa``): the full-codebook ``vq_delta`` kernel —
    codebook + zsum accumulator (both (kappa, d)), the counts column, one
    (bm, d) batch block, and the (bm, kappa) distance/one-hot tiles.

    ``bk < kappa``: the fused blocked assign+delta kernel — one (bm, d)
    point block, the (bk, d) codebook block and its (bk, d)+(bk, 1)
    accumulators, the (bm, bk) distance/one-hot tiles, and the running
    (batch, 1) argmin/min outputs that stay resident for the whole grid
    (``batch`` defaults to ``bm`` when the caller has not fixed it).
    """
    if bk is None or bk >= kappa:
        return dtype_bytes * (2 * kappa * d + kappa + bm * d + 2 * bm * kappa)
    rows = bm if batch is None else max(batch, bm)
    return dtype_bytes * (bm * d + 2 * bk * d + bk + 2 * bm * bk + 2 * rows)


def delta_fits_vmem(kappa: int, d: int, *, bm: int = 128,
                    budget_bytes: int | None = None) -> bool:
    """Can the full-codebook ``vq_delta`` kernel hold ``kappa*d`` in VMEM?"""
    return delta_vmem_bytes(kappa, d, bm=bm) <= vmem_budget_bytes(budget_bytes)


def window_vmem_bytes(kappa: int, d: int, tau: int, *,
                      dtype_bytes: int = 4) -> int:
    """Residency of the fused window kernel: the (tau, d) point stream plus
    its hoisted norms/steps, and ~4 (kappa, d)-sized codebook terms (w, wout,
    zsum/h intermediates) with the one-hot/distance columns."""
    return dtype_bytes * (tau * (d + 2) + 4 * kappa * d + 2 * kappa)


def window_fits_vmem(kappa: int, d: int, tau: int, *,
                     budget_bytes: int | None = None) -> bool:
    """Can a whole tau-step window run codebook-resident in one dispatch?"""
    return (window_vmem_bytes(kappa, d, tau)
            <= vmem_budget_bytes(budget_bytes))


def codebook_fits_vmem(kappa: int, d: int, *,
                       budget_bytes: int | None = None) -> bool:
    """Does a replicated (kappa, d) f32 codebook fit one device's budget?
    (The serving lookup shards kappa across devices when it does not.)"""
    return 4 * kappa * d <= vmem_budget_bytes(budget_bytes)


def _bm_floor(interpret: bool) -> int:
    """Minimum batch-block rows.  Real TPUs want >= 8 rows for sublane
    alignment; the interpret backend has no such constraint — and the fused
    window kernel's bitwise contract needs the batch-of-one per-step block
    to keep its true single-row shape there, because XLA:CPU's
    reduction/matmul emission is shape-dependent (see
    ``vq_fused._window_kernel``)."""
    return 1 if interpret else 8


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


def _tiles(z: jax.Array, w: jax.Array, bm: int | None, bk: int | None,
           kind: str, budget_bytes: int | None = None) -> tuple[int, int]:
    """Resolve (bm, bk): explicit values win, ``None`` comes from the
    autotuner (legacy 128s when the tuner is off).  Runs at trace time —
    shapes are static — so jitted callers pay nothing per step."""
    if bm is None or bk is None:
        cfg = autotune.pick_tiles(z.shape[0], w.shape[0], w.shape[1],
                                  kind=kind, budget_bytes=budget_bytes)
        bm = cfg.bm if bm is None else bm
        bk = cfg.bk if bk is None else bk
    return bm, bk


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def _vq_assign(z, w, *, bm: int, bk: int, interpret: bool):
    batch, kappa = z.shape[0], w.shape[0]
    bm_ = min(bm, max(_bm_floor(interpret), batch))
    zp = _pad_rows(z, bm_)
    wp = _pad_rows(w, bk)
    assign, mind = _k.vq_assign_pallas(zp, wp, bm=bm_, bk=min(bk, wp.shape[0]),
                                       kappa_valid=kappa, interpret=interpret)
    return assign[:batch], mind[:batch]


def vq_assign(z: jax.Array, w: jax.Array, *, bm: int | None = None,
              bk: int | None = None,
              interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Nearest-prototype assignment; same contract as ``ref.vq_assign_ref``."""
    interpret = _interpret_default() if interpret is None else interpret
    bm, bk = _tiles(z, w, bm, bk, "assign")
    return _vq_assign(z, w, bm=bm, bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _vq_delta(z, w, *, bm: int, interpret: bool):
    batch = z.shape[0]
    bm_ = min(bm, max(_bm_floor(interpret), batch))
    zp = _pad_rows(z, bm_)
    counts, zsum, _ = _k.vq_delta_pallas(zp, w, bm=bm_, n_valid=batch,
                                         interpret=interpret)
    return counts, zsum


def vq_delta(z: jax.Array, w: jax.Array, *, bm: int | None = None,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused minibatch displacement stats; contract of ``ref.vq_delta_ref``."""
    interpret = _interpret_default() if interpret is None else interpret
    if bm is None:      # explicit bm skips the tuner entirely (bk is unused
        bm, _ = _tiles(z, w, None, None, "delta")  # here, so no resolution)
    return _vq_delta(z, w, bm=bm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _distortion(z, w, *, bm: int, interpret: bool):
    batch = z.shape[0]
    bm_ = min(bm, max(_bm_floor(interpret), batch))
    zp = _pad_rows(z, bm_)
    _, _, mind = _k.vq_delta_pallas(zp, w, bm=bm_, n_valid=batch,
                                    interpret=interpret)
    return jnp.sum(mind) / batch


def distortion(z: jax.Array, w: jax.Array, *, bm: int | None = None,
               interpret: bool | None = None) -> jax.Array:
    """Mean min-distance (paper eq. 2 per worker) via the fused kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    if bm is None:
        bm, _ = _tiles(z, w, None, None, "delta")
    return _distortion(z, w, bm=bm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def _delta_via_assign(z: jax.Array, w: jax.Array, *, bm: int, bk: int,
                      interpret: bool | None) -> tuple[jax.Array, jax.Array]:
    """(counts, zsum) through the blocked assignment kernel + a segment sum.

    The pre-fusion blocked route: the assignments round-trip through HBM and
    the scatter-add back to (kappa, d) happens in XLA.  Kept as the
    ``fused=False`` comparator the engine benchmark gates against.
    """
    assign, _ = vq_assign(z, w, bm=bm, bk=bk, interpret=interpret)
    kappa, d = w.shape
    z32 = z.astype(jnp.float32)
    counts = jnp.zeros((kappa,), jnp.float32).at[assign].add(1.0)
    zsum = jnp.zeros((kappa, d), jnp.float32).at[assign].add(z32)
    return counts, zsum


@functools.partial(jax.jit, static_argnames=("bm", "bk", "with_delta",
                                             "interpret"))
def _vq_delta_blocked(z, w, residual, *, bm: int, bk: int, with_delta: bool,
                      interpret: bool):
    batch, d = z.shape
    kappa = w.shape[0]
    bm_ = min(bm, max(_bm_floor(interpret), batch))
    zp = _pad_rows(z, bm_)
    wp = _pad_rows(w, bk)
    bk_ = min(bk, wp.shape[0])
    if with_delta:
        rp = _pad_rows(residual.astype(jnp.float32), bk)
        _, _, counts, zsum, delta = _f.vq_delta_blocked_pallas(
            zp, wp, bm=bm_, bk=bk_, n_valid=batch, kappa_valid=kappa,
            residual=rp, interpret=interpret)
        return counts[:kappa], zsum[:kappa], delta[:kappa]
    _, _, counts, zsum = _f.vq_delta_blocked_pallas(
        zp, wp, bm=bm_, bk=bk_, n_valid=batch, kappa_valid=kappa,
        interpret=interpret)
    return counts[:kappa], zsum[:kappa]


def vq_delta_blocked(z: jax.Array, w: jax.Array, *, bm: int | None = None,
                     bk: int | None = None, residual: jax.Array | None = None,
                     interpret: bool | None = None):
    """Fused blocked assign+delta (one dispatch, any ``kappa*d``).

    Returns ``(counts, zsum)``; with ``residual`` given, also the in-VMEM
    displacement epilogue ``counts[:, None]*w - zsum + residual``.
    """
    interpret = _interpret_default() if interpret is None else interpret
    bm, bk = _tiles(z, w, bm, bk, "delta_blocked")
    return _vq_delta_blocked(z, w, residual, bm=bm, bk=bk,
                             with_delta=residual is not None,
                             interpret=interpret)


def vq_delta_routed(z: jax.Array, w: jax.Array, *, bm: int | None = None,
                    bk: int | None = None, budget_bytes: int | None = None,
                    fused: bool = True, interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """``vq_delta`` with VMEM-aware routing (same contract as ``vq_delta``).

    When the codebook fits the VMEM budget, the full-codebook kernel runs;
    when ``kappa*d`` is too large, the fused blocked assign+delta kernel
    keeps everything in one dispatch (``fused=False`` falls back to the
    pre-fusion blocked assign + XLA segment sum).
    """
    kappa, d = w.shape
    bm, bk = _tiles(z, w, bm, bk, "delta", budget_bytes=budget_bytes)
    if delta_fits_vmem(kappa, d, bm=min(bm, max(8, z.shape[0])),
                       budget_bytes=budget_bytes):
        return vq_delta(z, w, bm=bm, interpret=interpret)
    if fused:
        return vq_delta_blocked(z, w, bm=bm, bk=bk, interpret=interpret)
    return _delta_via_assign(z, w, bm=bm, bk=bk, interpret=interpret)


def vq_window(zwin: jax.Array, w0: jax.Array, eps: jax.Array, *,
              interpret: bool | None = None) -> jax.Array:
    """One fused window: tau sequential eq.-1 steps in a single dispatch.

    Bit-identical to scanning ``vq_delta_routed`` + the eq.-8 update over
    the rows of ``zwin`` (the engine gates this).  Callers check
    ``window_fits_vmem`` first — the codebook stays resident throughout.
    """
    interpret = _interpret_default() if interpret is None else interpret
    return _f.vq_window_pallas(zwin, w0, eps, interpret=interpret)


def vq_delta_topk(z: jax.Array, w: jax.Array, residual: jax.Array, *,
                  frac: float, bm: int | None = None, bk: int | None = None,
                  budget_bytes: int | None = None,
                  interpret: bool | None = None):
    """Fused displacement + top-k compression for the sparse transport.

    Computes the eq.-8 displacement with the error-feedback carry folded in
    (``counts*w - zsum + residual``) and compresses it to the transport's
    wire payload — ``(vals (k,), idx (k,) i32, new_residual (kappa, d))``,
    exactly what ``comm.sparse.sparse_allsum`` derives pre-gather, with
    ``k = max(1, int(frac * kappa * d))`` (the shared convention).  In the
    blocked regime the displacement never leaves VMEM before selection.
    """
    interpret = _interpret_default() if interpret is None else interpret
    kappa, d = w.shape
    bm, bk = _tiles(z, w, bm, bk, "delta", budget_bytes=budget_bytes)
    if delta_fits_vmem(kappa, d, bm=min(bm, max(8, z.shape[0])),
                       budget_bytes=budget_bytes):
        counts, zsum = vq_delta(z, w, bm=bm, interpret=interpret)
        full = (counts[:, None] * w.astype(jnp.float32) - zsum
                + residual.astype(jnp.float32))
    else:
        _, _, full = vq_delta_blocked(z, w, bm=bm, bk=bk, residual=residual,
                                      interpret=interpret)
    k = max(1, int(frac * kappa * d))
    return _f.vq_topk_pallas(full, k, interpret=interpret)


def vq_minibatch_step(z: jax.Array, w: jax.Array, eps: jax.Array,
                      *, budget_bytes: int | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """One fused minibatch VQ update: w <- w - (eps/|B|) * (counts*w - zsum).

    Routed through ``vq_delta_routed`` so large-kappa codebooks take the
    blocked kernel instead of blowing the full-codebook VMEM plan.
    """
    counts, zsum = vq_delta_routed(z, w, budget_bytes=budget_bytes,
                                   interpret=interpret)
    delta = counts[:, None] * w.astype(jnp.float32) - zsum
    return (w.astype(jnp.float32) - (eps / z.shape[0]) * delta).astype(w.dtype)
