"""Fused Pallas kernels for the VQ hot path — one dispatch, no round trips.

Two fusions on top of the ``vq_assign.py`` pair:

  * ``vq_delta_blocked_pallas`` — assignment + delta accumulation (counts,
    zsum, min-dist) in ONE Pallas dispatch for the blocked (``kappa*d`` >
    VMEM) regime.  The pre-fusion route (``ops._delta_via_assign``) ran the
    blocked assign kernel, round-tripped the assignments through HBM, and
    scatter-added in XLA; here the grid is ``(2*kappa_blocks,
    batch_blocks)`` with the batch axis minor — an outer *distance* sweep
    (j < K) streams codebook blocks and keeps the running (min, argmin)
    for the WHOLE batch in two VMEM-resident ``(batch, 1)`` outputs, then
    an outer *accumulate* sweep (j >= K) re-streams each codebook block and
    folds every batch block's one-hot contribution into that block's
    (counts, zsum) — output revisits stay consecutive, so the accumulators
    live in VMEM until their single flush.  An optional epilogue forms the
    eq.-8 displacement ``counts*w - zsum + residual`` in VMEM on each
    codebook block's last visit, so the sparse transport's top-k selection
    reads the finished payload instead of re-deriving it from two HBM
    arrays.

  * ``vq_window_pallas`` — the engine's inner loop: ``tau`` SEQUENTIAL
    eq.-1 steps (batch of one point each) fused into one dispatch with the
    codebook resident in VMEM for the whole window.  Each step runs the
    same float ops as the per-step path (d2 via MXU contraction, strict
    argmin, ``w - eps*(counts*w - zsum)``) on single-row operands, so the
    fused window is bit-identical to the per-step scan it replaces — every
    per-row reduction and product is independent of the seven padding rows
    the unfused kernel carries.  That bit-stability is gated by the engine
    benchmark's fused-vs-unfused records.

Block sizes come from ``kernels.autotune``; shapes are padded by ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.vq_assign import BIG


def _fused_delta_kernel(z_ref, w_ref, *refs, bm: int, bk: int, kb: int,
                        n_valid: int, kappa_valid: int, with_delta: bool):
    """Grid = (2*kb, batch_blocks); batch is the minor axis.

    Outer steps j < kb:   distance sweep — codebook block j vs batch block
                          i, running (min, argmin) updated in the resident
                          (batch, 1) outputs.
    Outer steps j >= kb:  accumulate sweep — codebook block j-kb gathers
                          counts/zsum from every batch block i (consecutive
                          revisits of one (bk, ·) output block), plus the
                          optional in-VMEM delta epilogue at i == last.
    """
    if with_delta:
        res_ref, assign_ref, mind_ref, counts_ref, zsum_ref, delta_ref = refs
    else:
        res_ref = delta_ref = None
        assign_ref, mind_ref, counts_ref, zsum_ref = refs
    j = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(jnp.logical_and(j == 0, i == 0))
    def _init_running():
        # the (batch, 1) min/arg outputs have constant index maps: one
        # block covering the whole array, resident for the entire grid
        mind_ref[...] = jnp.full_like(mind_ref, BIG)
        assign_ref[...] = jnp.zeros_like(assign_ref)

    rows = pl.ds(i * bm, bm)

    @pl.when(j < kb)
    def _distance_sweep():
        z = z_ref[...].astype(jnp.float32)           # (bm, d)
        w = w_ref[...].astype(jnp.float32)           # (bk, d)
        z2 = jnp.sum(z * z, axis=1, keepdims=True)
        w2 = jnp.sum(w * w, axis=1)[None, :]
        # ``z @ w.T`` rounds like the ``squared_distances`` oracle (see
        # the note in ``vq_assign._assign_kernel``)
        d2 = z2 - 2.0 * (z @ w.T) + w2                # (bm, bk)
        col = j * bk + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
        d2 = jnp.where(col < kappa_valid, d2, BIG)
        blk_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]
        blk_min = jnp.min(d2, axis=1)[:, None]
        cur_min = mind_ref[rows, :]
        cur_arg = assign_ref[rows, :]
        better = blk_min < cur_min
        mind_ref[rows, :] = jnp.where(better, blk_min, cur_min)
        assign_ref[rows, :] = jnp.where(better, j * bk + blk_arg, cur_arg)

    @pl.when(j >= kb)
    def _accumulate_sweep():
        @pl.when(i == 0)
        def _zero_block():
            counts_ref[...] = jnp.zeros_like(counts_ref)
            zsum_ref[...] = jnp.zeros_like(zsum_ref)

        z = z_ref[...].astype(jnp.float32)           # (bm, d)
        arg = assign_ref[rows, :]                     # (bm, 1) final argmin
        row = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
        valid = row < n_valid
        local = arg - (j - kb) * bk                   # block-local code id
        onehot = (local == jax.lax.broadcasted_iota(
            jnp.int32, (bm, bk), 1)).astype(jnp.float32)
        onehot = jnp.where(valid, onehot, 0.0)
        counts_ref[...] += jnp.sum(onehot, axis=0)[:, None]
        # (bk, bm) x (bm, d) scatter-add as an MXU matmul
        zsum_ref[...] += jax.lax.dot_general(
            onehot, z, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        if with_delta:
            @pl.when(i == nb - 1)
            def _delta_epilogue():
                # eq.-8 displacement + error-feedback carry, formed in VMEM
                # on this codebook block's LAST visit — the top-k selection
                # downstream reads a finished payload
                w = w_ref[...].astype(jnp.float32)
                delta_ref[...] = (counts_ref[...] * w - zsum_ref[...]
                                  + res_ref[...])


def vq_delta_blocked_pallas(z: jax.Array, w: jax.Array, *, bm: int, bk: int,
                            n_valid: int | None = None,
                            kappa_valid: int | None = None,
                            residual: jax.Array | None = None,
                            interpret: bool = False):
    """Fused blocked assign+delta: one dispatch for any ``kappa * d``.

    (batch, d), (kappa, d) -> (assign (batch,) i32, mind (batch,) f32,
    counts (kappa,) f32, zsum (kappa, d) f32[, delta (kappa, d) f32]).
    ``batch % bm == 0`` and ``kappa % bk == 0`` required (``ops.py`` pads).
    The residency plan holds only ``O(bm*d + bk*d + bm*bk + batch)`` bytes
    — never the full codebook — which is what ``ops.delta_vmem_bytes(...,
    bk=...)`` budgets.
    """
    batch, d = z.shape
    kappa, _ = w.shape
    n_valid = batch if n_valid is None else n_valid
    kappa_valid = kappa if kappa_valid is None else kappa_valid
    kb = kappa // bk
    with_delta = residual is not None

    grid = (2 * kb, batch // bm)
    in_specs = [
        pl.BlockSpec((bm, d), lambda j, i: (i, 0)),
        pl.BlockSpec((bk, d), lambda j, i: (j % kb, 0)),
    ]
    out_specs = [
        pl.BlockSpec((batch, 1), lambda j, i: (0, 0)),
        pl.BlockSpec((batch, 1), lambda j, i: (0, 0)),
        pl.BlockSpec((bk, 1), lambda j, i: (jnp.maximum(j - kb, 0), 0)),
        pl.BlockSpec((bk, d), lambda j, i: (jnp.maximum(j - kb, 0), 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        jax.ShapeDtypeStruct((batch, 1), jnp.float32),
        jax.ShapeDtypeStruct((kappa, 1), jnp.float32),
        jax.ShapeDtypeStruct((kappa, d), jnp.float32),
    ]
    inputs = (z, w)
    if with_delta:
        in_specs.append(
            pl.BlockSpec((bk, d), lambda j, i: (jnp.maximum(j - kb, 0), 0)))
        out_specs.append(
            pl.BlockSpec((bk, d), lambda j, i: (jnp.maximum(j - kb, 0), 0)))
        out_shape.append(jax.ShapeDtypeStruct((kappa, d), jnp.float32))
        inputs += (residual.astype(jnp.float32),)

    out = pl.pallas_call(
        functools.partial(_fused_delta_kernel, bm=bm, bk=bk, kb=kb,
                          n_valid=n_valid, kappa_valid=kappa_valid,
                          with_delta=with_delta),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    if with_delta:
        assign, mind, counts, zsum, delta = out
        return assign[:, 0], mind[:, 0], counts[:, 0], zsum, delta
    assign, mind, counts, zsum = out
    return assign[:, 0], mind[:, 0], counts[:, 0], zsum


def _topk_kernel(full_ref, vals_ref, idx_ref, res_ref, *, k: int):
    """Top-k delta compression: the ``sparse_allsum`` per-leaf selection
    (k largest-|.| entries, error-feedback residual) applied in VMEM to a
    finished ``(kappa, d)`` displacement, so the sparse transport's wire
    payload (vals, idx) leaves the kernel directly."""
    full = full_ref[...].astype(jnp.float32)
    flat = full.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    kept = jnp.zeros_like(flat).at[idx].set(vals)
    vals_ref[...] = vals[None, :]
    idx_ref[...] = idx.astype(jnp.int32)[None, :]
    res_ref[...] = (flat - kept).reshape(full.shape)


def vq_topk_pallas(full: jax.Array, k: int, *, interpret: bool = False):
    """(kappa, d) -> (vals (k,), idx (k,) i32, new_residual (kappa, d)).

    Matches ``comm.sparse.sparse_allsum``'s pre-gather compute bit-for-bit:
    same ``lax.top_k`` tie order, same scatter/subtract error feedback.
    """
    kappa, d = full.shape
    vals, idx, res = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(1,),
        in_specs=[pl.BlockSpec((kappa, d), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((kappa, d), jnp.float32),
        ],
        interpret=interpret,
    )(full)
    return vals[0], idx[0], res


def _window_kernel(z_ref, w0_ref, eps_ref, wout_ref, *, tau: int):
    """One fused window: tau sequential eq.-1 steps, codebook VMEM-resident.

    z_ref:   (tau, d)    the window's point stream
    w0_ref:  (kappa, d)  prototypes entering the window
    eps_ref: (tau, 1)    precomputed Robbins-Monro steps (f32)
    wout_ref:(kappa, d)  prototypes after the window

    Bitwise equality with the per-step scan is load-bearing (the engine CI
    gate and the mesh-vs-oracle tier-1 pins both ride on it), and two
    compilation artifacts can silently break it:

      * SHAPES: XLA's reduction/matmul emission is shape-dependent, so the
        distance ops here must see the SAME shapes as ``_delta_kernel``
        does on the per-step path.  On the interpret backend ``ops.py``
        clamps the batch-of-one block to one row (no MXU to align for), so
        each step here computes z2/dot/argmin on the matching (1, d)
        row, and the cross term is spelled ``z @ w.T`` exactly as
        ``core.vq.squared_distances`` writes it — a dim-1/dim-1
        ``dot_general`` accumulates in a different order on XLA:CPU and
        flips near-tie argmins (observed gap: ~2e-7 on unit-scale data).
      * FMA CONTRACTION: the update is left as the plain ``w - eps*h``
        the scan body writes — LLVM contracts BOTH loop contexts into the
        same fma.  Do not "improve" the rounding here (e.g. forcing the
        product to round first): eagerly-executed one-step programs round
        differently from either loop, and matching those breaks the
        jitted-scan equality that actually matters.
    """
    kappa = w0_ref.shape[0]
    zwin = z_ref[...].astype(jnp.float32)            # (tau, d)
    eps_all = eps_ref[...]                           # (tau, 1)

    def step(t, w):
        z = jax.lax.dynamic_slice_in_dim(zwin, t, 1, 0)          # (1, d)
        z2 = jnp.sum(z * z, axis=1, keepdims=True)               # (1, 1)
        w2 = jnp.sum(w * w, axis=1)[None, :]
        d2 = z2 - 2.0 * (z @ w.T) + w2                           # (1, kappa)
        arg = jnp.argmin(d2, axis=1)                             # (1,)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (kappa, 1), 0)
                  == arg[0]).astype(jnp.float32)                 # (kappa, 1)
        zsum = onehot * z                                        # (kappa, d)
        h = onehot * w - zsum
        eps = jax.lax.dynamic_slice_in_dim(eps_all, t, 1, 0)[0, 0]
        return w - eps * h

    wout_ref[...] = jax.lax.fori_loop(
        0, tau, step, w0_ref[...].astype(jnp.float32))


def vq_window_pallas(zwin: jax.Array, w0: jax.Array, eps: jax.Array, *,
                     interpret: bool = False) -> jax.Array:
    """(tau, d), (kappa, d), (tau,) -> w after tau fused sequential steps."""
    tau, d = zwin.shape
    kappa, _ = w0.shape
    return pl.pallas_call(
        functools.partial(_window_kernel, tau=tau),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((tau, d), lambda i: (0, 0)),
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),
            pl.BlockSpec((tau, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((kappa, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kappa, d), jnp.float32),
        interpret=interpret,
    )(zwin, w0.astype(jnp.float32),
      eps.reshape(tau, 1).astype(jnp.float32))
