"""Pure-jnp oracles for the Pallas kernels — the ground truth for allclose tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def vq_assign_ref(z: jax.Array, w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-prototype assignment.

    z: (batch, d), w: (kappa, d) ->
      assign: (batch,) int32 argmin_l ||z - w_l||^2
      mindist: (batch,) float32 min_l ||z - w_l||^2
    """
    z32 = z.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    z2 = jnp.sum(z32 * z32, axis=-1, keepdims=True)
    w2 = jnp.sum(w32 * w32, axis=-1)
    d2 = z2 - 2.0 * (z32 @ w32.T) + w2[None, :]
    return jnp.argmin(d2, axis=-1).astype(jnp.int32), jnp.min(d2, axis=-1)


def vq_delta_ref(z: jax.Array, w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused minibatch VQ displacement (what the training hot loop needs).

    Returns (counts, zsum):
      counts: (kappa,)   number of batch points assigned to each prototype
      zsum:   (kappa, d) sum of the points assigned to each prototype
    The displacement is then ``delta = counts[:, None] * w - zsum`` and the
    minibatch VQ update is ``w <- w - (eps / batch) * delta``.
    """
    assign, _ = vq_assign_ref(z, w)
    onehot = jax.nn.one_hot(assign, w.shape[0], dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    zsum = onehot.T @ z.astype(jnp.float32)
    return counts, zsum


def distortion_ref(z: jax.Array, w: jax.Array) -> jax.Array:
    """Mean over the batch of min_l ||z - w_l||^2 (paper eq. 2 per worker)."""
    _, mind = vq_assign_ref(z, w)
    return jnp.mean(mind)
