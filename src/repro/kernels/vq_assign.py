"""Pallas TPU kernels for the VQ hot spot: fused distance + argmin (+ delta).

The paper's compute bottleneck is the nearest-prototype search over the data
stream.  On TPU we express ``||z - w||^2 = ||z||^2 - 2 z.w^T + ||w||^2`` so
the dominant cost is a (batch, d) x (d, kappa) matmul on the MXU, and fuse
the argmin reduction (and, in the delta kernel, the one-hot scatter-add) into
the same VMEM-resident pass so distances are never materialized in HBM.

Two kernels:

  * ``vq_assign_kernel`` — blocked over (batch, kappa): supports arbitrarily
    large codebooks.  Grid is (batch_blocks, kappa_blocks) with kappa minor,
    keeping a running (min, argmin) in the revisited output block.
  * ``vq_delta_kernel``  — grid over batch blocks with the full codebook
    resident in VMEM: computes assignments AND accumulates per-prototype
    (counts, zsum) in one pass — the whole minibatch VQ update's memory
    traffic is ``batch*d + kappa*d`` instead of ``batch*kappa``.

Block sizes default to MXU-aligned 128s; all shapes are padded by ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38  # python float: safe to close over in kernel bodies


def _assign_kernel(z_ref, w_ref, z2_ref, w2_ref, assign_ref, mind_ref,
                   *, bk: int, kappa_valid: int):
    """Grid = (batch_blocks, kappa_blocks); kappa is the minor axis.

    z_ref:  (bm, d)   batch block (revisited across kappa blocks)
    w_ref:  (bk, d)   codebook block
    z2_ref: (bm, 1)   precomputed ||z||^2
    w2_ref: (1, bk)   precomputed ||w||^2 (BIG on padded rows)
    assign_ref/mind_ref: (bm, 1) running argmin / min, revisited.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mind_ref[...] = jnp.full_like(mind_ref, BIG)
        assign_ref[...] = jnp.zeros_like(assign_ref)

    z = z_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # (bm, bk) distances for this codebook block — MXU matmul + rank-1 terms.
    # The cross term is spelled ``z @ w.T`` (not a dim-1/dim-1 dot_general):
    # XLA:CPU accumulates the two contractions in different orders, and the
    # engine's bitwise fused-vs-scan gate needs the SAME rounding as the
    # ``core.vq.squared_distances`` oracle, which writes ``z @ w.T``.
    d2 = z2_ref[...] - 2.0 * (z @ w.T) + w2_ref[...]

    # mask out padded codebook rows (global kappa index >= kappa_valid)
    col = j * bk + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(col < kappa_valid, d2, BIG)

    blk_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)  # (bm,)
    blk_min = jnp.min(d2, axis=1)                       # (bm,)
    better = blk_min < mind_ref[..., 0]
    mind_ref[..., 0] = jnp.where(better, blk_min, mind_ref[..., 0])
    assign_ref[..., 0] = jnp.where(better, j * bk + blk_arg, assign_ref[..., 0])


def vq_assign_pallas(z: jax.Array, w: jax.Array, *, bm: int = 128,
                     bk: int = 128, kappa_valid: int | None = None,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(batch, d), (kappa, d) -> assign (batch,) int32, mindist (batch,) f32.

    batch % bm == 0 and kappa % bk == 0 are required (ops.py pads).
    """
    batch, d = z.shape
    kappa, _ = w.shape
    kappa_valid = kappa if kappa_valid is None else kappa_valid
    z32 = z.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    z2 = jnp.sum(z32 * z32, axis=1, keepdims=True)          # (batch, 1)
    w2 = jnp.sum(w32 * w32, axis=1)[None, :]                # (1, kappa)

    grid = (batch // bm, kappa // bk)
    assign, mind = pl.pallas_call(
        functools.partial(_assign_kernel, bk=bk, kappa_valid=kappa_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            jax.ShapeDtypeStruct((batch, 1), jnp.float32),
        ],
        interpret=interpret,
    )(z, w, z2, w2)
    return assign[:, 0], mind[:, 0]


def _delta_kernel(z_ref, w_ref, counts_ref, zsum_ref, mind_ref,
                  *, bm: int, n_valid: int):
    """Grid = (batch_blocks,); full codebook resident in VMEM.

    Accumulates counts (kappa, 1) and zsum (kappa, d) across batch blocks via
    revisited output blocks; also writes per-row min distance (for eq. 2).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        zsum_ref[...] = jnp.zeros_like(zsum_ref)

    z = z_ref[...].astype(jnp.float32)           # (bm, d)
    w = w_ref[...].astype(jnp.float32)           # (kappa, d)
    z2 = jnp.sum(z * z, axis=1, keepdims=True)
    w2 = jnp.sum(w * w, axis=1)[None, :]
    # ``z @ w.T`` (not a dim-1/dim-1 dot_general) — rounds exactly like the
    # ``core.vq.squared_distances`` oracle; see the note in ``_assign_kernel``
    d2 = z2 - 2.0 * (z @ w.T) + w2               # (bm, kappa)

    row = i * bm + jax.lax.broadcasted_iota(jnp.int32, (z.shape[0], 1), 0)
    valid = row < n_valid                         # (bm, 1)

    mind_ref[...] = jnp.where(valid, jnp.min(d2, axis=1, keepdims=True), 0.0)
    arg = jnp.argmin(d2, axis=1)                  # (bm,)
    onehot = (arg[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (z.shape[0], w.shape[0]), 1)).astype(jnp.float32)
    onehot = jnp.where(valid, onehot, 0.0)        # mask padded rows

    counts_ref[...] += jnp.sum(onehot, axis=0)[:, None]
    # (kappa, bm) x (bm, d) scatter-add as an MXU matmul
    zsum_ref[...] += jax.lax.dot_general(
        onehot, z, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def vq_delta_pallas(z: jax.Array, w: jax.Array, *, bm: int = 128,
                    n_valid: int | None = None, interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(batch, d), (kappa, d) -> counts (kappa,), zsum (kappa, d), mind (batch,).

    Requires batch % bm == 0 (ops.py pads) and kappa*d to fit in VMEM.
    """
    batch, d = z.shape
    kappa, _ = w.shape
    n_valid = batch if n_valid is None else n_valid

    counts, zsum, mind = pl.pallas_call(
        functools.partial(_delta_kernel, bm=bm, n_valid=n_valid),
        grid=(batch // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((kappa, 1), lambda i: (0, 0)),
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kappa, 1), jnp.float32),
            jax.ShapeDtypeStruct((kappa, d), jnp.float32),
            jax.ShapeDtypeStruct((batch, 1), jnp.float32),
        ],
        interpret=interpret,
    )(z, w)
    return counts[:, 0], zsum, mind[:, 0]
