"""Roofline-driven (bm, bk) tile selection with a deterministic cache.

The Pallas kernels used to run on hardcoded ``bm=128``/``bk=128`` tiles
regardless of shape.  This module picks tiles per
``(kind, batch, kappa, d, dtype_bytes, device_kind)`` from the
``distributed.roofline.VqCell`` analytic model: among the candidate tiles
whose residency fits the VMEM budget (``ops.delta_vmem_bytes`` — the SAME
formula the runtime router uses, so the two can never disagree about what
fits), minimize the roofline time bound

    max(delta_flops / PEAK_FLOPS, delta_hbm_bytes / HBM_BW)

where ``delta_hbm_bytes`` counts the blocked kernel's refetch traffic —
larger tiles mean fewer refetches, so the model pushes tiles as large as
the budget allows, then grid size breaks ties deterministically.

Three modes, set once at launch (``--autotune {off,cache,search}``):

  * ``off``    — legacy fixed (128, 128) tiles, no cache touched.
  * ``cache``  — model-picked tiles, memoized in-process and (optionally)
                 in a JSON file (``REPRO_AUTOTUNE_CACHE=path`` or
                 ``set_cache_path``).  Same shape => same config, always.
  * ``search`` — model ranks candidates, then the top ``SEARCH_TOP_N`` are
                 actually timed (best-of-3 jitted walls on synthetic data)
                 and the fastest wins.  Results land in the same cache, so
                 a hit never re-searches.

The JSON cache is keyed by the full tune key INCLUDING the device kind, so
a file tuned on one accelerator never leaks tiles to another.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading

from repro.distributed.roofline import HBM_BW, PEAK_FLOPS, VqCell

MODES = ("off", "cache", "search")
DEFAULT_TILES = (128, 128)          # the pre-autotune hardcoded tiles
CANDIDATE_TILES = (8, 16, 32, 64, 128, 256, 512)
SEARCH_TOP_N = 3                    # model-ranked candidates timed in search
SEARCH_BATCH_REPS = 3               # best-of walls per timed candidate


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bk: int


class _TunerState:
    def __init__(self):
        self.mode = "cache"
        self.cache: dict[str, TileConfig] = {}
        self.cache_path: str | None = None
        self.file_loaded = False
        self.searches = 0            # model/search evaluations (cache misses)
        self.lock = threading.Lock()


_STATE = _TunerState()


def set_mode(mode: str) -> None:
    if mode not in MODES:
        raise ValueError(f"autotune mode must be one of {MODES}, got {mode!r}")
    _STATE.mode = mode


def get_mode() -> str:
    return _STATE.mode


def set_cache_path(path: str | None) -> None:
    """Point the tuner at a JSON cache file (None = in-memory only)."""
    _STATE.cache_path = path
    _STATE.file_loaded = False


def reset(mode: str | None = None) -> None:
    """Drop all cached configs and counters (tests use this)."""
    with _STATE.lock:
        _STATE.cache.clear()
        _STATE.searches = 0
        _STATE.file_loaded = False
        if mode is not None:
            _STATE.mode = mode


def search_count() -> int:
    """How many cache misses have been resolved since the last reset."""
    return _STATE.searches


def device_kind() -> str:
    import jax
    dev = jax.devices()[0]
    return f"{dev.platform}:{getattr(dev, 'device_kind', '?')}"


def tune_key(kind: str, batch: int, kappa: int, d: int,
             dtype_bytes: int = 4, device: str | None = None) -> str:
    device = device_kind() if device is None else device
    return f"{kind}|b{batch}|k{kappa}|d{d}|e{dtype_bytes}|{device}"


def _candidates(batch: int, kappa: int, d: int, *, budget_bytes: int,
                dtype_bytes: int) -> list[TileConfig]:
    """VMEM-feasible (bm, bk) pairs.  bm beyond the (8-row-padded) batch or
    bk beyond the codebook only pads work, so those are clamped out."""
    from repro.kernels import ops

    bm_cap = max(8, batch)
    bk_cap = max(8, kappa)
    bms = sorted({min(c, bm_cap) for c in CANDIDATE_TILES})
    bks = sorted({min(c, bk_cap) for c in CANDIDATE_TILES})
    out = []
    for bm in bms:
        for bk in bks:
            need = ops.delta_vmem_bytes(kappa, d, bm=bm, bk=bk,
                                        dtype_bytes=dtype_bytes)
            if need <= budget_bytes:
                out.append(TileConfig(bm=bm, bk=bk))
    if not out:                       # degenerate budget: smallest tiles
        out.append(TileConfig(bm=min(bms), bk=min(bks)))
    return out


def model_time(cfg: TileConfig, batch: int, kappa: int, d: int,
               dtype_bytes: int = 4) -> float:
    """Roofline time bound (s) for one fused delta dispatch at these tiles."""
    cell = VqCell(d=d, kappa=kappa, tau=1, bm=cfg.bm, bk=cfg.bk,
                  dtype_bytes=dtype_bytes)
    return max(cell.delta_flops(batch) / PEAK_FLOPS,
               cell.delta_hbm_bytes(batch) / HBM_BW)


def _rank(cands: list[TileConfig], batch: int, kappa: int, d: int,
          dtype_bytes: int) -> list[TileConfig]:
    """Deterministic model ranking: roofline time, then grid steps, then
    the larger tile — a pure function of the tune key."""
    def score(cfg: TileConfig):
        cell = VqCell(d=d, kappa=kappa, tau=1, bm=cfg.bm, bk=cfg.bk,
                      dtype_bytes=dtype_bytes)
        kb, nb = cell.delta_grid(batch)
        return (model_time(cfg, batch, kappa, d, dtype_bytes),
                2 * kb * nb, -cfg.bm, -cfg.bk)
    return sorted(cands, key=score)


def _measure(cfg: TileConfig, batch: int, kappa: int, d: int) -> float:
    """Best-of-N jitted wall for one fused-delta dispatch (search mode)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (batch, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (kappa, d), jnp.float32)
    fn = jax.jit(lambda z, w: ops.vq_delta_routed(z, w, bm=cfg.bm, bk=cfg.bk))
    jax.block_until_ready(fn(z, w))   # compile outside the timed region
    best = float("inf")
    for _ in range(SEARCH_BATCH_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(z, w))
        best = min(best, time.perf_counter() - t0)
    return best


def _load_file_cache() -> None:
    path = _STATE.cache_path or os.environ.get("REPRO_AUTOTUNE_CACHE")
    _STATE.file_loaded = True
    if not path or not os.path.exists(path):
        return
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return
    for k, v in raw.items():
        if (isinstance(v, (list, tuple)) and len(v) == 2
                and k not in _STATE.cache):
            _STATE.cache[k] = TileConfig(bm=int(v[0]), bk=int(v[1]))


def _save_file_cache() -> None:
    path = _STATE.cache_path or os.environ.get("REPRO_AUTOTUNE_CACHE")
    if not path:
        return
    try:
        with open(path, "w") as f:
            json.dump({k: [c.bm, c.bk] for k, c in
                       sorted(_STATE.cache.items())}, f, indent=0,
                      sort_keys=True)
    except OSError:
        pass


def pick_tiles(batch: int, kappa: int, d: int, *, kind: str = "delta",
               budget_bytes: int | None = None,
               dtype_bytes: int = 4) -> TileConfig:
    """Tuned (bm, bk) for one kernel shape — THE entry point.

    ``off`` returns the legacy fixed tiles.  Otherwise the config comes
    from the cache (file-backed if configured) or is computed once: model
    pick in ``cache`` mode, model-ranked measurement in ``search`` mode.
    """
    if _STATE.mode == "off":
        return TileConfig(*DEFAULT_TILES)
    from repro.kernels import ops

    budget = ops.vmem_budget_bytes(budget_bytes)
    key = tune_key(kind, batch, kappa, d, dtype_bytes)
    with _STATE.lock:
        if not _STATE.file_loaded:
            _load_file_cache()
        hit = _STATE.cache.get(key)
        if hit is not None:
            return hit
        mode = _STATE.mode
    # rank (and in search mode, measure) OUTSIDE the lock: _measure runs
    # jitted kernels whose wrappers may consult the tuner for OTHER keys —
    # holding a non-reentrant lock across that is a deadlock
    cands = _rank(_candidates(batch, kappa, d, budget_bytes=budget,
                              dtype_bytes=dtype_bytes),
                  batch, kappa, d, dtype_bytes)
    best = cands[0]
    if mode == "search" and len(cands) > 1:
        timed = [(_measure(c, batch, kappa, d), i, c)
                 for i, c in enumerate(cands[:SEARCH_TOP_N])]
        best = min(timed)[2]
    with _STATE.lock:
        hit = _STATE.cache.get(key)
        if hit is not None:        # a racing thread resolved it first
            return hit
        _STATE.searches += 1
        _STATE.cache[key] = best
        _save_file_cache()
        return best
