"""Gradient / delta compression with error feedback.

Composes with the paper's delta-merge: instead of compressing per-step
gradients (which hurts convergence), we compress the tau-window DELTA before
the cross-pod merge — the residual is carried into the next window's delta
(error feedback, Stich et al. style), so nothing is lost, only delayed.

``topk_compress`` keeps the k largest-magnitude entries per leaf (as a dense
masked tensor — TPU-friendly; the bandwidth win is modeled for the roofline
as k/n of the leaf bytes, and realized on hardware via sparse DCN transfers).

The top-k selection itself lives in ``repro.comm.sparse`` (the pluggable
``SparseTransport`` is the gathered-indices production form of the same
protocol); this module keeps the dense-masked-tensor spelling for roofline
modeling and offline compression studies.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm.sparse import topk_threshold_mask as _topk_mask


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree like params, f32


def init_error_feedback(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def topk_compress(delta, ef: ErrorFeedbackState, *, frac: float = 0.01
                  ) -> tuple[Any, ErrorFeedbackState, jax.Array]:
    """Returns (compressed_delta, new_ef_state, kept_fraction).

    compressed = topk(delta + residual); residual' = (delta + residual) - compressed.
    """
    def leaf(d, r):
        full = d.astype(jnp.float32) + r
        mask = _topk_mask(full, frac)
        kept = full * mask
        return kept.astype(d.dtype), full - kept

    flat_d, treedef = jax.tree.flatten(delta)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [leaf(d, r) for d, r in zip(flat_d, flat_r)]
    compressed = jax.tree.unflatten(treedef, [o[0] for o in outs])
    residual = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return compressed, ErrorFeedbackState(residual=residual), jnp.asarray(frac)
