"""Minimal optax-style optimizers (pure pytree transforms, no deps).

AdamW and SGD(+momentum), with cosine / inverse-sqrt / paper-style
Robbins-Monro schedules.  State layouts mirror param sharding (the dry-run
assigns them the same NamedSharding as their parameter leaf), so ZeRO-style
optimizer-state sharding falls out of the param sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: Any        # first moment, f32, param-shaped
    nu: Any        # second moment, f32, param-shaped
    count: jax.Array


class SGDState(NamedTuple):
    momentum: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def adamw(lr: Callable[[jax.Array], jax.Array] | float, *, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(mu=zeros, nu=_tmap(jnp.copy, zeros),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        lr_t = lr_fn(c)
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                   state.mu, grads)
        nu = _tmap(lambda v, g: b2 * v + (1 - b2)
                   * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step).astype(p.dtype)

        new_params = _tmap(upd, params, mu, nu)
        return new_params, AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init=init, update=update)


def sgd(lr: Callable[[jax.Array], jax.Array] | float, *,
        momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        mom = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if momentum else None
        return SGDState(momentum=mom, count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        lr_t = lr_fn(c)
        if momentum:
            mom = _tmap(lambda m, g: momentum * m + g.astype(jnp.float32),
                        state.momentum, grads)
            step = mom
        else:
            mom, step = None, _tmap(lambda g: g.astype(jnp.float32), grads)
        new_params = _tmap(
            lambda p, s: (p.astype(jnp.float32) - lr_t * s).astype(p.dtype),
            params, step)
        return new_params, SGDState(momentum=mom, count=c)

    return Optimizer(init=init, update=update)


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

def cosine_schedule(peak: float, *, warmup: int = 100,
                    total: int = 10000, floor: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)
    return fn


def rm_schedule(eps0: float = 0.5, decay: float = 1.0):
    """The paper's Robbins-Monro step sequence eps_t = eps0 / (1 + decay*t)."""
    def fn(count):
        return eps0 / (1.0 + decay * count.astype(jnp.float32))
    return fn


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
