"""Host-side span tracer with Chrome trace-event (Perfetto) export.

Two kinds of spans share one timeline:

* **wall spans** — real host work (``with tracer.span("run", ...):``),
  stamped with ``time.monotonic_ns`` (never ``time.time`` — span math must
  not jump with wall-clock adjustments).  Nesting is the natural ``with``
  nesting; a span records its attrs, track, and thread automatically.
* **modeled spans** — the engine's tick-timeline reconstruction
  (``tracer.add_span(...)`` with explicit start/duration).  The mesh
  engine runs windows as fused device scans, so per-worker compute and
  merge phases are *modeled* from the same ``NetworkModel`` arithmetic
  that produces ``wall_ticks`` — which is exactly what makes the eq.-9
  compute/communication overlap visible in Perfetto without
  de-optimising the hot path.

Counters (``tracer.counter``) become Chrome ``"C"`` events — Perfetto
renders them as per-process line charts (distortion and codebook
divergence over the run).

``Tracer(enabled=False)`` (or the shared ``NULL_TRACER``) makes every
call a constant-time no-op so instrumented code paths stay on the
<3% overhead budget the obs bench gate enforces.

The exported file is plain Chrome trace-event JSON: open it at
https://ui.perfetto.dev (or chrome://tracing).  ``ts``/``dur`` are
microseconds; one Perfetto "process" per logical process (host, ticks),
one "thread" per track (worker, tier, host thread).
"""

from __future__ import annotations

import atexit
import contextlib
import dataclasses
import json
import os
import signal
import threading
import time
from typing import Any


@dataclasses.dataclass(slots=True)
class SpanEvent:
    """One completed (or still-open) span on the trace timeline."""

    name: str
    start_us: float
    dur_us: float | None           # None while the span is still open
    process: str                   # Perfetto process (pid) label
    track: str                     # Perfetto thread (tid) label
    attrs: dict[str, Any]


@dataclasses.dataclass(slots=True)
class CounterEvent:
    """One sample of a numeric series (Chrome ``"C"`` counter event)."""

    name: str
    value: float
    ts_us: float
    process: str


class Tracer:
    """Bounded span/counter recorder; thread-safe; monotonic-clock.

    ``process``/``track`` name the Perfetto lanes.  Wall spans default to
    ``process="host"`` and the current thread's name; modeled spans pick
    their own (e.g. ``process="ticks", track="worker 3"``).

    Buffers are bounded like ``CommLog``: a long-lived serve/train loop
    appends forever, so only the newest ``max_spans``/``max_counters``
    events are kept and the oldest dropped — ``dropped_spans``/
    ``dropped_counters`` say how many fell off the front, so a truncated
    export is detectable instead of silently partial.  The defaults are
    sized so a benchmark-scale run never trims (the obs overhead bench
    emits thousands of spans, not millions).
    """

    WALL_PROCESS = "host"
    TICK_PROCESS = "ticks"

    def __init__(self, *, enabled: bool = True, max_spans: int = 1 << 20,
                 max_counters: int = 1 << 20):
        if max_spans < 1 or max_counters < 1:
            raise ValueError(
                f"span/counter buffer bounds must be >= 1, got "
                f"max_spans={max_spans} max_counters={max_counters}")
        self.enabled = enabled
        self.max_spans = max_spans
        self.max_counters = max_counters
        self._lock = threading.Lock()
        self._spans: list[SpanEvent] = []
        self._counters: list[CounterEvent] = []
        self._dropped_spans = 0           # trimmed off the front, ever
        self._dropped_counters = 0
        self._open = 0                    # wall spans entered but not exited
        self._t0_ns = time.monotonic_ns()

    # -- bounds --------------------------------------------------------------

    @property
    def dropped_spans(self) -> int:
        """Spans trimmed off the front of the buffer, ever."""
        return self._dropped_spans

    @property
    def dropped_counters(self) -> int:
        """Counter samples trimmed off the front of the buffer, ever."""
        return self._dropped_counters

    def _trim(self) -> None:
        """Drop-oldest down to the bounds (under ``_lock``)."""
        excess = len(self._spans) - self.max_spans
        if excess > 0:
            del self._spans[:excess]
            self._dropped_spans += excess
        excess = len(self._counters) - self.max_counters
        if excess > 0:
            del self._counters[:excess]
            self._dropped_counters += excess

    # -- clock ---------------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since this tracer was created (monotonic)."""
        return (time.monotonic_ns() - self._t0_ns) / 1e3

    # -- wall spans ----------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, *, process: str | None = None,
             track: str | None = None, **attrs):
        """Record a real (monotonic-clock) span around the ``with`` body."""
        if not self.enabled:
            yield None
            return
        ev = SpanEvent(
            name=name, start_us=self.now_us(), dur_us=None,
            process=process or self.WALL_PROCESS,
            track=track or threading.current_thread().name,
            attrs=attrs)
        with self._lock:
            self._spans.append(ev)
            self._open += 1
            if len(self._spans) > self.max_spans:
                self._trim()
        try:
            yield ev
        finally:
            ev.dur_us = self.now_us() - ev.start_us
            with self._lock:
                self._open -= 1

    # -- modeled spans and counters ------------------------------------------

    def add_span(self, name: str, start_us: float, dur_us: float, *,
                 process: str | None = None, track: str, **attrs) -> None:
        """Record a span with explicit timestamps (tick-timeline tracks).

        Lock-free: ``list.append`` is atomic under the GIL, and modeled
        spans are the instrumentation hot path (hundreds per window-scan
        segment) — this call is on the obs bench's <3% overhead budget.
        """
        if not self.enabled:
            return
        self._spans.append(SpanEvent(
            name, float(start_us), max(float(dur_us), 0.0),
            process or self.TICK_PROCESS, track, attrs))
        # bound check stays off the common path: with the default 1M cap
        # the branch is a len() compare, and only over-cap calls take the
        # lock to trim — the obs bench's <3% overhead budget holds
        if len(self._spans) > self.max_spans:
            with self._lock:
                self._trim()

    def counter(self, name: str, value: float, ts_us: float | None = None, *,
                process: str | None = None) -> None:
        """Sample a numeric series (rendered as a Perfetto line chart)."""
        if not self.enabled:
            return
        self._counters.append(CounterEvent(
            name, float(value),
            self.now_us() if ts_us is None else float(ts_us),
            process or self.TICK_PROCESS))
        if len(self._counters) > self.max_counters:
            with self._lock:
                self._trim()

    # -- introspection -------------------------------------------------------

    @property
    def open_spans(self) -> int:
        """Wall spans currently entered but not yet exited."""
        with self._lock:
            return self._open

    def spans(self, name: str | None = None) -> list[SpanEvent]:
        with self._lock:
            evs = list(self._spans)
        return evs if name is None else [e for e in evs if e.name == name]

    def counters(self, name: str | None = None) -> list[CounterEvent]:
        with self._lock:
            evs = list(self._counters)
        return evs if name is None else [e for e in evs if e.name == name]

    # -- export --------------------------------------------------------------

    def chrome_events(self) -> list[dict]:
        """Chrome trace-event dicts (``"X"`` spans, ``"C"`` counters,
        ``"M"`` metadata naming each process/track)."""
        with self._lock:
            spans = list(self._spans)
            counters = list(self._counters)
        pids: dict[str, int] = {}
        tids: dict[tuple[int, str], int] = {}
        events: list[dict] = []

        def pid_of(process: str) -> int:
            if process not in pids:
                pids[process] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pids[process], "tid": 0,
                               "args": {"name": process}})
            return pids[process]

        def tid_of(pid: int, track: str) -> int:
            key = (pid, track)
            if key not in tids:
                tids[key] = sum(1 for p, _ in tids if p == pid) + 1
                events.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": tids[key],
                               "args": {"name": track}})
            return tids[key]

        for s in spans:
            pid = pid_of(s.process)
            events.append({
                "ph": "X", "name": s.name, "cat": s.process,
                "ts": s.start_us,
                "dur": s.dur_us if s.dur_us is not None else 0.0,
                "pid": pid, "tid": tid_of(pid, s.track),
                "args": {**s.attrs,
                         **({"unclosed": True} if s.dur_us is None else {})},
            })
        for c in counters:
            events.append({"ph": "C", "name": c.name, "ts": c.ts_us,
                           "pid": pid_of(c.process), "tid": 0,
                           "args": {c.name: c.value}})
        return events

    def export_chrome(self, path: str) -> None:
        """Write a Perfetto-loadable Chrome trace-event JSON file."""
        doc = {"traceEvents": self.chrome_events(), "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)


NULL_TRACER = Tracer(enabled=False)


class ExitFlush:
    """Flush trace/metrics exports even when the run dies early.

    A chaos-killed or Ctrl-C'd training loop never reaches the
    end-of-run ``export_chrome``/``dump_jsonl`` calls, losing exactly
    the artifacts needed to debug why it died.  Constructing an
    ``ExitFlush`` registers an ``atexit`` hook (and, opt-in, a SIGTERM
    hook — the chaos sweep and container runtimes kill with SIGTERM)
    that writes whatever the tracer/metrics hold *now*.  ``flush()`` is
    idempotent: the normal happy-path flush disarms the exit hook, so
    artifacts are written exactly once either way.

    Usable as a context manager for scoped runs::

        with ExitFlush(tracer=tr, trace_path="t.json") as fl:
            executor.run(...)
        # flushed here, and also on KeyboardInterrupt/SystemExit
    """

    def __init__(self, *, tracer=None, trace_path: str | None = None,
                 metrics=None, metrics_path: str | None = None,
                 run: str | None = None, catch_sigterm: bool = False):
        if tracer is None and metrics is None:
            raise ValueError("ExitFlush needs a tracer and/or metrics")
        self.tracer = tracer
        self.trace_path = trace_path
        self.metrics = metrics
        self.metrics_path = metrics_path
        self.run = run
        self._done = False
        self._lock = threading.Lock()
        self._prev_sigterm = None
        atexit.register(self._atexit)
        if catch_sigterm and threading.current_thread() is threading.main_thread():
            self._prev_sigterm = signal.signal(signal.SIGTERM, self._on_sigterm)

    def _atexit(self) -> None:
        self.flush()

    def _on_sigterm(self, signum, frame) -> None:
        self.flush()
        # restore and re-deliver so the process still dies with the
        # default SIGTERM semantics (exit code 143, parent sees the signal)
        signal.signal(signum, self._prev_sigterm or signal.SIG_DFL)
        os.kill(os.getpid(), signum)

    def flush(self) -> dict[str, str]:
        """Write the pending exports; no-op on every call after the first."""
        with self._lock:
            if self._done:
                return {}
            self._done = True
        atexit.unregister(self._atexit)
        if self._prev_sigterm is not None:
            with contextlib.suppress(ValueError):   # not main thread at exit
                signal.signal(signal.SIGTERM, self._prev_sigterm)
        written: dict[str, str] = {}
        if self.tracer is not None and self.trace_path:
            self.tracer.export_chrome(self.trace_path)
            written["trace"] = self.trace_path
        if self.metrics is not None and self.metrics_path:
            self.metrics.dump_jsonl(self.metrics_path, run=self.run)
            written["metrics"] = self.metrics_path
        return written

    def __enter__(self) -> "ExitFlush":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.flush()
