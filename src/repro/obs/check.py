"""Trace-invariant checker for exported Chrome trace-event files.

Invariants (the structural contract downstream tooling relies on):

1. the file is valid Chrome trace-event JSON (``traceEvents`` list);
2. spans are *balanced* — every ``"X"`` span was closed (no
   ``unclosed`` marker, non-negative duration);
3. every ``merge`` span carries a ``tier`` attr (``0``/``1``, or
   ``"flat"`` for untiered transports) and an integral
   ``wire_bytes >= 0`` attr;
4. per (pid, tid) track, same-track spans nest properly — a span
   either contains or is disjoint from its successors (Perfetto
   renders overlapping same-track spans misleadingly);
5. metadata names every pid/tid that events reference.

CLI (wired into ``make ci-local``)::

    PYTHONPATH=src python -m repro.obs.check out.trace.json \
        [--expect-merge-tiers 0,1] [--expect-counter codebook_divergence] \
        [--expect-span chaos_kill]

Exit 0 = all invariants hold, 1 = violations (listed on stdout).
"""

from __future__ import annotations

import argparse
import json
from typing import Any


def load_trace(path: str) -> list[dict[str, Any]]:
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):            # bare-array form is also legal
        return doc
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents list")
    return events


def check_trace(events: list[dict[str, Any]], *,
                expect_merge_tiers: set[str] | None = None,
                expect_counters: list[str] | None = None,
                expect_spans: list[str] | None = None) -> list[str]:
    """Return a list of human-readable violations (empty = clean)."""
    errors: list[str] = []
    named_pids: set[int] = set()
    named_tids: set[tuple[int, int]] = set()
    seen_merge_tiers: set[str] = set()
    seen_counters: set[str] = set()
    seen_spans: set[str] = set()
    by_track: dict[tuple[int, int], list[dict]] = {}

    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "process_name":
                named_pids.add(ev.get("pid"))
            elif ev.get("name") == "thread_name":
                named_tids.add((ev.get("pid"), ev.get("tid")))
            continue
        if ph == "C":
            seen_counters.add(ev.get("name", ""))
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"event {i}: counter {ev.get('name')!r} "
                              f"has no numeric ts")
            continue
        if ph in ("B", "E"):
            errors.append(f"event {i}: begin/end pair event ({ph}) — "
                          f"exporter must emit complete 'X' spans only")
            continue
        if ph != "X":
            continue
        name = ev.get("name", "")
        seen_spans.add(name)
        args = ev.get("args") or {}
        if args.get("unclosed"):
            errors.append(f"event {i}: span {name!r} was never closed")
        dur = ev.get("dur")
        if not isinstance(dur, (int, float)) or dur < 0:
            errors.append(f"event {i}: span {name!r} has bad dur={dur!r}")
            continue
        if name == "merge":
            tier = args.get("tier")
            if tier is None:
                errors.append(f"event {i}: merge span missing 'tier' attr")
            else:
                seen_merge_tiers.add(str(tier))
            wb = args.get("wire_bytes")
            if not isinstance(wb, (int, float)) or wb < 0:
                errors.append(f"event {i}: merge span has bad "
                              f"wire_bytes={wb!r}")
        by_track.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)

    # referenced pids/tids must be named by metadata
    for (pid, tid), evs in by_track.items():
        if pid not in named_pids:
            errors.append(f"pid {pid} has spans but no process_name metadata")
        if (pid, tid) not in named_tids:
            errors.append(f"pid {pid} tid {tid} has spans but no "
                          f"thread_name metadata")
        # same-track spans must nest or be disjoint (small tolerance for
        # float microsecond rounding)
        evs = sorted(evs, key=lambda e: (e["ts"], -e["dur"]))
        stack: list[tuple[float, float, str]] = []
        for ev in evs:
            s, e = ev["ts"], ev["ts"] + ev["dur"]
            while stack and s >= stack[-1][1] - 1e-6:
                stack.pop()
            if stack and e > stack[-1][1] + 1e-6:
                errors.append(
                    f"track pid={pid} tid={tid}: span {ev['name']!r} "
                    f"[{s:.1f}, {e:.1f}]us straddles enclosing "
                    f"{stack[-1][2]!r} ending at {stack[-1][1]:.1f}us")
                continue
            stack.append((s, e, ev["name"]))

    if expect_merge_tiers is not None:
        missing = expect_merge_tiers - seen_merge_tiers
        if missing:
            errors.append(f"expected merge tiers {sorted(missing)} absent "
                          f"(saw {sorted(seen_merge_tiers) or 'none'})")
    for cname in expect_counters or []:
        if cname not in seen_counters:
            errors.append(f"expected counter series {cname!r} absent "
                          f"(saw {sorted(seen_counters) or 'none'})")
    for sname in expect_spans or []:
        if sname not in seen_spans:
            errors.append(f"expected span {sname!r} absent "
                          f"(saw {sorted(seen_spans) or 'none'})")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--expect-merge-tiers", default=None,
                    help="comma-separated tier attrs that must appear on "
                         "merge spans (e.g. '0,1' or 'flat')")
    ap.add_argument("--expect-counter", action="append", default=[],
                    help="counter series that must be present (repeatable)")
    ap.add_argument("--expect-span", action="append", default=[],
                    help="span names that must be present, e.g. "
                         "'chaos_kill' (repeatable)")
    args = ap.parse_args(argv)

    try:
        events = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL {args.trace}: unreadable trace: {e}")
        return 1
    tiers = (set(args.expect_merge_tiers.split(","))
             if args.expect_merge_tiers else None)
    errors = check_trace(events, expect_merge_tiers=tiers,
                         expect_counters=args.expect_counter,
                         expect_spans=args.expect_span)
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    n_counters = sum(1 for e in events if e.get("ph") == "C")
    if errors:
        for err in errors:
            print(f"FAIL {err}")
        print(f"{args.trace}: {len(errors)} violation(s) over "
              f"{n_spans} spans")
        return 1
    print(f"OK {args.trace}: {n_spans} spans, {n_counters} counter "
          f"samples, invariants hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
