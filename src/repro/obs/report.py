"""Self-contained HTML perf-trajectory report over the BENCH family.

Renders every committed ``BENCH_*.json`` baseline (engine, elastic,
serve, comm, hier, obs, chaos, profile) plus any ``--profile`` export
from a training run into ONE static HTML file: no external JS/CSS/fonts,
every chart is inline SVG — so the file survives as a CI artifact and
opens identically offline, air-gapped, or years later.

Layout:

* a wall-time overview — every benchmark record that measured a
  ``wall_s``, as one horizontal bar chart grouped by suite, so a perf
  trajectory across PRs is one artifact-diff away;
* a roofline-attribution section (from ``BENCH_profile.json`` /
  ``--profile``) — per (scheme x transport) stacked bars of the
  compute / memory / collective / host shares of measured window wall,
  the visual form of the paper's "which scheme wastes time where"
  accounting;
* one table per suite with the raw records; numeric series (distortion
  curves, wall-sample arrays) render as inline SVG sparklines.

CLI::

    python -m repro.obs.report --dir . --out perf_report.html \
        [--profile PROF.json] [--title "..."]
"""

from __future__ import annotations

import argparse
import glob
import html
import json
import os

_CSS = """
body { font-family: -apple-system, 'Segoe UI', Roboto, sans-serif;
       margin: 2rem auto; max-width: 72rem; color: #1a1a2e; }
h1 { border-bottom: 3px solid #4c78a8; padding-bottom: .3rem; }
h2 { margin-top: 2.2rem; color: #16324f; }
table { border-collapse: collapse; font-size: .82rem; margin: .8rem 0; }
th, td { border: 1px solid #d7dbe0; padding: .25rem .55rem;
         text-align: right; white-space: nowrap; }
th { background: #eef2f6; position: sticky; top: 0; }
td:first-child, th:first-child { text-align: left; }
.meta { color: #5a6b7b; font-size: .85rem; }
.legend span { display: inline-block; margin-right: 1.1rem;
               font-size: .82rem; }
.swatch { display: inline-block; width: .8rem; height: .8rem;
          margin-right: .3rem; vertical-align: -0.08rem; }
svg { vertical-align: middle; }
.small { font-size: .78rem; color: #5a6b7b; }
"""

TERM_COLORS = {"compute": "#4c78a8", "memory": "#f58518",
               "collective": "#e45756", "host": "#b8c2cc"}
_BAR_COLOR = "#4c78a8"


def _esc(x) -> str:
    return html.escape(str(x))


def _fmt(v) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _is_num_list(v) -> bool:
    return (isinstance(v, list) and len(v) >= 2
            and all(isinstance(x, (int, float)) and not isinstance(x, bool)
                    for x in v))


def sparkline(values, *, w: int = 130, h: int = 26) -> str:
    """Inline SVG polyline of a numeric series (no axes — shape only)."""
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    n = len(values)
    pts = " ".join(
        f"{2 + i * (w - 4) / max(n - 1, 1):.1f},"
        f"{h - 3 - (v - lo) / span * (h - 6):.1f}"
        for i, v in enumerate(values))
    return (f'<svg width="{w}" height="{h}" role="img">'
            f'<polyline points="{pts}" fill="none" stroke="{_BAR_COLOR}" '
            f'stroke-width="1.3"/></svg>'
            f'<span class="small"> [{_fmt(lo)} .. {_fmt(hi)}]</span>')


def _bar_chart(rows, *, w: int = 640, bar_h: int = 16) -> str:
    """Horizontal labeled bar chart: rows = [(label, value_seconds)]."""
    if not rows:
        return ""
    vmax = max(v for _, v in rows) or 1.0
    gap, label_w = 6, 330
    height = len(rows) * (bar_h + gap) + gap
    parts = [f'<svg width="{w + label_w + 90}" height="{height}" role="img">']
    for i, (label, v) in enumerate(rows):
        y = gap + i * (bar_h + gap)
        bw = max(v / vmax * w, 1.0)
        parts.append(
            f'<text x="{label_w - 6}" y="{y + bar_h - 4}" '
            f'text-anchor="end" font-size="11">{_esc(label)}</text>'
            f'<rect x="{label_w}" y="{y}" width="{bw:.1f}" '
            f'height="{bar_h}" fill="{_BAR_COLOR}"/>'
            f'<text x="{label_w + bw + 5:.1f}" y="{y + bar_h - 4}" '
            f'font-size="11">{v * 1e3:.2f} ms</text>')
    parts.append("</svg>")
    return "".join(parts)


def _stacked_bar(shares: dict[str, float], *, w: int = 420,
                 h: int = 18) -> str:
    """One stacked horizontal bar of term shares (clipped into [0, 1])."""
    parts = [f'<svg width="{w}" height="{h}" role="img">'
             f'<rect x="0" y="0" width="{w}" height="{h}" fill="#f3f5f7"/>']
    x = 0.0
    for term, color in TERM_COLORS.items():
        frac = min(max(shares.get(term, 0.0), 0.0), 1.0)
        bw = frac * w
        if bw > 0.2:
            parts.append(f'<rect x="{x:.1f}" y="0" width="{bw:.1f}" '
                         f'height="{h}" fill="{color}"/>')
        x = min(x + bw, w)
    parts.append("</svg>")
    return "".join(parts)


def _records_table(records: list[dict]) -> str:
    """Union-of-keys table over a suite's result records."""
    cols: list[str] = []
    for r in records:
        for k in r:
            if k not in cols:
                cols.append(k)
    out = ["<table><tr>"]
    out += [f"<th>{_esc(c)}</th>" for c in cols]
    out.append("</tr>")
    for r in records:
        out.append("<tr>")
        for c in cols:
            v = r.get(c, "")
            if _is_num_list(v):
                cell = sparkline(v)
            elif isinstance(v, (dict, list)):
                s = json.dumps(v)
                cell = _esc(s if len(s) <= 60 else s[:57] + "...")
            else:
                cell = _esc(_fmt(v))
            out.append(f"<td>{cell}</td>")
        out.append("</tr>")
    out.append("</table>")
    return "".join(out)


def _wall_overview(docs: dict[str, dict]) -> str:
    rows = []
    for suite in sorted(docs):
        for r in docs[suite].get("results", []):
            if not isinstance(r, dict):
                continue
            wall = r.get("wall_s")
            if not isinstance(wall, (int, float)) or wall <= 0:
                continue
            bits = [suite]
            for k in ("executor", "kind", "scheme", "transport", "mode",
                      "m", "sparse_frac"):
                if r.get(k) not in (None, ""):
                    bits.append(f"{k}={r[k]}")
            rows.append((" ".join(bits), float(wall)))
    if not rows:
        return ""
    return ("<h2>Wall-time overview</h2>"
            "<p class='meta'>Every benchmark record with a measured "
            "wall_s, across all committed baselines.</p>"
            + _bar_chart(rows))


def _attribution_section(attributions: list[dict], origin: str) -> str:
    if not attributions:
        return ""
    legend = "".join(
        f'<span><span class="swatch" style="background:{c}"></span>'
        f"{t}</span>" for t, c in TERM_COLORS.items())
    out = [f"<h2>Roofline attribution <span class='meta'>({_esc(origin)})"
           "</span></h2>",
           "<p class='meta'>Measured per-window wall decomposed against "
           "the three-term roofline (analytic compute/HBM for the VQ "
           "inner loop, collective bytes from the compiled program's "
           "HLO) plus the host residual.</p>",
           f"<p class='legend'>{legend}</p>", "<table><tr>"]
    for c in ("scheme", "transport", "topology", "m", "n_windows",
              "window_wall_s", "attribution", "consistency",
              "collective_bytes_per_window", "compiled_in_run"):
        out.append(f"<th>{_esc(c)}</th>")
    out.append("</tr>")
    for a in attributions:
        eff = a.get("efficiency", {})
        out.append("<tr>")
        for c in ("scheme", "transport", "topology", "m", "n_windows"):
            out.append(f"<td>{_esc(a.get(c, ''))}</td>")
        out.append(f"<td>{_fmt(a.get('window_wall_s', 0.0))}</td>")
        out.append(f"<td>{_stacked_bar(eff)}</td>")
        out.append(f"<td>{_fmt(a.get('consistency', ''))}</td>")
        out.append(f"<td>{_fmt(a.get('collective_bytes_per_window', ''))}"
                   "</td>")
        out.append(f"<td>{_esc(a.get('compiled_in_run', ''))}</td></tr>")
    out.append("</table>")
    return "".join(out)


def render_report(docs: dict[str, dict], *, title: str = "Perf trajectory",
                  profile_runs: list[tuple[str, list[dict]]] = ()) -> str:
    """Render the full report; ``docs`` maps suite name -> BENCH doc."""
    body = [f"<h1>{_esc(title)}</h1>"]
    metas = {(d.get("devices"), d.get("backend")) for d in docs.values()}
    if metas:
        body.append("<p class='meta'>baselines: "
                    + ", ".join(f"{_esc(s)} (devices={_esc(d.get('devices'))}"
                                f", {_esc(d.get('backend'))})"
                                for s, d in sorted(docs.items())) + "</p>")
    body.append(_wall_overview(docs))
    prof_doc = docs.get("profile")
    if prof_doc:
        attrs = [r.get("attribution", r) for r in prof_doc.get("results", [])]
        attrs = [a for a in attrs if isinstance(a, dict) and "efficiency" in a]
        body.append(_attribution_section(attrs, "BENCH_profile.json"))
    for origin, attrs in profile_runs:
        body.append(_attribution_section(attrs, origin))
    for suite in sorted(docs):
        doc = docs[suite]
        recs = [r for r in doc.get("results", []) if isinstance(r, dict)]
        if not recs:
            continue
        body.append(f"<h2>{_esc(suite)}</h2>")
        body.append(_records_table(recs))
    return ("<!DOCTYPE html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            f"<body>{''.join(body)}</body></html>")


def load_bench_dir(path: str) -> dict[str, dict]:
    """All committed ``BENCH_<suite>.json`` files (skips ``*.fresh.json``)."""
    docs: dict[str, dict] = {}
    for p in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        base = os.path.basename(p)
        if base.endswith(".fresh.json"):
            continue
        try:
            with open(p) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        docs[doc.get("suite") or base[len("BENCH_"):-len(".json")]] = doc
    return docs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=".", help="directory of BENCH_*.json")
    ap.add_argument("--out", default="perf_report.html")
    ap.add_argument("--title", default="Perf trajectory")
    ap.add_argument("--profile", action="append", default=[],
                    help="additional Profiler export(s) (PROF.json) to "
                         "render alongside the baselines")
    args = ap.parse_args(argv)
    docs = load_bench_dir(args.dir)
    runs = []
    for p in args.profile:
        with open(p) as f:
            doc = json.load(f)
        runs.append((os.path.basename(p), doc.get("attributions", [])))
    html_text = render_report(docs, title=args.title, profile_runs=runs)
    with open(args.out, "w") as f:
        f.write(html_text)
    n_attr = sum(len(a) for _, a in runs)
    print(f"wrote {args.out}: {len(docs)} baseline suites"
          + (f", {n_attr} profiled runs" if n_attr else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
