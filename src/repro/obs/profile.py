"""Roofline-attributed profiling: decompose measured wall into cost terms.

The paper's whole argument is a wall-clock accounting exercise — which
parallelization scheme wastes time where.  PR 6's spans say *how long* a
run took; this module says *why*: each run's measured wall is decomposed
per window against the three-term roofline

* ``compute``    — analytic device FLOPs for the VQ inner loop
  (``VqCell.window_flops``, the (d, kappa, tau, bm) hand count) over the
  TPU-v5e peak,
* ``memory``     — analytic HBM traffic (``VqCell.window_hbm_bytes``)
  over HBM bandwidth,
* ``collective`` — merge bytes parsed out of the *actual compiled*
  program's post-SPMD HLO, trip-count-corrected for the window scan
  (``hlo_analysis.analyze_collectives``), over ICI link bandwidth,

plus an explicit ``host`` residual — whatever measured wall the modeled
terms do not explain (Python dispatch, transfers, the CPU backend being
nothing like a TPU).  The residual is *clamped at zero*: attribution can
under-explain wall (big host term) but the check gate fails when the
modeled terms overshoot the measured wall, which is what catches a wrong
analytic count or a mis-inferred trip count.

Wiring: ``MeshExecutor`` (and ``ElasticMeshExecutor``, which shares one
profiler across its per-M segment executors) calls

* ``record_program(key, hlo, cost)``  at each compile miss — the engine
  switches to AOT lowering when a profiler is attached so the compiled
  text comes from the very executable that then runs (zero extra
  compiles; the ``observe`` cache key already forks instrumented
  programs, profiling rides the same fork),
* ``note_segment(...)``               per executed run/segment with the
  (scheme, m, n_windows, d, kappa, tau, n_eval) shapes,
* ``finish_run(wall_s)``              once the run's wall is measured.

``finish_run`` emits ``roofline_efficiency{term=}`` gauges and
``attributed_*_ns`` counters through the shared ``MetricsRegistry`` and
appends an attribution record (exported by ``--profile PROF.json`` and
benchmarked by ``--suite profile``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.distributed import hlo_analysis
from repro.distributed.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, VqCell,
                                        vq_roofline_terms)

TERMS = ("compute", "memory", "collective", "host")


@dataclasses.dataclass
class ProgramCost:
    """Cost facts parsed from one compiled mesh program."""

    key: str
    collective_bytes: float            # whole-program, trip-corrected
    bytes_by_kind: dict[str, float]
    loops: list[tuple[str, int]]       # (while body, trip count)
    cost_flops: float | None           # XLA cost_analysis (body counted once)
    cost_bytes: float | None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Profiler:
    """Per-run cost attribution against the three-term roofline.

    Opt-in and engine-agnostic: holds no jax state, only parsed HLO facts
    and shape metadata the engine reports.  Attach the run's
    ``MetricsRegistry`` to also publish gauges/counters.
    """

    def __init__(self, *, metrics=None):
        self.metrics = metrics
        self.programs: dict[str, ProgramCost] = {}
        self.attributions: list[dict] = []
        self._pending: list[dict] = []

    # -- engine-facing hooks -------------------------------------------------

    def record_program(self, key: Any, hlo_text: str, cost=None) -> ProgramCost:
        """Parse a freshly compiled program's HLO (called on compile miss)."""
        coll = hlo_analysis.analyze_collectives(hlo_text)
        flops = bytes_ = None
        if cost is not None:
            c0 = cost[0] if isinstance(cost, (list, tuple)) else cost
            if isinstance(c0, dict):
                flops = c0.get("flops")
                bytes_ = c0.get("bytes accessed")
        pc = ProgramCost(
            key=str(key),
            collective_bytes=float(coll["total_bytes"]),
            bytes_by_kind=dict(coll["bytes_by_kind"]),
            loops=list(coll["loops"]),
            cost_flops=flops, cost_bytes=bytes_)
        self.programs[pc.key] = pc
        return pc

    def note_segment(self, *, program: Any, scheme: str, transport: str,
                     topology: str, m: int, n_windows: int, d: int,
                     kappa: int, tau: int, n_eval: int = 0,
                     compiled: bool = False) -> None:
        """Report one executed segment's shapes (a whole run for the fixed-M
        executor; one per-M slice for an elastic run)."""
        self._pending.append(dict(
            program=str(program), scheme=scheme, transport=transport,
            topology=topology, m=int(m), n_windows=max(int(n_windows), 1),
            d=int(d), kappa=int(kappa), tau=int(tau), n_eval=int(n_eval),
            compiled=bool(compiled)))

    def finish_run(self, wall_s: float) -> dict | None:
        """Attribute one run's measured wall across the pending segments.

        Per-window terms from each segment's ``VqCell`` (collective term
        from that segment's compiled program when recorded, analytic dense
        merge otherwise) are combined weighted by window count; the
        ``host`` term is the clamped residual, so
        ``sum(terms) == window wall`` exactly unless the model overshoots.
        """
        segs, self._pending = self._pending, []
        if not segs or wall_s <= 0:
            return None
        total_windows = sum(s["n_windows"] for s in segs)
        window_wall = wall_s / total_windows

        t = {"compute": 0.0, "memory": 0.0, "collective": 0.0}
        flops = hbm = coll_bytes = 0.0
        for s in segs:
            cell = VqCell(d=s["d"], kappa=s["kappa"], tau=s["tau"],
                          n_eval=s["n_eval"])
            prog = self.programs.get(s["program"])
            coll_per_win = (prog.collective_bytes / s["n_windows"]
                            if prog is not None else None)
            terms = vq_roofline_terms(
                cell, collective_bytes_per_window=coll_per_win)
            w = s["n_windows"] / total_windows
            for k in t:
                t[k] += terms[f"t_{k}"] * w
            flops += terms["window_flops"] * w
            hbm += terms["window_hbm_bytes"] * w
            coll_bytes += terms["collective_bytes"] * w

        modeled = sum(t.values())
        t["host"] = max(window_wall - modeled, 0.0)
        attributed = modeled + t["host"]
        consistency = abs(attributed - window_wall) / window_wall
        first = segs[0]
        rec = {
            "scheme": first["scheme"],
            "transport": first["transport"],
            "topology": first["topology"],
            "m": first["m"],
            "segments": len(segs),
            "n_windows": total_windows,
            "tau": first["tau"],
            "d": first["d"],
            "kappa": first["kappa"],
            "wall_s": wall_s,
            "window_wall_s": window_wall,
            **{f"t_{k}_s": v for k, v in t.items()},
            "attributed_window_s": attributed,
            "consistency": consistency,
            "efficiency": {k: (v / window_wall if window_wall > 0 else 0.0)
                           for k, v in t.items()},
            "window_flops": flops,
            "window_hbm_bytes": hbm,
            "collective_bytes_per_window": coll_bytes,
            "compiled_in_run": any(s["compiled"] for s in segs),
            "peaks": {"flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW},
        }
        self.attributions.append(rec)
        if self.metrics is not None:
            labels = {"scheme": first["scheme"],
                      "transport": first["transport"]}
            for k in TERMS:
                self.metrics.gauge("roofline_efficiency", term=k,
                                   **labels).set(rec["efficiency"][k])
                self.metrics.counter(f"attributed_{k}_ns", **labels).inc(
                    t[k] * total_windows * 1e9)
        return rec

    # -- export --------------------------------------------------------------

    def as_dict(self) -> dict:
        return {
            "attributions": self.attributions,
            "programs": {k: p.as_dict() for k, p in self.programs.items()},
        }

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1)

    def summary_table(self) -> str:
        """Aligned per-run attribution table (for ``--profile`` stdout)."""
        if not self.attributions:
            return "(no profiled runs)"
        hdr = (f"{'scheme':<12} {'wall_s':>9} {'win_us':>9} "
               f"{'compute%':>9} {'memory%':>8} {'collective%':>12} "
               f"{'host%':>7} {'consistency':>12}")
        lines = [hdr, "-" * len(hdr)]
        for r in self.attributions:
            eff = r["efficiency"]
            lines.append(
                f"{r['scheme']:<12} {r['wall_s']:>9.4f} "
                f"{r['window_wall_s'] * 1e6:>9.1f} "
                f"{eff['compute'] * 100:>8.3f}% {eff['memory'] * 100:>7.3f}% "
                f"{eff['collective'] * 100:>11.3f}% {eff['host'] * 100:>6.1f}% "
                f"{r['consistency']:>12.4f}")
        return "\n".join(lines)
