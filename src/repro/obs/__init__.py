"""Unified observability: span tracing + metrics registry + exporters.

``Tracer`` records wall spans (monotonic clock) and modeled tick-timeline
spans, exporting Chrome trace-event JSON for Perfetto.  ``MetricsRegistry``
holds counters/gauges/streaming histograms and dumps an append-only JSONL
sink.  ``check_trace`` validates the structural invariants CI gates on.
"""

from repro.obs.check import check_trace, load_trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               format_metric, load_jsonl)
from repro.obs.profile import Profiler
from repro.obs.trace import (NULL_TRACER, CounterEvent, ExitFlush, SpanEvent,
                             Tracer)

__all__ = [
    "NULL_TRACER",
    "Counter",
    "CounterEvent",
    "ExitFlush",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Profiler",
    "SpanEvent",
    "Tracer",
    "check_trace",
    "format_metric",
    "load_jsonl",
    "load_trace",
]
