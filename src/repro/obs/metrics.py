"""Counters, gauges, and streaming histograms behind one registry.

Metric names are dotted strings plus optional labels
(``wire_bytes{tag=merge, tier=1}``).  Three instrument kinds:

* ``Counter`` — monotone accumulator (``inc``): wire bytes, staleness
  windows, resize events.
* ``Gauge`` — last-value-wins with min/max/count: queue depth, fill rate,
  codebook divergence per window.
* ``Histogram`` — streaming log-bucketed distribution with p50/p99.
  Buckets are geometric with ratio ``2**(1/8)`` (~9%/bucket), so
  quantiles carry a bounded ~4.5% relative error at O(1) memory —
  no sample retention, negligible hot-path cost.

Export is an append-only JSONL sink (one object per metric per ``dump``
call, stamped with a run label) that ``benchmarks/check_regression.py``
and ad-hoc tooling can consume line by line, plus ``summary_table()``
for the end-of-run report ``launch/train.py``/``serve.py`` print.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Any

_BUCKET_LOG = math.log(2.0) / 8.0       # geometric buckets, ratio 2**(1/8)


class Counter:
    """Monotone accumulator."""

    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self) -> dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """Last-value-wins sample with range tracking."""

    kind = "gauge"

    def __init__(self):
        self.value = 0.0
        self.n = 0
        self.min = math.inf
        self.max = -math.inf

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.n += 1
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self) -> dict[str, Any]:
        return {"value": self.value, "n": self.n,
                "min": self.min if self.n else 0.0,
                "max": self.max if self.n else 0.0}


class Histogram:
    """Streaming log-bucketed histogram (p50/p99 within ~4.5%)."""

    kind = "histogram"

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: dict[int, int] = {}   # bucket index -> count

    @staticmethod
    def _bucket(v: float) -> int:
        # bucket 0 holds all v <= 0 (and denormal-tiny values)
        if v <= 1e-12:
            return -(10 ** 6)
        return int(math.floor(math.log(v) / _BUCKET_LOG))

    @staticmethod
    def _bucket_value(b: int) -> float:
        if b <= -(10 ** 6):
            return 0.0
        # geometric-mean representative of the bucket
        return math.exp((b + 0.5) * _BUCKET_LOG)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        b = self._bucket(v)
        self._buckets[b] = self._buckets.get(b, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]) from the bucket stream."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if not self.count:
            return 0.0
        rank = q * (self.count - 1)
        seen = 0
        for b in sorted(self._buckets):
            seen += self._buckets[b]
            if seen > rank:
                # clamp the representative to the observed range so
                # single-sample and extreme quantiles are exact-ish
                return min(max(self._bucket_value(b), self.min), self.max)
        return self.max

    def snapshot(self) -> dict[str, Any]:
        return {"count": self.count, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


def _labels_key(labels: dict[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def format_metric(name: str, labels: dict[str, Any] | None) -> str:
    if not labels:
        return name
    inner = ", ".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Process-wide named instruments; thread-safe get-or-create."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple[str, tuple], Any] = {}

    def _get(self, cls, name: str, labels: dict[str, Any]):
        key = (name, _labels_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {format_metric(name, labels)} already registered "
                    f"as {type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    # -- export --------------------------------------------------------------

    def snapshot(self) -> list[dict[str, Any]]:
        """One dict per metric: name, labels, kind, and current values."""
        with self._lock:
            items = sorted(self._metrics.items())
        out = []
        for (name, lkey), metric in items:
            out.append({"name": name, "labels": dict(lkey),
                        "kind": metric.kind, **metric.snapshot()})
        return out

    def dump_jsonl(self, path: str, *, run: str | None = None,
                   append: bool = True) -> int:
        """Append one JSON line per metric to ``path``; returns line count."""
        rows = self.snapshot()
        with open(path, "a" if append else "w") as f:
            for row in rows:
                if run is not None:
                    row = {"run": run, **row}
                f.write(json.dumps(row) + "\n")
        return len(rows)

    def summary_table(self) -> str:
        """Aligned human-readable table of every registered metric."""
        rows = [("metric", "kind", "value", "p50", "p99", "n")]
        for m in self.snapshot():
            label = format_metric(m["name"], m["labels"])
            if m["kind"] == "histogram":
                rows.append((label, "hist", f"{m['mean']:.6g}",
                             f"{m['p50']:.6g}", f"{m['p99']:.6g}",
                             str(m["count"])))
            elif m["kind"] == "gauge":
                rows.append((label, "gauge", f"{m['value']:.6g}",
                             "-", "-", str(m["n"])))
            else:
                rows.append((label, "count", f"{m['value']:.6g}",
                             "-", "-", "-"))
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
                 for r in rows]
        lines.insert(1, "-" * len(lines[0]))
        return "\n".join(lines)


def load_jsonl(path: str) -> list[dict[str, Any]]:
    """Read a metrics JSONL sink back into a list of dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
