"""Distributed VQ on the production mesh — the paper's workload at pod scale.

The simulation in ``schemes.py`` validates the algorithms; this module runs
them as REAL SPMD programs: the dataset is sharded over the DP axes (the
paper's "dataset split among the local memories"), every DP shard is one of
the paper's workers, and the reducing phase is a psum over those axes —
scheme S2/eq. (8) exactly, with the Pallas fused kernel as the per-worker
hot loop.

  * ``make_vq_window_step(...)`` — one tau-point window per worker:
    local sequential VQ displacements (scan over the worker's tau points),
    then ``w_srd <- w_srd - psum(delta)``.
  * ``make_minibatch_vq_step(...)`` — the batched variant: each worker
    computes the fused (counts, zsum) displacement over its shard via the
    Pallas kernel and merges — this is the throughput-optimal form on MXU
    hardware, and the beyond-paper upgrade of the paper's point-at-a-time
    loop (EXPERIMENTS.md §Perf it.9 lowers it on the 512-chip mesh).

Codebook sharding: for large (kappa, d) the codebook is TP-sharded over
'model' on the kappa dim; the distance pass then computes local-kappa
argmin candidates and a tiny (value, index) psum-style tournament picks the
global winner — all expressed with jnp ops, GSPMD inserts the collectives.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import vq
from repro.kernels import ops as kops


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def vq_shardings(mesh: Mesh, *, kappa: int, d: int, batch: int):
    """(w_sharding, data_sharding) for the production mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get("model", 1)
    w_spec = P("model", None) if kappa % tp == 0 and tp > 1 else P(None, None)
    dp = _dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]
    z_spec = P(dp, None) if batch % max(dp_total, 1) == 0 else P(None, None)
    return NamedSharding(mesh, w_spec), NamedSharding(mesh, z_spec)


def make_minibatch_vq_step(*, eps0: float = 0.5, decay: float = 1.0,
                           use_kernel: bool = True) -> Callable:
    """(w, t, z_batch) -> (w', t').  z_batch: (global_batch, d) sharded over
    DP; the fused displacement is a global psum by construction (counts and
    zsum are sums over the batch dim), i.e. eq. (8) with tau = one batch."""

    def step(w: jax.Array, t: jax.Array, z: jax.Array):
        eps = vq.default_steps(t + 1, eps0=eps0, decay=decay)
        if use_kernel:
            counts, zsum = kops.vq_delta(z, w)
        else:
            from repro.kernels import ref
            counts, zsum = ref.vq_delta_ref(z, w)
        delta = counts[:, None] * w.astype(jnp.float32) - zsum
        w_new = (w.astype(jnp.float32)
                 - (eps / z.shape[0]) * delta).astype(w.dtype)
        return w_new, t + 1

    return step


def make_window_vq_step(*, tau: int, eps0: float = 0.5,
                        decay: float = 1.0) -> Callable:
    """Paper-faithful S2 window: each DP shard runs ``tau`` SEQUENTIAL
    eq.-(1) steps on its local points, then the displacements are summed
    into the shared version (eq. 8).

    (w, t, z_window) -> (w', t + tau).  z_window: (n_workers, tau, d) with
    the worker dim sharded over DP — inside, a vmap over workers of the
    sequential scan; the final psum falls out of averaging... no: of the
    SUM over the worker dim, which GSPMD lowers to the DP all-reduce."""

    def step(w: jax.Array, t: jax.Array, z_window: jax.Array):
        def one_worker(zw):
            delta, _ = vq.window_displacement(w, zw, t, eps0=eps0,
                                              decay=decay)
            return delta

        deltas = jax.vmap(one_worker)(z_window)      # (workers, kappa, d)
        total = jnp.sum(deltas.astype(jnp.float32), axis=0)
        w_new = (w.astype(jnp.float32) - total).astype(w.dtype)
        return w_new, t + tau

    return step


@functools.partial(jax.jit, static_argnames=("steps", "eps0", "decay"))
def run_minibatch_vq(w0: jax.Array, data: jax.Array, *, steps: int,
                     eps0: float = 0.5, decay: float = 1.0):
    """Convenience: scan the minibatch step over a (steps, batch, d) stream.
    Returns (w_final, distortion_trace).  The trace is evaluated on a FIXED
    eval set (a <=4096-point prefix of the stream, the async_runtime cap) so
    entries are comparable across steps — per-incoming-batch distortion is
    noise-dominated whenever the per-step improvement is smaller than the
    batch-to-batch variance, and a full-stream eval would cost
    O(steps * total_points) per trace entry."""
    step = make_minibatch_vq_step(eps0=eps0, decay=decay, use_kernel=False)
    flat = data.reshape(-1, data.shape[-1])
    eval_set = flat[: min(4096, flat.shape[0])]

    def body(carry, z):
        w, t = carry
        w, t = step(w, t, z)
        return (w, t), vq.distortion(eval_set, w)

    (w, _), trace = jax.lax.scan(
        body, (w0, jnp.zeros((), jnp.int32)), data)
    return w, trace
