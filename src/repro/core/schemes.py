"""Synchronous parallelization schemes — paper Sections 2 and 3.

Both schemes run ``M`` concurrent sequential-VQ executions (one per worker,
``vmap`` over the worker axis) and synchronize every ``tau`` processed points:

  * ``scheme_average``  (Section 2, eq. 3):  w_srd = mean_i w^i(tau) — the
    intuitive scheme the paper shows does NOT speed up convergence.
  * ``scheme_delta``    (Section 3, eq. 8):  w_srd <- w_srd - sum_i Delta^i —
    displacement merging, which does.

Wall-clock semantics: workers are concurrent, so one synchronization window
costs ``tau`` ticks of wall time regardless of M (communications are
instantaneous here, as in the paper's simulated architecture; delays are the
subject of ``async_vq``).  The returned curves are indexed by wall tick.

These functions are also the reference oracles for the distributed
``repro.core.merge`` strategies used by the training framework.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vq


class SchemeResult(NamedTuple):
    w_shared: jax.Array      # (kappa, d) final shared prototypes
    wall_ticks: jax.Array    # (n_windows,) wall time at each sync point
    distortion: jax.Array    # (n_windows,) eq. (2) criterion of w_srd at each sync


def _windows(data: jax.Array, tau: int) -> jax.Array:
    """(M, n, d) -> (n_windows, M, tau, d), dropping the ragged tail."""
    m, n, d = data.shape
    n_windows = n // tau
    usable = data[:, : n_windows * tau, :]
    return usable.reshape(m, n_windows, tau, d).transpose(1, 0, 2, 3)


@functools.partial(jax.jit, static_argnames=("tau", "eps0", "decay"))
def scheme_average(w0: jax.Array, data: jax.Array, eval_data: jax.Array,
                   *, tau: int, eps0: float = 0.5, decay: float = 1.0) -> SchemeResult:
    """Paper Section 2 (eq. 3): synchronize by AVERAGING worker versions.

    data: (M, n, d) — worker-local streams. eval_data: (M, n_eval, d) for the
    eq. (2) criterion.  All workers share the step schedule eps_t indexed by
    their local step count (t advances by tau per window).
    """
    windows = _windows(data, tau)

    def window_body(carry, zwin):
        w_srd, t0 = carry
        # every worker starts the window from the shared version
        _, w_finals = jax.vmap(
            lambda z: vq.window_displacement(w_srd, z, t0, eps0=eps0, decay=decay)
        )(zwin)
        w_srd = jnp.mean(w_finals, axis=0)  # eq. (3)
        t0 = t0 + tau
        return (w_srd, t0), (t0, vq.distortion_multi(eval_data, w_srd))

    (w_srd, _), (ticks, curve) = jax.lax.scan(
        window_body, (w0, jnp.asarray(0, jnp.int32)), windows
    )
    return SchemeResult(w_shared=w_srd, wall_ticks=ticks, distortion=curve)


@functools.partial(jax.jit, static_argnames=("tau", "eps0", "decay"))
def scheme_delta(w0: jax.Array, data: jax.Array, eval_data: jax.Array,
                 *, tau: int, eps0: float = 0.5, decay: float = 1.0) -> SchemeResult:
    """Paper Section 3 (eq. 8): merge by applying the SUM of displacements.

    w_srd <- w_srd - sum_j Delta^j_{t-tau->t};  workers restart from w_srd.
    """
    windows = _windows(data, tau)

    def window_body(carry, zwin):
        w_srd, t0 = carry
        deltas, _ = jax.vmap(
            lambda z: vq.window_displacement(w_srd, z, t0, eps0=eps0, decay=decay)
        )(zwin)
        w_srd = w_srd - jnp.sum(deltas, axis=0)  # eq. (8) reducing phase
        t0 = t0 + tau
        return (w_srd, t0), (t0, vq.distortion_multi(eval_data, w_srd))

    (w_srd, _), (ticks, curve) = jax.lax.scan(
        window_body, (w0, jnp.asarray(0, jnp.int32)), windows
    )
    return SchemeResult(w_shared=w_srd, wall_ticks=ticks, distortion=curve)


@functools.partial(jax.jit, static_argnames=("tau", "eps0", "decay"))
def scheme_sequential(w0: jax.Array, data: jax.Array, eval_data: jax.Array,
                      *, tau: int, eps0: float = 0.5, decay: float = 1.0) -> SchemeResult:
    """M=1 baseline with the same evaluation cadence (every tau points).

    data: (n, d) single stream (or (1, n, d)).
    """
    stream = data[None] if data.ndim == 2 else data
    assert stream.shape[0] == 1, "sequential baseline takes a single stream"
    return scheme_delta(w0, stream, eval_data, tau=tau, eps0=eps0, decay=decay)
