"""True asynchronous VQ runtime — the paper's CloudDALVQ system shape.

``async_vq.py`` simulates eq. (9) tick-by-tick inside one ``lax.scan``; this
module runs it FOR REAL: worker threads execute local VQ concurrently, a
dedicated reducer thread merges displacement messages with no barrier
anywhere, and a versioned blob store stands in for Azure blob storage (the
paper's section-4 architecture: "each machine uploads its updates and
downloads the shared version as soon as its previous uploads and downloads
are completed; a dedicated unit permanently modifies the shared version").

Used by ``examples/cloud_async_vq.py`` and ``tests/test_async_runtime.py``;
straggler injection (per-worker delay multipliers) quantifies the scheme's
tolerance claim on a real thread pool rather than a model of one.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable

import numpy as np

from repro.kernels import ref as kref


class BlobStore:
    """Versioned shared-value store (the Azure-blob stand-in).

    ``put`` installs a new version; ``get`` returns (version, value).
    Reads and writes are atomic but unsynchronized with each other — exactly
    the consistency the paper's reducer/worker protocol needs (workers may
    read a slightly stale shared version; that IS eq. 9)."""

    def __init__(self, value: np.ndarray):
        self._lock = threading.Lock()
        self._value = value.copy()
        self._version = 0

    def get(self) -> tuple[int, np.ndarray]:
        with self._lock:
            return self._version, self._value.copy()

    def put(self, value: np.ndarray) -> int:
        with self._lock:
            self._value = value
            self._version += 1
            return self._version

    def apply(self, fn: Callable[[np.ndarray], np.ndarray]) -> int:
        """Atomic read-modify-write: install ``fn(current)`` as a new version.

        A bare ``get()`` -> ``put()`` pair is NOT atomic — with several
        writers, updates between the two calls are silently dropped.  The
        reducer's delta merge must go through here."""
        with self._lock:
            self._value = fn(self._value)
            self._version += 1
            return self._version


@dataclasses.dataclass
class WorkerStats:
    points: int = 0
    pushes: int = 0
    stale_reads: int = 0


def run_async_vq(data: np.ndarray, w0: np.ndarray, *, tau: int = 10,
                 duration_s: float = 2.0, eps0: float = 0.5,
                 decay: float = 1.0,
                 comm_delay_s: float | Callable[[int], float] = 0.0,
                 straggler: dict[int, float] | None = None):
    """Run M worker threads + 1 reducer for ``duration_s`` wall seconds.

    data: (M, n, d) per-worker streams; w0: (kappa, d) initial prototypes.
    ``comm_delay_s``: per-round communication latency (float or f(worker)).
    ``straggler``: {worker_id: compute-slowdown-multiplier}.

    Returns (w_final, per-worker WorkerStats, distortion_trace) where
    distortion_trace is [(t_seconds, distortion-of-shared-version), ...].
    """
    m, n, d = data.shape
    store = BlobStore(np.asarray(w0, np.float32))
    inbox: queue.Queue = queue.Queue()
    stop = threading.Event()
    stats = [WorkerStats() for _ in range(m)]
    global_step = [0]  # drives the shared Robbins-Monro schedule
    step_lock = threading.Lock()

    def eps_for() -> float:
        with step_lock:
            global_step[0] += 1
            t = global_step[0]
        return eps0 / (1.0 + decay * t)

    def delay_of(i: int) -> float:
        return comm_delay_s(i) if callable(comm_delay_s) else comm_delay_s

    def worker(i: int) -> None:
        rng = np.random.default_rng(i)
        version, w = store.get()
        delta = np.zeros_like(w)
        slow = (straggler or {}).get(i, 1.0)
        pos = 0
        while not stop.is_set():
            # --- tau local sequential VQ steps (eq. 1) -------------------
            for _ in range(tau):
                z = data[i, pos % n]
                pos += 1
                dist = np.sum((w - z) ** 2, axis=1)
                l = int(np.argmin(dist))
                step = eps_for() * (w[l] - z)
                w[l] -= step
                delta[l] += step
                stats[i].points += 1
                if slow > 1.0:
                    time.sleep(1e-5 * (slow - 1.0))
            # --- push delta, pull shared (no barrier; eq. 9) -------------
            if delay_of(i):
                time.sleep(delay_of(i))
            inbox.put((i, delta.copy()))
            stats[i].pushes += 1
            new_version, w_srd = store.get()
            if new_version == version:
                stats[i].stale_reads += 1
            version = new_version
            # replay local displacement since push on top of the download —
            # here the push is synchronous-with-pull so the replay is empty;
            # the reducer's merge of OUR delta may not be in w_srd yet,
            # which is exactly the paper's stale-read tolerance.
            w = w_srd
            delta = np.zeros_like(w)

    def reducer() -> None:
        while not stop.is_set() or not inbox.empty():
            try:
                _, delta = inbox.get(timeout=0.01)
            except queue.Empty:
                continue
            # eq. (9) 4th line, one message at a time; atomic so a second
            # reducer (or any future writer) cannot drop merges
            store.apply(lambda w_srd: w_srd - delta)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(m)]
    red = threading.Thread(target=reducer)
    eval_data = data.reshape(-1, d)[: min(4096, m * n)]
    # warm the distortion jit and record the t=0 baseline BEFORE any work
    d0 = float(kref.distortion_ref(eval_data, w0))
    trace = [(0.0, d0)]
    t0 = time.perf_counter()
    red.start()
    for th in threads:
        th.start()
    while time.perf_counter() - t0 < duration_s:
        time.sleep(duration_s / 20)
        _, w_now = store.get()
        trace.append((time.perf_counter() - t0,
                      float(kref.distortion_ref(eval_data, w_now))))
    stop.set()
    for th in threads:
        th.join()
    red.join()
    _, w_final = store.get()
    return w_final, stats, trace
