"""Asynchronous delta-merge scheme with stochastic delays — paper Section 4, eq. (9).

Faithful discrete-event simulation of the cloud model:

  * every wall tick, every worker processes one data point (workers are
    concurrent — one tick == one point per worker);
  * each worker runs communication "rounds" back-to-back: as soon as its
    previous upload+download completes it starts the next one.  A round takes
    ``tau + G`` ticks where ``G ~ Geometric(p_delay)`` models the random
    communication cost (the paper's geometric-delay model);
  * when worker ``i``'s round completes at tick ``t`` (``t == tau^i(t)``):
      - the delta it UPLOADED during that round — the displacement over its
        *previous* inter-completion window — lands on the reducer:
        ``w_srd <- w_srd - Delta^i_{prev window}``          (4th line of eq. 9)
      - the shared version it DOWNLOADED during the round — the reducer state
        at its previous completion ``tau^i(t-1)`` — replaces its local
        version, with its since-then local displacement replayed on top:
        ``w^i(t+1) = w_srd(tau^i(t-1)) - Delta^i_{tau^i(t-1) -> t}``  (3rd line)
  * there is no synchronization barrier anywhere; the reducer ("dedicated
    unit") merges whatever arrives whenever it arrives.

The whole simulation is a single ``lax.scan`` over wall ticks with masked
per-worker updates, so it jits and runs fast for the paper's scales
(M <= 32, n ~ 1e4).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vq


class AsyncResult(NamedTuple):
    w_shared: jax.Array      # (kappa, d) final reducer state
    wall_ticks: jax.Array    # (n_evals,)
    distortion: jax.Array    # (n_evals,) eq. (2) of w_srd over wall time


class _SimState(NamedTuple):
    w_workers: jax.Array     # (M, kappa, d) local versions w^i(t)
    w_shared: jax.Array      # (kappa, d)    reducer state w_srd(t)
    snapshot: jax.Array      # (M, kappa, d) shared version downloaded at last completion
    delta_cur: jax.Array     # (M, kappa, d) Delta^i since last completion
    delta_inflight: jax.Array  # (M, kappa, d) Delta^i uploaded, lands at next completion
    next_done: jax.Array     # (M,) int32 tick when current round completes
    t: jax.Array             # scalar int32 wall tick


def _round_lengths(key: jax.Array, shape, *, tau: int, p_delay: float) -> jax.Array:
    """tau + Geometric(p_delay) extra ticks (0 extra when p_delay -> 1)."""
    u = jax.random.uniform(key, shape, minval=1e-7, maxval=1.0)
    geom = jnp.floor(jnp.log(u) / jnp.log1p(-p_delay)).astype(jnp.int32)
    return tau + jnp.maximum(geom, 0)


@functools.partial(
    jax.jit, static_argnames=("tau", "p_delay", "eps0", "decay", "eval_every")
)
def scheme_async(w0: jax.Array, data: jax.Array, eval_data: jax.Array,
                 key: jax.Array, *, tau: int, p_delay: float = 0.5,
                 eps0: float = 0.5, decay: float = 1.0,
                 eval_every: int = 10,
                 lengths: jax.Array | None = None) -> AsyncResult:
    """Run eq. (9) for ``n`` wall ticks (n = data.shape[1]).

    data: (M, n, d); eval_data: (M, n_eval, d); key: PRNG for round delays.
    ``p_delay`` is the geometric parameter: mean extra delay (1-p)/p ticks.
    ``lengths``: optional pre-sampled (M, n // tau + 2) per-round durations
    (a ``repro.engine.network.NetworkModel`` draw); overrides ``p_delay`` so
    the sim oracle and the mesh engine can replay identical delays.
    """
    m, n, _ = data.shape
    kappa = w0.shape[0]

    # Pre-sample enough round lengths: each round is >= tau ticks, so at most
    # ceil(n / tau) + 1 rounds per worker.
    max_rounds = n // tau + 2
    if lengths is None:
        lengths = _round_lengths(key, (m, max_rounds), tau=tau,
                                 p_delay=p_delay)
    assert lengths.shape == (m, max_rounds), (
        f"lengths must be (M, n // tau + 2) = {(m, max_rounds)}, "
        f"got {lengths.shape}")
    done_at = jnp.cumsum(lengths, axis=1)  # (M, max_rounds) completion ticks
    round_idx0 = jnp.zeros((m,), jnp.int32)

    def tick(carry, z_t):
        state, round_idx = carry
        t = state.t
        eps = vq.default_steps(t + 1, eps0=eps0, decay=decay)

        # --- local VQ step on every worker (1st line of eq. 9) -------------
        step = eps * jax.vmap(vq.H)(z_t, state.w_workers)  # (M, kappa, d)
        w_temp = state.w_workers - step
        delta_cur = state.delta_cur + step

        # --- completions: workers whose round finishes at this tick --------
        done = state.next_done == t  # (M,) bool
        donef = done.astype(w0.dtype)[:, None, None]

        # uploaded (in-flight) deltas land on the reducer  (4th line of eq. 9)
        w_shared = state.w_shared - jnp.sum(donef * state.delta_inflight, axis=0)

        # completed workers: adopt downloaded snapshot + replay local delta
        # (3rd line of eq. 9); others keep w_temp (2nd line).
        w_adopt = state.snapshot - delta_cur
        w_workers = jnp.where(donef > 0, w_adopt, w_temp)

        # completed workers start a new round: snapshot the (just-merged)
        # shared version, move delta_cur into the upload slot, reset.
        snapshot = jnp.where(donef > 0, w_shared[None], state.snapshot)
        delta_inflight = jnp.where(donef > 0, delta_cur, state.delta_inflight)
        delta_cur = jnp.where(donef > 0, jnp.zeros_like(delta_cur), delta_cur)
        round_idx = round_idx + done.astype(jnp.int32)
        next_done = jnp.where(
            done, jnp.take_along_axis(done_at, round_idx[:, None], axis=1)[:, 0],
            state.next_done,
        )

        new = _SimState(w_workers, w_shared, snapshot, delta_cur,
                        delta_inflight, next_done, t + 1)
        return (new, round_idx), w_shared

    init = _SimState(
        w_workers=jnp.broadcast_to(w0, (m, kappa, w0.shape[1])),
        w_shared=w0,
        snapshot=jnp.broadcast_to(w0, (m, kappa, w0.shape[1])),
        delta_cur=jnp.zeros((m, kappa, w0.shape[1]), w0.dtype),
        delta_inflight=jnp.zeros((m, kappa, w0.shape[1]), w0.dtype),
        next_done=done_at[:, 0],
        t=jnp.asarray(0, jnp.int32),
    )
    (final, _), shared_traj = jax.lax.scan(
        tick, (init, round_idx0), data.transpose(1, 0, 2)
    )

    # evaluate the shared version every ``eval_every`` ticks
    eval_ticks = jnp.arange(eval_every - 1, n, eval_every)
    curve = jax.vmap(lambda w: vq.distortion_multi(eval_data, w))(
        shared_traj[eval_ticks]
    )
    return AsyncResult(w_shared=final.w_shared, wall_ticks=eval_ticks + 1,
                       distortion=curve)
