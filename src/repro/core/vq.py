"""Sequential stochastic Vector Quantization (online k-means) — paper eqs. (1), (2), (4), (5).

The paper's objects, verbatim in JAX:

  * ``H(z, w)``  (eq. 4): the one-prototype displacement direction,
    ``H(z,w)_l = (w_l - z) * 1{l = argmin_i ||z - w_i||^2}``.
  * the sequential VQ iteration (eq. 1): ``w <- w - eps_{t+1} H(z_{t+1}, w)``.
  * the distortion criterion (eq. 2):
    ``C_{n,M}(w) = 1/(nM) sum_{i,t} min_l ||z_t^i - w_l||^2``.

Everything is pure-functional and jit/scan/vmap friendly.  ``H`` is written
with the matmul expansion ``||z-w||^2 = ||z||^2 - 2 z.w + ||w||^2`` so the
hot path hits the MXU on TPU; the Pallas kernel in ``repro.kernels`` is the
blocked version of the same computation for large (batch, kappa, d).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class VQState(NamedTuple):
    """Carried state of a sequential VQ run."""

    w: jax.Array  # (kappa, d) prototypes
    t: jax.Array  # scalar int32 step counter (drives the step schedule)


def squared_distances(z: jax.Array, w: jax.Array) -> jax.Array:
    """Pairwise squared distances ``(batch, kappa)`` via the matmul expansion.

    z: (batch, d), w: (kappa, d).  Uses ||z||^2 - 2 z.w^T + ||w||^2 which is
    MXU-friendly (one (batch,d)x(d,kappa) matmul) rather than the O(batch *
    kappa * d) broadcast-subtract which is VPU-bound and 3x the HBM traffic.
    """
    z2 = jnp.sum(z * z, axis=-1, keepdims=True)  # (batch, 1)
    w2 = jnp.sum(w * w, axis=-1)  # (kappa,)
    cross = z @ w.T  # (batch, kappa)
    return z2 - 2.0 * cross + w2[None, :]


def nearest(z: jax.Array, w: jax.Array) -> jax.Array:
    """argmin_l ||z - w_l||^2, per row of ``z``.  Shape (batch,)."""
    return jnp.argmin(squared_distances(z, w), axis=-1)


def H(z: jax.Array, w: jax.Array) -> jax.Array:
    """Paper eq. (4) for a single sample.

    z: (d,), w: (kappa, d) -> (kappa, d), nonzero only on the winning row.
    """
    l = nearest(z[None, :], w)[0]
    onehot = jax.nn.one_hot(l, w.shape[0], dtype=w.dtype)  # (kappa,)
    return onehot[:, None] * (w - z[None, :])


def H_batch(z: jax.Array, w: jax.Array) -> jax.Array:
    """Sum of H(z_b, w) over a minibatch — the mini-batch displacement.

    z: (batch, d), w: (kappa, d) -> (kappa, d).  Equivalent to
    ``sum_b H(z[b], w)`` but computed as a one-hot matmul (MXU-friendly).
    """
    l = nearest(z, w)  # (batch,)
    onehot = jax.nn.one_hot(l, w.shape[0], dtype=w.dtype)  # (batch, kappa)
    counts = jnp.sum(onehot, axis=0)  # (kappa,)
    zsum = onehot.T @ z  # (kappa, d)
    return counts[:, None] * w - zsum


def distortion(z: jax.Array, w: jax.Array) -> jax.Array:
    """Paper eq. (2) for one worker's data: mean_t min_l ||z_t - w_l||^2."""
    return jnp.mean(jnp.min(squared_distances(z, w), axis=-1))


def distortion_multi(z: jax.Array, w: jax.Array) -> jax.Array:
    """Eq. (2) over M workers: z is (M, n, d); normalizes by n*M."""
    return jnp.mean(jax.vmap(lambda zi: distortion(zi, w))(z))


def default_steps(t: jax.Array, *, eps0: float = 0.5, decay: float = 1.0) -> jax.Array:
    """The classical Robbins-Monro schedule eps_t = eps0 / (1 + decay * t).

    The paper assumes "a satisfactory sequential implementation", i.e. a
    step sequence adapted to the dataset; this is the standard choice used
    in [1] (Patra, JMLR 2011) and keeps sum eps_t = inf, sum eps_t^2 < inf.
    """
    return eps0 / (1.0 + decay * t.astype(jnp.float32))


def vq_step(state: VQState, z: jax.Array, *, eps0: float = 0.5, decay: float = 1.0) -> VQState:
    """One sequential VQ iteration — paper eq. (1)."""
    eps = default_steps(state.t + 1, eps0=eps0, decay=decay)
    w = state.w - eps * H(z, state.w)
    return VQState(w=w, t=state.t + 1)


@functools.partial(jax.jit, static_argnames=("eps0", "decay"))
def vq_run(w0: jax.Array, data: jax.Array, *, t0: int | jax.Array = 0,
           eps0: float = 0.5, decay: float = 1.0) -> VQState:
    """Run sequential VQ over ``data`` (n, d) in order — eq. (5) unrolled by scan."""

    def body(state: VQState, z: jax.Array) -> tuple[VQState, None]:
        return vq_step(state, z, eps0=eps0, decay=decay), None

    init = VQState(w=w0, t=jnp.asarray(t0, jnp.int32))
    final, _ = jax.lax.scan(body, init, data)
    return final


def window_displacement(w0: jax.Array, data: jax.Array, t0: jax.Array,
                        *, eps0: float = 0.5, decay: float = 1.0) -> tuple[jax.Array, jax.Array]:
    """Delta_{t0 -> t0+tau}: the accumulated displacement of tau sequential VQ
    steps starting from prototypes ``w0`` at global step ``t0`` (paper eq. 7).

    Returns (delta, w_final) with ``w_final = w0 - delta``.
    """
    final = vq_run(w0, data, t0=t0, eps0=eps0, decay=decay)
    return w0 - final.w, final.w
