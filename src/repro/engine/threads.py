"""``ThreadExecutor`` — the real-thread CloudDALVQ runtime as a backend.

Wraps ``core.async_runtime.run_async_vq`` (worker threads + dedicated
reducer + versioned blob store, no barrier anywhere) behind the Executor
API.  Only the asynchronous delta scheme exists here — threads with a
barrier would just be a slow simulation, so 'average' / 'delta' raise.

Because real threads have no tick clock, ``wall_ticks`` in the returned
``SchemeResult`` holds wall-clock SECONDS (float) instead of ticks.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import async_runtime
from repro.core.schemes import SchemeResult
from repro.engine import api
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer


class ThreadExecutor:
    """Real worker threads + reducer thread (async_delta only)."""

    name = "thread"

    def __init__(self, *, duration_s: float = 2.0, comm_delay_s: float = 0.0,
                 straggler: dict[int, float] | None = None,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.duration_s = duration_s
        self.comm_delay_s = comm_delay_s
        self.straggler = straggler
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics

    def run(self, scheme, w0, data, eval_data, *, tau, eps0=0.5, decay=1.0,
            key=None) -> SchemeResult:
        api.validate_scheme(scheme)
        if scheme != "async_delta":
            raise ValueError(
                f"ThreadExecutor only runs 'async_delta' (the thread pool "
                f"has no barrier to express {scheme!r}); use SimExecutor or "
                f"MeshExecutor for the synchronous schemes")
        del eval_data, key  # the runtime evaluates on its own data slice
        t_wall = time.perf_counter()
        with self.tracer.span("run", scheme=scheme, executor=self.name,
                              m=data.shape[0]):
            w, stats, trace = async_runtime.run_async_vq(
                np.asarray(data, np.float32), np.asarray(w0, np.float32),
                tau=tau, duration_s=self.duration_s, eps0=eps0, decay=decay,
                comm_delay_s=self.comm_delay_s, straggler=self.straggler)
        seconds = jnp.asarray([t for t, _ in trace], jnp.float32)
        curve = jnp.asarray([c for _, c in trace], jnp.float32)
        self.last_stats = stats
        wall_s = time.perf_counter() - t_wall
        if self.metrics is not None:
            mt = self.metrics
            mt.histogram("run_wall_s", executor=self.name,
                         scheme=scheme).observe(wall_s)
            h = mt.histogram("distortion", scheme=scheme)
            for _, c in trace:
                h.observe(float(c))
            mt.counter("async_rounds_total", scheme=scheme).inc(
                sum(s.pushes for s in stats))
            mt.counter("stale_reads_total", scheme=scheme).inc(
                sum(s.stale_reads for s in stats))
        if self.tracer.enabled:
            # the thread runtime's trace is (seconds, distortion) pairs —
            # real wall samples, so they land on the wall timeline in us
            for t, c in trace:
                self.tracer.counter("distortion", float(c), ts_us=t * 1e6,
                                    process=self.tracer.WALL_PROCESS)
        return SchemeResult(w_shared=jnp.asarray(w), wall_ticks=seconds,
                            distortion=curve)
