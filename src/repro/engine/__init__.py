"""Device-mesh execution engine for the paper's parallelization schemes.

One ``Executor`` API (``engine.api``), three interchangeable backends:

  * ``SimExecutor``    — single-device jit/vmap oracles (core.schemes);
  * ``MeshExecutor``   — one worker per JAX device, shard_map + collectives;
  * ``ThreadExecutor`` — real threads + blob store (core.async_runtime);
  * ``ElasticMeshExecutor`` — MeshExecutor plus a ``ResizeSchedule``: the
    worker set grows/shrinks between merge windows (checkpoint -> remesh ->
    reshard -> resume) without restarting the run (engine.elastic).

plus the pluggable pieces: ``NetworkModel`` (engine.network — instant /
fixed-latency / geometric-delay communication cost), ``MergeStrategy``
(engine.merge — the reducing phases as pytree ops, shared with the LM
window step in training.steps) and ``Transport`` (repro.comm — how a
merge's bytes actually move: dense XLA, Pallas ring, or top-k sparse,
with per-call wire-byte accounting).
"""

from repro.comm import HierarchicalTransport, Transport, get_transport
from repro.engine.api import SCHEMES, Executor, get_executor
from repro.engine.chaos import ChaosEvent, ChaosNetwork, ChaosSchedule
from repro.engine.elastic import (ElasticMeshExecutor, ResizeEvent,
                                  ResizeSchedule)
from repro.engine.merge import (AsyncDeltaMerge, AverageMerge, DeltaMerge,
                                DynamicMerge, MergeStrategy, QuorumMerge,
                                SparseDeltaMerge, get_merge)
from repro.engine.mesh import MeshExecutor, make_worker_mesh
from repro.engine.network import (FixedLatencyNetwork, GeometricDelayNetwork,
                                  InstantNetwork, NetworkModel,
                                  Tier1BudgetController, get_network)
from repro.engine.sim import SimExecutor
from repro.engine.threads import ThreadExecutor
from repro.topology import Topology

__all__ = [
    "SCHEMES", "Executor", "get_executor",
    "Transport", "get_transport", "HierarchicalTransport", "Topology",
    "MergeStrategy", "AverageMerge", "DeltaMerge", "AsyncDeltaMerge",
    "SparseDeltaMerge", "QuorumMerge", "DynamicMerge", "get_merge",
    "NetworkModel", "InstantNetwork", "FixedLatencyNetwork",
    "GeometricDelayNetwork", "Tier1BudgetController", "get_network",
    "ChaosEvent", "ChaosSchedule", "ChaosNetwork",
    "SimExecutor", "MeshExecutor", "ThreadExecutor", "make_worker_mesh",
    "ElasticMeshExecutor", "ResizeEvent", "ResizeSchedule",
]
