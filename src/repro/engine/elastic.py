"""``ElasticMeshExecutor`` — grow/shrink the worker set between merge windows.

The PR-1 ``MeshExecutor`` is static: M worker streams, M devices, one mesh
for the whole run.  A cloud deployment of the paper's schemes (CloudDALVQ:
up to 32 Azure VMs) sees workers *appear and disappear*; Patra's convergence
analysis of the displacement merge (arXiv:1012.5150) shows eq. (8) stays
sound under stale and late contributions, so a worker-set change can be a
**resharding event instead of a restart**:

    window k merge complete
        │
        ▼
    ResizeSchedule says M -> M' at window k
        │
        ├─ 1. checkpoint {w_srd, t, cursor} (Checkpointer, unsharded leaves)
        ├─ 2. late deltas: departing workers' in-flight windows merged via
        │     eq. (8) on the stale window, scaled by ``staleness_scale``
        ├─ 3. plan_remesh(survivors) -> build the M' worker mesh
        └─ 4. reshard the global sample pool into M' streams
        │
        ▼
    window k+1 runs on the new mesh (step schedule eps_t continues at t)

Wall-clock semantics: a window costs ``network.window_ticks(tau)`` ticks as
in the static executor; each resize event adds ``resize_cost_ticks`` (the
checkpoint + remesh + reshard pause, 0 by default — ``benchmarks/run.py
--suite elastic`` measures the real seconds).

Sample-budget semantics: the executor consumes one global pool of
``M0 * n`` points (the concatenation of the input streams, time-major), so
an elastic run and a fixed-M oracle given the same ``data`` see the same
total sample budget — the acceptance test pins their final distortion
within rtol 1e-2.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.core import vq
from repro.core.schemes import SchemeResult
from repro.distributed import elastic as elastic_lib
from repro.engine import api
from repro.engine.mesh import MeshExecutor, make_worker_mesh
from repro.engine.network import InstantNetwork, NetworkModel
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.topology import Topology

ELASTIC_SCHEMES = ("average", "delta")


@dataclasses.dataclass(frozen=True)
class ResizeEvent:
    """At the end of global window ``window``, the worker set becomes
    ``new_m`` (clamped to the available devices by ``plan_remesh``)."""

    window: int
    new_m: int


class ResizeSchedule:
    """An ordered list of ``ResizeEvent``s, e.g. ``[(20, 4), (40, 8)]``."""

    def __init__(self, events):
        evs = [e if isinstance(e, ResizeEvent) else ResizeEvent(*e)
               for e in events]
        for e in evs:
            if e.window < 1:
                raise ValueError(
                    f"resize window must be >= 1 (after at least one merge), "
                    f"got {e.window}")
            if e.new_m < 1:
                raise ValueError(f"resize target M must be >= 1, "
                                 f"got {e.new_m}")
        windows = [e.window for e in evs]
        if sorted(windows) != windows or len(set(windows)) != len(windows):
            raise ValueError(
                f"resize windows must be strictly increasing, got {windows}")
        self.events: tuple[ResizeEvent, ...] = tuple(evs)

    @classmethod
    def parse(cls, spec: str) -> "ResizeSchedule":
        """Parse the CLI form ``"WINDOW:M,WINDOW:M,..."`` (e.g. "20:4,40:8")."""
        events = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                win, m = part.split(":")
                events.append(ResizeEvent(int(win), int(m)))
            except ValueError as e:
                raise ValueError(
                    f"bad resize spec {part!r} (want 'WINDOW:M'): {e}") from None
        if not events:
            raise ValueError(f"empty resize spec {spec!r}")
        return cls(events)

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)


@dataclasses.dataclass
class ResizeStats:
    """What one resize event did (filled in by the executor at run time)."""

    window: int
    old_m: int
    new_m: int
    # from the shared remesh planner (distributed.elastic.plan_remesh).  The
    # VQ engine's worker mesh is 1-D (model axis = 1), so this is trivially
    # True today; it becomes informative once the elastic executor carries a
    # real TP axis (the LM side of plan_remesh already does).
    tp_preserved: bool
    late_points: int
    checkpoint_step: int | None
    wall_s: float
    # late_policy='merge' was requested but the remaining pool was too small
    # to give the departing workers their in-flight window — the event
    # degraded to 'drop' (the sample budget wins over the staleness model)
    late_skipped: bool = False
    # what fired this resize: 'schedule' (a planned ResizeEvent) or
    # 'chaos_kill' (an injected worker death treated as an unscheduled
    # shrink at the next window barrier)
    cause: str = "schedule"


class ElasticMeshExecutor:
    """``MeshExecutor`` with a ``ResizeSchedule``: the worker set grows and
    shrinks between merge windows without restarting the run.

    Parameters
    ----------
    schedule:         ``ResizeSchedule`` (or anything its ctor accepts).
    network:          ``NetworkModel`` for wall-tick accounting (instant
                      default, matching the paper's simulated architecture).
    checkpointer:     optional ``repro.checkpoint.Checkpointer``; when given,
                      every resize event first checkpoints
                      ``{w_srd, t, cursor, window, m}`` (blocking — the save
                      is part of the measured resize cost), and
                      ``resume=True`` restores the latest step and skips the
                      already-consumed prefix (the elastic restore path:
                      leaves are stored unsharded, so the new mesh size is
                      irrelevant to the read).
    late_policy:      'merge' (default) integrates departing workers'
                      in-flight window deltas with ``merge_late_delta`` —
                      eq. (8) on the stale window, damped by
                      ``staleness_scale(1, gamma)``; 'drop' discards them
                      (the restart-style baseline).
    resize_cost_ticks: wall ticks charged per resize event on the curve axis.
    topology:         optional ``repro.topology.Topology``.  A hierarchical
                      topology turns every resize into MULTI-HOST
                      elasticity: targets are clamped to whole host groups
                      (``workers_per_host`` stays fixed, the HOST tier
                      grows/shrinks), each segment runs on its own
                      ``(hosts, workers)`` mesh, and the shared transport
                      (typically ``HierarchicalTransport``) keeps per-tier
                      accounting across the whole run.
    """

    name = "elastic"

    def __init__(self, schedule, network: NetworkModel | None = None,
                 axis: str = "workers", *, use_pallas: bool = True,
                 fused: bool = True,
                 transport: comm.Transport | str | None = None,
                 topology: Topology | None = None,
                 checkpointer=None, resume: bool = False,
                 late_policy: str = "merge", staleness_gamma: float = 0.5,
                 resize_cost_ticks: int = 0, on_window=None,
                 publish_every: int = 1, chaos=None,
                 checkpoint_every: int | None = None,
                 merge: str | None = None, quorum_frac: float = 0.6,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None,
                 profiler=None):
        if not isinstance(schedule, ResizeSchedule):
            schedule = ResizeSchedule(schedule)
        if late_policy not in ("merge", "drop"):
            raise ValueError(
                f"late_policy must be 'merge' or 'drop', got {late_policy!r}")
        if resume and checkpointer is None:
            raise ValueError(
                "resume=True needs a checkpointer to restore from — "
                "silently restarting from scratch is not a resume")
        if publish_every < 1:
            raise ValueError(f"publish_every must be >= 1, "
                             f"got {publish_every}")
        if checkpoint_every is not None:
            if checkpoint_every < 1:
                raise ValueError(f"checkpoint_every must be >= 1, "
                                 f"got {checkpoint_every}")
            if checkpointer is None:
                raise ValueError(
                    "checkpoint_every needs a checkpointer to save to")
        if merge not in (None, "quorum"):
            raise ValueError(
                f"merge override must be None (scheme default) or 'quorum', "
                f"got {merge!r}")
        self.schedule = schedule
        self.network = network or InstantNetwork()
        self.topology = topology
        if topology is not None:
            axis = topology.worker_axis
        self.axis = axis
        self.use_pallas = use_pallas
        self.fused = fused
        # ONE transport shared by every per-M segment executor, so the whole
        # elastic run streams into a single CommLog (segments + late deltas)
        self.transport = comm.get_transport(
            transport if transport is not None else "xla")
        self.last_comm: dict | None = None
        self.checkpointer = checkpointer
        self.resume = resume
        self.late_policy = late_policy
        self.staleness_gamma = staleness_gamma
        self.resize_cost_ticks = resize_cost_ticks
        # publication hook (see MeshExecutor.on_window): fires with the
        # GLOBAL window index — continuous across resize events — so a
        # CodebookStore sees one monotone stream over the whole elastic run
        self.on_window = on_window
        self.publish_every = publish_every
        # chaos schedule: its KILL events become unscheduled shrink-by-one
        # resizes at the next window barrier (the dead worker's in-flight
        # delta folds in via the late-delta path, exactly like a scheduled
        # departure); its slow/partition events ride the quorum merge's
        # late matrix through a ChaosNetwork passed as ``network``
        self.chaos = chaos
        # preemption-safe checkpointing: every ``checkpoint_every`` global
        # windows the publish hook saves the full elastic state, so a
        # killed process resumes mid-segment instead of from the last
        # resize event (serve-while-train restarts without failing queries)
        self.checkpoint_every = checkpoint_every
        self._last_ckpt_window = -1
        # merge override forwarded to every per-M segment executor
        self.merge = merge
        self.quorum_frac = quorum_frac
        # one tracer/registry shared by every per-M segment executor, so the
        # whole elastic run lands on one timeline (segments, resizes, comm)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        if metrics is not None:
            self.transport.log.attach_metrics(metrics)
        # one profiler shared by every per-M segment executor: each segment
        # reports its own (m, n_windows) shapes via note_segment, and the
        # elastic run's total wall is attributed across them window-weighted
        self.profiler = profiler
        # one MeshExecutor per worker count — each holds its plan_remesh-built
        # mesh and its own compiled-program cache
        self._mesh_ex: dict[int, MeshExecutor] = {}
        self.resize_events: list[ResizeStats] = []

    # -- internals ----------------------------------------------------------

    @property
    def _hierarchical(self) -> bool:
        return self.topology is not None and not self.topology.is_flat

    def _executor_for(self, m: int, prev_m: int) -> MeshExecutor:
        """(Re)build the device mesh for ``m`` workers via ``plan_remesh``.

        On a hierarchical topology the worker count maps to WHOLE host
        groups (``workers_per_host`` fixed, the host tier resized), so the
        per-M executor carries its own ``hosts x workers_per_host``
        topology — a host-group departure/arrival is a resharding event on
        the host axis, not a restart."""
        if m not in self._mesh_ex:
            if self._hierarchical:
                wph = self.topology.workers_per_host
                topo = Topology.from_spec(
                    m, hosts=max(1, m // wph),
                    host_axis=self.topology.host_axis,
                    worker_axis=self.topology.worker_axis)
                self._mesh_ex[m] = MeshExecutor(
                    topology=topo, network=self.network,
                    transport=self.transport, use_pallas=self.use_pallas,
                    fused=self.fused,
                    merge=self.merge, quorum_frac=self.quorum_frac,
                    staleness_gamma=self.staleness_gamma,
                    tracer=self.tracer, metrics=self.metrics,
                    profiler=self.profiler)
            else:
                plan = elastic_lib.plan_remesh(m, prev_data=prev_m,
                                               prev_model=1)
                mesh = make_worker_mesh(plan.data * plan.model, self.axis)
                self._mesh_ex[m] = MeshExecutor(
                    mesh=mesh, axis=self.axis, network=self.network,
                    transport=self.transport, use_pallas=self.use_pallas,
                    fused=self.fused, merge=self.merge, quorum_frac=self.quorum_frac,
                    staleness_gamma=self.staleness_gamma,
                    tracer=self.tracer, metrics=self.metrics,
                    profiler=self.profiler)
        return self._mesh_ex[m]

    def _segment_hook(self, window_idx: int, t0: int, cursor: int,
                      cur_m: int, tau: int, wt: int, tick_offset: int):
        """Build one segment's ``on_window`` adapter: forward the publish
        hook with the GLOBAL window index, and — when ``checkpoint_every``
        is set — save the full elastic state every N global windows, so a
        preempted process resumes mid-segment from the last periodic save
        instead of replaying everything since the last resize event."""
        periodic = (self.checkpointer is not None
                    and self.checkpoint_every is not None)
        if self.on_window is None and not periodic:
            return None

        def hook(wi, w, _off=window_idx, _t=t0, _cur=cursor, _m=cur_m,
                 _tick=tick_offset):
            gw = _off + wi
            if self.on_window is not None:
                self.on_window(gw, w)
            if (periodic and gw % self.checkpoint_every == 0
                    and gw > self._last_ckpt_window):
                with self.tracer.span("checkpoint", step=gw, periodic=True):
                    state = {"w_srd": jnp.asarray(jax.device_get(w)),
                             "t": np.asarray(_t + wi * tau, np.int64),
                             "cursor": np.asarray(_cur + wi * _m * tau,
                                                  np.int64),
                             "window": np.asarray(gw, np.int64),
                             "m": np.asarray(_m, np.int64),
                             "tick_offset": np.asarray(_tick + wi * wt,
                                                       np.int64)}
                    self.checkpointer.save(gw, state)
                self._last_ckpt_window = gw
                if self.metrics is not None:
                    self.metrics.counter("periodic_checkpoints").inc()

        return hook

    @staticmethod
    def _eval_streams(eval_pool: jax.Array, m: int) -> jax.Array:
        """Split the shared eval pool into m per-worker shards (the in-mesh
        curve pmean then evaluates (almost) the whole pool at every M)."""
        n_ev = eval_pool.shape[0] // m
        if n_ev == 0:
            raise ValueError(
                f"eval pool of {eval_pool.shape[0]} points cannot feed "
                f"M={m} workers")
        d = eval_pool.shape[-1]
        return eval_pool[: n_ev * m].reshape(m, n_ev, d)

    def _clamp_m(self, requested: int) -> tuple[int, "elastic_lib.RemeshPlan"]:
        n_dev = len(jax.devices())
        if self._hierarchical:
            # multi-host elasticity resizes WHOLE host groups: round the
            # target down to a multiple of workers_per_host (at least one
            # group), then clamp to the available devices
            wph = self.topology.workers_per_host
            m = max(wph, min(requested, n_dev) // wph * wph)
            if m > n_dev:
                raise ValueError(
                    f"one host group needs {wph} devices, have {n_dev} "
                    f"(hint: --xla_force_host_platform_device_count)")
            plan = elastic_lib.plan_remesh(m, prev_data=requested,
                                           prev_model=1)
            return m, plan
        plan = elastic_lib.plan_remesh(min(requested, n_dev),
                                       prev_data=requested, prev_model=1)
        return plan.data * plan.model, plan

    # -- public API ---------------------------------------------------------

    def run(self, scheme: str, w0: jax.Array, data: jax.Array,
            eval_data: jax.Array, *, tau: int, eps0: float = 0.5,
            decay: float = 1.0, key: jax.Array | None = None) -> SchemeResult:
        del key  # sync schemes are deterministic; kept for Executor protocol
        t_wall = time.perf_counter()
        with self.tracer.span("run", scheme=scheme, executor=self.name,
                              m=data.shape[0] if data.ndim == 3 else None):
            res = self._run(scheme, w0, data, eval_data, tau=tau, eps0=eps0,
                            decay=decay)
        wall_s = time.perf_counter() - t_wall
        if self.metrics is not None:
            self.metrics.histogram("run_wall_s", executor=self.name,
                                   scheme=scheme).observe(wall_s)
        if self.profiler is not None:
            # segments were noted by the per-M executors' _run_sync calls;
            # attribute the whole elastic run's wall across them
            self.profiler.finish_run(wall_s)
        return res

    def _run(self, scheme: str, w0: jax.Array, data: jax.Array,
             eval_data: jax.Array, *, tau: int, eps0: float,
             decay: float) -> SchemeResult:
        api.validate_scheme(scheme)
        if scheme not in ELASTIC_SCHEMES:
            raise ValueError(
                f"elastic execution supports {ELASTIC_SCHEMES}; "
                f"async_delta has no window barrier to resize at")
        if data.ndim != 3:
            raise ValueError(f"data must be (M, n, d), got {data.shape}")
        if eval_data.ndim != 3:
            raise ValueError(
                f"eval_data must be (M, n_eval, d), got {eval_data.shape}")
        m0, n, d = data.shape
        if n < tau:
            raise ValueError(
                f"need at least one tau={tau} window per worker, got n={n}")

        # one global pool, time-major: elastic and fixed-M runs on the same
        # `data` consume the same total sample budget
        pool = data.transpose(1, 0, 2).reshape(-1, d)
        eval_pool = eval_data.reshape(-1, d)
        total = pool.shape[0]
        wt = self.network.window_ticks(tau)

        cur_m, _ = self._clamp_m(m0)
        w_srd, t0, cursor, window_idx, tick_offset = w0, 0, 0, 0, 0
        self.resize_events = []
        comm_mark = self.transport.log.mark()

        resumed = False
        if self.resume:
            latest = self.checkpointer.latest_step()
            if latest is None:
                raise ValueError(
                    f"resume=True but no checkpoint found in "
                    f"{self.checkpointer.dir!r} — silently restarting from "
                    f"scratch is not a resume (drop resume for a fresh run)")
            st = self.checkpointer.restore(latest, self._state_target(w0))
            w_srd = st["w_srd"]
            t0 = int(st["t"])
            cursor = int(st["cursor"])
            window_idx = int(st["window"])
            cur_m, _ = self._clamp_m(int(st["m"]))
            tick_offset = int(st["tick_offset"])
            resumed = True

        # one merged boundary list: scheduled resizes plus injected worker
        # deaths, each an (window, cause, payload) barrier the segment loop
        # stops at.  A chaos kill's target M is resolved at fire time
        # (shrink the CURRENT worker set by one) — two kills at different
        # windows compose to M-2 without the schedule knowing M up front.
        boundaries: list[tuple[int, str, int]] = [
            (e.window, "schedule", e.new_m)
            for e in self.schedule if e.window > window_idx]
        if self.chaos is not None:
            boundaries += [
                (ce.window, "chaos_kill", -1)
                for ce in self.chaos.kill_events if ce.window > window_idx]
        boundaries.sort(key=lambda b: (b[0], b[1] != "schedule"))
        ei = 0
        curves: list[np.ndarray] = []
        ticks: list[np.ndarray] = []
        prev_m = cur_m
        self._last_ckpt_window = window_idx

        while True:
            target = boundaries[ei][0] if ei < len(boundaries) else None
            max_w = (total - cursor) // (cur_m * tau)
            want_w = max_w if target is None else (target - window_idx)
            seg_w = min(max_w, want_w)
            if seg_w > 0:
                seg_pts = cur_m * seg_w * tau
                with self.tracer.span("resplit", m=cur_m, windows=seg_w,
                                      points=seg_pts):
                    # reshard the global pool into cur_m time-major streams
                    seg = pool[cursor: cursor + seg_pts]
                    seg_data = seg.reshape(
                        seg_w * tau, cur_m, d).transpose(1, 0, 2)
                    seg_eval = self._eval_streams(eval_pool, cur_m)
                mex = self._executor_for(cur_m, prev_m)
                # assign unconditionally: the per-M executors are cached, so
                # a previous run's publish adapter must not survive into a
                # run with the hook cleared
                mex.on_window = self._segment_hook(
                    window_idx, t0, cursor, cur_m, tau, wt, tick_offset)
                mex.publish_every = self.publish_every
                res = mex.run_segment(
                    scheme, w_srd, seg_data, seg_eval, tau=tau, eps0=eps0,
                    decay=decay, t0=t0)
                w_srd = res.w_shared
                curves.append(np.asarray(res.distortion))
                ticks.append(tick_offset + np.asarray(res.wall_ticks))
                tick_offset += seg_w * wt
                cursor += seg_pts
                t0 += seg_w * tau
                window_idx += seg_w
            if target is None or window_idx < target:
                break  # no more events, or the pool ran dry before the next
            win, cause, payload = boundaries[ei]
            ei += 1
            prev_m = cur_m
            # an injected death shrinks the CURRENT worker set by one; the
            # dead worker's in-flight window folds in via the late-delta
            # path exactly like a scheduled departure
            new_m_req = payload if cause == "schedule" else max(1, cur_m - 1)
            w_srd, cur_m, cursor = self._do_resize(
                ResizeEvent(win, new_m_req), w_srd, cur_m, pool, cursor, t0,
                window_idx, tick_offset, tau=tau, eps0=eps0, decay=decay,
                cause=cause)
            tick_offset += self.resize_cost_ticks

        self.last_comm = comm.CommLog.summarize(
            self.transport.log.since(comm_mark))
        if not curves:
            if resumed:
                # the checkpoint captured an already-complete run: nothing
                # left to execute — report the restored state as the result
                c = vq.distortion(eval_pool, w_srd)
                return SchemeResult(
                    w_shared=w_srd,
                    wall_ticks=jnp.asarray([tick_offset], jnp.int32),
                    distortion=jnp.asarray([c]))
            raise ValueError(
                "elastic run produced no windows — pool exhausted before the "
                "first merge (reduce tau or provide more data)")
        return SchemeResult(
            w_shared=w_srd,
            wall_ticks=jnp.asarray(np.concatenate(ticks), jnp.int32),
            distortion=jnp.asarray(np.concatenate(curves)))

    # -- resize event -------------------------------------------------------

    @staticmethod
    def _state_target(w0: jax.Array) -> dict:
        return {"w_srd": jnp.zeros_like(w0),
                "t": np.zeros((), np.int64),
                "cursor": np.zeros((), np.int64),
                "window": np.zeros((), np.int64),
                "m": np.zeros((), np.int64),
                "tick_offset": np.zeros((), np.int64)}

    def _do_resize(self, ev: ResizeEvent, w_srd, cur_m: int, pool, cursor: int,
                   t0: int, window_idx: int, tick_offset: int, *, tau: int,
                   eps0: float, decay: float, cause: str = "schedule"):
        t_start = time.perf_counter()
        ckpt_step = None
        new_m, plan = self._clamp_m(ev.new_m)
        if cause == "chaos_kill" and self.metrics is not None:
            self.metrics.counter("chaos_kills").inc()
        with self.tracer.span("resize", window=window_idx, old_m=cur_m,
                              new_m=new_m, cause=cause):
            # un-commit the shared prototypes from the old mesh: the segment
            # output is sharded over the outgoing device set, and the next
            # shard_map runs on a different one
            w_srd = jnp.asarray(jax.device_get(w_srd))
            late_pts = 0
            late_skipped = False
            if new_m < cur_m and self.late_policy == "merge":
                # the departed workers were mid-flight on their next window
                # when the resize fired: their deltas arrive late, computed
                # against the stale shared version, and are summed in via
                # eq. (8) damped by one window of staleness
                n_dep = cur_m - new_m
                need = n_dep * tau
                if pool.shape[0] - cursor >= need:
                    with self.tracer.span("late_delta", n_dep=n_dep,
                                          points=need):
                        d = pool.shape[-1]
                        late = pool[cursor: cursor + need].reshape(
                            n_dep, tau, d)
                        cursor += need
                        late_pts = need
                        deltas, _ = jax.vmap(
                            lambda z: vq.window_displacement(
                                w_srd, z, jnp.asarray(t0, jnp.int32),
                                eps0=eps0, decay=decay))(late)
                        w_srd = elastic_lib.merge_late_delta(
                            w_srd, jnp.sum(deltas, axis=0), delay_windows=1,
                            gamma=self.staleness_gamma)
                        # the departing workers' deltas ride the same
                        # accounting stream as the collectives: each uploads
                        # one (kappa, d) f32 displacement to the survivors,
                        # host-side.  On a hierarchical topology the departed
                        # workers were whole host groups, so the upload
                        # crossed the inter-host tier.
                        self.transport.record_host_transfer(
                            logical_bytes=4 * int(w_srd.size),
                            wire_bytes=4 * int(w_srd.size),
                            participants=n_dep, axis=self.axis,
                            tag="late_delta",
                            tier=1 if self._hierarchical else None)
                    if self.metrics is not None:
                        # every departing worker's delta lands exactly one
                        # window stale (delay_windows=1 above)
                        self.metrics.counter("staleness_windows").inc(n_dep)
                        self.metrics.counter("late_delta_points").inc(need)
                else:
                    late_skipped = True  # pool too dry; recorded, not silent
                    if self.metrics is not None:
                        self.metrics.counter("late_delta_skipped").inc()
            # rebuild the mesh for the survivors (cached per M)
            with self.tracer.span("remesh", m=new_m):
                self._executor_for(new_m, cur_m)
                jax.block_until_ready(w_srd)
            if self.checkpointer is not None:
                # post-event state: a resume from here continues
                # bit-identically (late deltas already integrated, cursor
                # already advanced)
                with self.tracer.span("checkpoint", step=window_idx):
                    state = {"w_srd": w_srd,
                             "t": np.asarray(t0, np.int64),
                             "cursor": np.asarray(cursor, np.int64),
                             "window": np.asarray(window_idx, np.int64),
                             "m": np.asarray(new_m, np.int64),
                             "tick_offset": np.asarray(
                                 tick_offset + self.resize_cost_ticks,
                                 np.int64)}
                    self.checkpointer.save(window_idx, state)
                    ckpt_step = window_idx
        wall_s = time.perf_counter() - t_start
        if self.metrics is not None:
            self.metrics.counter("resize_events").inc()
            self.metrics.histogram("resize_wall_s").observe(wall_s)
        self.resize_events.append(ResizeStats(
            window=window_idx, old_m=cur_m, new_m=new_m,
            tp_preserved=plan.tp_preserved, late_points=late_pts,
            checkpoint_step=ckpt_step,
            wall_s=wall_s,
            late_skipped=late_skipped,
            cause=cause))
        return w_srd, new_m, cursor
