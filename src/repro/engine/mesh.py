"""``MeshExecutor`` — the paper's schemes on a REAL JAX device mesh.

One worker per device: worker streams are sharded over the worker axes
with shard_map, each device runs its own sequential-VQ inner loop, and the
reducing phases are collectives issued through the pluggable ``repro.comm``
transport layer.  The mesh comes from a ``repro.topology.Topology`` — a
flat topology (the default) is the classic 1-D ``workers`` axis; a
hierarchical one (``topology=Topology.from_spec(8, hosts=2)``) builds the
2-D ``(hosts, workers)`` grid, the scans shard and reduce over the joint
axes, and a ``HierarchicalTransport`` splits each merge into a dense
intra-host tier and a (typically sparse) inter-host tier with per-tier
wire accounting.  The schemes —

  * average  (eq. 3): cross-worker mean of the worker versions;
  * delta    (eq. 8): cross-worker sum of the worker displacements;
  * async    (eq. 9): a per-tick MASKED sum — only workers whose
    communication round (drawn from the pluggable ``NetworkModel``)
    completes at this tick contribute their in-flight delta, which is the
    barrier-free reducer of the paper's cloud architecture expressed as an
    SPMD collective (``Transport.masked_all_reduce``).

Which wire the merge rides is the executor's ``transport``: dense XLA
(default, the numerics oracle), the Pallas ring, or top-k sparse — and
every collective appends a ``CommRecord``, so ``last_comm`` reports the
bytes the run actually moved (records traced per compiled program are
replayed on compile-cache hits).

The per-worker inner loop routes the nearest-prototype search through the
fused Pallas kernel via ``kernels.ops.vq_delta_routed`` (interpret mode on
CPU): codebooks that fit the VMEM budget take the fused kernel, larger
ones the blocked-assign + segment-sum fallback — so the engine now honors
the same larger-than-VMEM routing as the serving lookup.

On CPU, force a mesh with ``--xla_force_host_platform_device_count=8`` (set
before jax initializes; see tests/conftest.py) — the SPMD program is then
bit-for-bit the one a real 8-chip mesh runs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import comm, compat
from repro.core import vq
from repro.core.schemes import SchemeResult
from repro.engine import api, merge as merge_lib
from repro.engine.network import GeometricDelayNetwork, NetworkModel
from repro.kernels import ops
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.topology import Topology
from repro.topology import make_worker_mesh  # noqa: F401 — re-export; the
# construction itself lives in repro.topology (the only module allowed to
# build meshes — CI-pinned)


def _validate_axis_names(mesh: Mesh, axes: tuple[str, ...]) -> None:
    if any(not name for name in mesh.axis_names):
        raise ValueError(
            f"mesh axis names must be non-empty, got {mesh.axis_names}")
    for axis in axes:
        if axis not in mesh.axis_names:
            raise ValueError(
                f"worker axis {axis!r} not in mesh axes {mesh.axis_names}")


def _validate_mesh(mesh: Mesh, axes: tuple[str, ...], m: int) -> None:
    _validate_axis_names(mesh, axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    have = 1
    for axis in axes:
        have *= sizes[axis]
    if have != m:
        raise ValueError(
            f"data has M={m} worker streams but mesh axes {axes!r} have "
            f"{have} devices — one worker per device is required")


def _local_window(w0: jax.Array, zwin: jax.Array, t0: jax.Array, *,
                  eps0: float, decay: float, use_pallas: bool,
                  vmem_budget: int | None = None, fused: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    """tau sequential VQ steps (eq. 1) on one device; returns (delta, w)."""
    tau = zwin.shape[0]
    kappa, d = w0.shape
    if (use_pallas and fused
            and ops.window_fits_vmem(kappa, d, tau,
                                     budget_bytes=vmem_budget)):
        # whole window in ONE Pallas dispatch: tau steps with the codebook
        # VMEM-resident, eliminating tau-1 per-step kernel launches — the
        # step schedule is precomputed (it depends only on t0) and the
        # kernel replays the per-step float ops exactly, so this path is
        # bit-identical to the scan below (the engine benchmark gates it)
        eps = vq.default_steps(t0 + 1 + jnp.arange(tau, dtype=jnp.int32),
                               eps0=eps0, decay=decay)
        w = ops.vq_window(zwin, w0, eps)
        return w0 - w, w

    def body(carry, z):
        w, t = carry
        eps = vq.default_steps(t + 1, eps0=eps0, decay=decay)
        if use_pallas:
            # fused distance+argmin+scatter kernel (blocked fallback past
            # the VMEM budget); batch of one point, so counts/zsum
            # reduce exactly to eq. (4)'s H(z, w)
            counts, zsum = ops.vq_delta_routed(z[None, :], w,
                                               budget_bytes=vmem_budget,
                                               fused=fused)
            h = counts[:, None] * w - zsum
        else:
            h = vq.H(z, w)
        return (w - eps * h, t + 1), None

    (w, _), _ = jax.lax.scan(body, (w0, t0), zwin)
    return w0 - w, w


class MeshExecutor:
    """One worker per mesh device, merged with collectives (the headline)."""

    name = "mesh"

    def __init__(self, mesh: Mesh | None = None, axis: str = "workers",
                 network: NetworkModel | None = None, *,
                 topology: Topology | None = None,
                 transport: comm.Transport | str | None = None,
                 use_pallas: bool = True, fused: bool = True,
                 eval_every: int = 10,
                 vmem_budget_bytes: int | None = None,
                 on_window: Callable[[int, jax.Array], None] | None = None,
                 publish_every: int = 1,
                 merge: str | None = None, quorum_frac: float = 0.6,
                 staleness_gamma: float = 0.5,
                 divergence_thresh: float = 0.0, max_stale: int = 8,
                 tier1_controller=None,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None,
                 profiler=None):
        if not axis:
            raise ValueError("worker axis name must be a non-empty string")
        if merge not in (None, "quorum", "dynamic"):
            raise ValueError(
                f"merge override must be None (scheme default), 'quorum', "
                f"or 'dynamic', got {merge!r}")
        if not 0.0 < quorum_frac <= 1.0:
            raise ValueError(
                f"quorum_frac must be in (0, 1], got {quorum_frac}")
        if divergence_thresh < 0.0:
            raise ValueError(
                f"divergence_thresh must be >= 0, got {divergence_thresh}")
        if max_stale < 1:
            raise ValueError(f"max_stale must be >= 1, got {max_stale}")
        if topology is not None:
            if mesh is not None:
                raise ValueError(
                    "pass mesh= or topology=, not both — a topology builds "
                    "its own mesh")
            # the topology owns the axis model: a flat topology is the 1-D
            # worker mesh (bit-identical to the pre-topology path), a
            # hierarchical one the 2-D (hosts, workers) grid
            axis = topology.worker_axis
            mesh = topology.make_mesh()
        if mesh is not None:
            _validate_axis_names(
                mesh, topology.axes if topology is not None else (axis,))
        if publish_every < 1:
            raise ValueError(f"publish_every must be >= 1, "
                             f"got {publish_every}")
        self.mesh = mesh
        self.axis = axis
        self.topology = topology
        self.network = network or GeometricDelayNetwork()
        self.transport = comm.get_transport(
            transport if transport is not None else "xla")
        self.use_pallas = use_pallas
        # fused=True rides the one-dispatch Pallas hot path (window kernel
        # when the codebook fits VMEM, fused blocked assign+delta past it)
        # plus the double-buffered publish drain; fused=False keeps the
        # per-step scan + XLA segment-sum route as the benchmark comparator.
        # Both are bit-identical — the flag trades dispatches, not math.
        self.fused = fused
        self.eval_every = eval_every
        self.vmem_budget_bytes = vmem_budget_bytes
        # merge override: None = the scheme's own strategy (the default,
        # byte-identical program); "quorum" = straggler-tolerant eq. 8
        # (delta scheme only), proceeding on ceil(quorum_frac * M) arrivals
        # and folding late deltas via the stale-window rule; "dynamic" =
        # divergence-triggered eq. 8 (delta scheme only): merge when the
        # probed global drift crosses divergence_thresh or max_stale
        # windows have passed, re-pricing the traced merge wire to the
        # measured trigger count after each run
        self.merge = merge
        self.quorum_frac = quorum_frac
        self.staleness_gamma = staleness_gamma
        self.divergence_thresh = divergence_thresh
        self.max_stale = max_stale
        # bandwidth-adaptive sparse tier: a Tier1BudgetController re-sizes
        # the transport's tier1_frac after every published chunk from the
        # chunk's measured tier-1 wire bytes (engine.network closes the
        # loop the CommLog/transfer_ticks accounting opened); setting it
        # routes sync runs through the chunked publish path even without
        # an on_window hook, since frac is trace-static and can only
        # change at a program boundary
        self.tier1_controller = tier1_controller
        # publication hook: when set, the sync schemes run in host-level
        # chunks of ``publish_every`` windows (numerically identical — the
        # window scan is sequential either way) and ``on_window(windows_done,
        # w_shared)`` fires after each chunk's merge; a CodebookStore's
        # ``publisher()`` plugs in here to hot-swap a live serving codebook.
        # The async scheme has no window barrier: it publishes once, at end.
        self.on_window = on_window
        self.publish_every = publish_every
        # observability: a disabled tracer is a constant-time no-op, so the
        # hot path stays on the <3% overhead budget the obs bench enforces;
        # when a registry is attached every CommRecord is mirrored onto it
        # (per-tag/per-tier wire bytes become first-class metrics)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        if metrics is not None:
            self.transport.log.attach_metrics(metrics)
        # roofline attribution (obs.profile.Profiler): when attached, compile
        # misses go through the AOT path (lower -> compile -> run) so the
        # profiler parses the HLO of the very executable that runs — zero
        # extra compiles, and the cached callable is the compiled object
        self.profiler = profiler
        # compiled-program cache: rebuilding the shard_map closure on every
        # run() would recompile each time; key = everything trace-affecting.
        # Each entry also keeps the CommRecords traced for that program, so
        # cache hits replay the accounting the trace measured.
        self._compiled: dict[tuple, tuple] = {}
        # comm summary of the most recent run()/run_segment() (CommLog dict)
        self.last_comm: dict | None = None

    # -- topology-derived axis model ----------------------------------------

    @property
    def _axes(self) -> tuple[str, ...]:
        """Mesh axes the worker dimension shards over, outermost first."""
        if self.topology is not None:
            return self.topology.axes
        return (self.axis,)

    @property
    def _spec(self):
        """PartitionSpec entry / reduce-axis spec for the worker dim: the
        bare axis name on a flat mesh, the (hosts, workers) tuple on a
        hierarchical one (transports and strategies take either)."""
        if self.topology is not None:
            return self.topology.spec
        return self.axis

    @property
    def _topology_label(self) -> str:
        """Human label for attribution records: 'flat' or 'HxW'."""
        if self.topology is not None:
            return self.topology.describe()
        return "flat"

    # -- comm-aware compile cache -------------------------------------------

    def _call_compiled(self, cache_key: tuple, build: Callable, *args):
        """Run the cached program for ``cache_key`` (building+tracing it on
        a miss), replaying its traced ``CommRecord``s on every hit."""
        log = self.transport.log
        if cache_key not in self._compiled:
            fn = build()
            mark = log.mark()
            with self.tracer.span("compile", program=str(cache_key[0])):
                if self.profiler is not None:
                    # AOT split: .lower() runs the Python trace (appending
                    # the CommRecords exactly once), .compile() yields the
                    # post-SPMD HLO + cost_analysis, and the compiled
                    # executable is cached as the callable — same program,
                    # same numerics, no second compile
                    compiled = fn.lower(*args).compile()
                    try:
                        cost = compiled.cost_analysis()
                    except Exception:       # backend without cost support
                        cost = None
                    self.profiler.record_program(
                        cache_key, compiled.as_text(), cost)
                    fn = compiled
                out = fn(*args)              # first call traces -> records
            self._compiled[cache_key] = (fn, log.since(mark))
            return out
        fn, records = self._compiled[cache_key]
        log.extend(records)
        return fn(*args)

    def _merge_wire_by_tier(self, cache_key: tuple) -> dict:
        """Merge-tag wire bytes one execution of ``cache_key`` moves per
        participant, grouped by tier (None = untiered flat traffic, 0 =
        intra-host, 1 = inter-host) for the network model's per-link-class
        bandwidth charge."""
        _, records = self._compiled[cache_key]
        out: dict = {}
        for r in records:
            if r.tag == "merge":
                out[r.tier] = out.get(r.tier, 0) + r.wire_bytes * r.calls
        return out

    # -- public API ---------------------------------------------------------

    def run(self, scheme: str, w0: jax.Array, data: jax.Array,
            eval_data: jax.Array, *, tau: int, eps0: float = 0.5,
            decay: float = 1.0, key: jax.Array | None = None) -> SchemeResult:
        api.validate_scheme(scheme)
        if data.ndim != 3:
            raise ValueError(f"data must be (M, n, d), got {data.shape}")
        if eval_data.ndim != 3 or eval_data.shape[0] != data.shape[0]:
            raise ValueError(
                f"eval_data must be (M, n_eval, d) with the same M as data; "
                f"got {eval_data.shape} vs M={data.shape[0]}")
        m = data.shape[0]
        mesh = self.mesh if self.mesh is not None else make_worker_mesh(
            m, self.axis)
        _validate_mesh(mesh, self._axes, m)
        mark = self.transport.log.mark()
        t_wall = time.perf_counter()
        try:
            with self.tracer.span("run", scheme=scheme, executor=self.name,
                                  m=m, transport=self.transport.name):
                if scheme == "async_delta":
                    res = self._run_async(mesh, w0, data, eval_data, tau=tau,
                                          eps0=eps0, decay=decay, key=key)
                    if self.on_window is not None:
                        self.on_window(data.shape[1] // tau, res.w_shared)
                elif (self.on_window is not None
                      or self.tier1_controller is not None):
                    res = self._run_sync_published(mesh, scheme, w0, data,
                                                   eval_data, tau=tau,
                                                   eps0=eps0, decay=decay,
                                                   t0=0)
                else:
                    res, _ = self._run_sync(mesh, scheme, w0, data, eval_data,
                                            tau=tau, eps0=eps0, decay=decay)
        finally:
            self.last_comm = comm.CommLog.summarize(
                self.transport.log.since(mark))
        wall_s = time.perf_counter() - t_wall
        if self.metrics is not None:
            self.metrics.histogram("run_wall_s", executor=self.name,
                                   scheme=scheme).observe(wall_s)
        if self.profiler is not None:
            self.profiler.finish_run(wall_s)
        return res

    def run_segment(self, scheme: str, w0: jax.Array, data: jax.Array,
                    eval_data: jax.Array, *, tau: int, eps0: float = 0.5,
                    decay: float = 1.0, t0: int = 0,
                    mesh: Mesh | None = None) -> SchemeResult:
        """One elastic segment: sync windows starting at local step ``t0``.

        The ``ElasticMeshExecutor`` hook — identical to ``run`` for the
        synchronous schemes except that the Robbins-Monro step schedule
        continues from ``t0`` (so a resized run keeps the same eps_t sequence
        a fixed-M run would see) and the caller may supply the mesh built by
        ``distributed.elastic.plan_remesh`` for the current worker set."""
        api.validate_scheme(scheme)
        if scheme == "async_delta":
            raise ValueError(
                "elastic segments support the synchronous schemes "
                "('average', 'delta'); async_delta has no window barrier "
                "to resize at")
        if data.ndim != 3:
            raise ValueError(f"data must be (M, n, d), got {data.shape}")
        m = data.shape[0]
        if mesh is None:
            mesh = self.mesh if self.mesh is not None else make_worker_mesh(
                m, self.axis)
        _validate_mesh(mesh, self._axes, m)
        mark = self.transport.log.mark()
        try:
            with self.tracer.span("segment", scheme=scheme, m=m, t0=t0):
                if (self.on_window is not None
                        or self.tier1_controller is not None):
                    res = self._run_sync_published(mesh, scheme, w0, data,
                                                   eval_data, tau=tau,
                                                   eps0=eps0, decay=decay,
                                                   t0=t0)
                else:
                    res, _ = self._run_sync(mesh, scheme, w0, data, eval_data,
                                            tau=tau, eps0=eps0, decay=decay,
                                            t0=t0)
        finally:
            self.last_comm = comm.CommLog.summarize(
                self.transport.log.since(mark))
        return res

    # -- synchronous schemes (eqs. 3 and 8) ---------------------------------

    def _run_sync_published(self, mesh: Mesh, scheme: str, w0, data,
                            eval_data, *, tau: int, eps0: float, decay: float,
                            t0: int) -> SchemeResult:
        """``_run_sync`` in host-level chunks of ``publish_every`` windows,
        firing ``on_window`` after each chunk — same numerics (the window
        scan is sequential, and the merge/transport state threads across
        chunks exactly as it threads across the scan), at most two extra
        compiled programs (the chunk shape and one remainder shape).

        The drain is DOUBLE-BUFFERED (when ``fused`` is on): chunk k+1 is
        dispatched before chunk k's host-side reads (``np.asarray`` on the
        curve, the tick conversion, the ``on_window`` publish) block on its
        result — the latency-hiding pattern ``comm/ring.py`` uses for
        neighbor hops, lifted to the host loop, so the merge collective at
        the tail of one chunk overlaps the next chunk's compute.  The same
        programs run in the same order with the same inputs (chunk k+1
        depends on chunk k only through device arrays), so the pipelining
        is bit-stable; ``on_window`` still fires in chunk order."""
        n_windows = data.shape[1] // tau
        w, t, done = w0, t0, 0
        curves, ticks = [], []
        wt, ms = None, None
        pending = None          # (result, windows done BEFORE its chunk)

        def drain(slot, wt):
            res, base = slot
            if wt is None:
                # per-window tick cost as the segment run charged it
                # (window_ticks + any bandwidth transfer charge)
                wt = int(res.wall_ticks[0])
            curves.append(np.asarray(res.distortion))
            ticks.append(base * wt + np.asarray(res.wall_ticks))
            if self.on_window is not None:
                self.on_window(base + res.wall_ticks.shape[0], res.w_shared)
            return wt

        while done < n_windows:
            k = min(self.publish_every, n_windows - done)
            seg = data[:, done * tau:(done + k) * tau]
            cmark = self.transport.log.mark()
            with self.tracer.span("chunk", windows=k, t0=t):
                res, ms = self._run_sync(mesh, scheme, w, seg, eval_data,
                                         tau=tau, eps0=eps0, decay=decay,
                                         t0=t, merge_state=ms)
            w = res.w_shared     # device-side dependency only: no host sync
            if pending is not None:
                wt = drain(pending, wt)
            if self.fused:
                pending = (res, done)
            else:
                wt = drain((res, done), wt)
            done += k
            t += k * tau
            if self.tier1_controller is not None:
                self._adapt_tier1(cmark, n_windows_chunk=k, t_ticks=t)
        if pending is not None:
            wt = drain(pending, wt)
        if not curves:
            raise ValueError(
                f"need at least one tau={tau} window, got n={data.shape[1]}")
        return SchemeResult(
            w_shared=w,
            wall_ticks=jnp.asarray(np.concatenate(ticks), jnp.int32),
            distortion=jnp.asarray(np.concatenate(curves)))

    def _adapt_tier1(self, cmark: int, *, n_windows_chunk: int,
                     t_ticks: int) -> None:
        """One bandwidth-control step: feed the chunk's measured tier-1
        merge wire (bytes per window) to the ``Tier1BudgetController``,
        which re-sizes the transport's sparse fraction in place.  The new
        frac enters the next chunk's compile-cache key, so the program set
        stays bounded by the controller's ladder."""
        recs = self.transport.log.since(cmark)
        wire1 = sum(r.wire_bytes * r.calls for r in recs
                    if r.tag in ("merge", "probe") and r.tier == 1)
        frac = self.tier1_controller.update(
            self.transport, wire1 / max(n_windows_chunk, 1))
        if frac is None:
            return
        if self.metrics is not None:
            self.metrics.gauge("tier1_frac").set(frac)
        if self.tracer.enabled:
            self.tracer.counter("tier1_frac", float(frac),
                                ts_us=float(t_ticks))

    def _transport_frac_key(self) -> tuple:
        """Compile-cache fingerprint of the transport's trace-affecting
        compression knobs: the adaptive controller mutates ``frac`` (a
        static top-k shape) between chunks, so a cached program must be
        keyed on the value it was traced with.  A ``QuantizedTransport``
        is transparent here (the knobs live on its inner transport)."""
        t = self.transport
        t = getattr(t, "inner", t)
        return (getattr(t, "tier1_frac", None), getattr(t, "frac", None))

    def _run_sync(self, mesh: Mesh, scheme: str, w0, data, eval_data, *,
                  tau: int, eps0: float, decay: float, t0: int = 0,
                  merge_state=None) -> tuple[SchemeResult, Any]:
        """One compiled sync segment.  Returns ``(result, merge_state)`` so
        host-chunked callers (the publish path) can thread stateful-merge
        state — e.g. the sparse transport's error-feedback residual —
        across chunks instead of resetting it per program.  The host-side
        state representation carries a leading (M, ...) worker dim (the
        state is per-worker distinct, sharded over the axis)."""
        axis = self._spec
        axes = self._axes
        m = data.shape[0]
        n = data.shape[1]
        n_windows = n // tau
        quorum = self.merge == "quorum"
        dynamic = self.merge == "dynamic"
        late_np = None
        if quorum:
            if scheme != "delta":
                raise ValueError(
                    "the quorum merge folds eq.-8 displacements, so it rides "
                    f"scheme 'delta' only; got scheme {scheme!r}")
            strategy = merge_lib.get_merge(
                "quorum", transport=self.transport,
                quorum_frac=self.quorum_frac, gamma=self.staleness_gamma)
            # host-side lateness schedule: (m, n_windows) arrival-miss bits
            # drawn from the network model (and any chaos schedule wrapping
            # it), keyed by GLOBAL window so elastic segments stay aligned
            late_np = np.asarray(
                self.network.late_matrix(m, n_windows, tau,
                                         window0=t0 // tau), np.float32)
        elif dynamic:
            if scheme != "delta":
                raise ValueError(
                    "the dynamic merge folds eq.-8 displacements, so it "
                    f"rides scheme 'delta' only; got scheme {scheme!r}")
            strategy = merge_lib.get_merge(
                "dynamic", transport=self.transport,
                thresh=self.divergence_thresh, gamma=self.staleness_gamma,
                max_stale=self.max_stale)
        else:
            strategy = merge_lib.get_merge(scheme, transport=self.transport)
        transport = self.transport
        use_pallas = self.use_pallas
        fused = self.fused
        vmem_budget = self.vmem_budget_bytes
        if merge_state is None:
            # host-side merge state carries a leading per-worker dim: the
            # state (e.g. the sparse error-feedback residual) is DISTINCT
            # per worker, so it crosses the program boundary sharded over
            # the axis — not as a nominally-replicated array whose device
            # buffers secretly disagree
            merge_state = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (m,) + x.shape),
                strategy.init_state(w0))

        # observing runs additionally reduce the inter-worker codebook
        # divergence each window (mean over workers of ||w_local - w_merged||^2
        # — the future DynamicMerge trigger signal); the reduce rides an
        # "eval"-tagged collective so the exactly-pinned merge wire bytes are
        # untouched, and the flag joins the cache key because it changes the
        # compiled program's outputs.  A profiler rides the SAME fork — no
        # additional program variant beyond observe
        observe = (self.tracer.enabled or self.metrics is not None
                   or self.profiler is not None)

        def body(w0_in, t0_in, ms_in, data_l, eval_l, *late_in):
            stream = data_l[0]                       # (n, d) local shard
            windows = stream[: n_windows * tau].reshape(n_windows, tau, -1)
            ev = eval_l[0]                           # (n_eval, d)
            ms0 = jax.tree.map(lambda x: x[0], ms_in)  # drop worker dim
            xs = (windows, late_in[0][0]) if quorum else (windows,)

            def window(carry, x):
                zwin = x[0]
                w_srd, t, ms = carry
                _, w_fin = _local_window(w_srd, zwin, t, eps0=eps0,
                                         decay=decay, use_pallas=use_pallas,
                                         vmem_budget=vmem_budget,
                                         fused=fused)
                if quorum:
                    w_srd, ms = strategy(w_srd, w_fin, axis, ms,
                                         calls=n_windows, late=x[1])
                else:
                    w_srd, ms = strategy(w_srd, w_fin, axis, ms,
                                         calls=n_windows)
                # the dynamic merge's per-window sync decision, stacked into
                # a program output so the host can re-price the wire and tag
                # the trace with what actually triggered
                extra = (strategy.last_trigger,) if dynamic else ()
                t = t + tau
                if observe:
                    # one stacked reduce for (distortion, divergence): the
                    # observing program keeps the bare program's collective
                    # count, so live instrumentation stays on the <3% obs
                    # bench budget
                    cd, _ = transport.all_reduce(
                        jnp.stack([vq.distortion(ev, w_srd),
                                   jnp.sum((w_fin - w_srd) ** 2)]),
                        axis, op="mean", calls=n_windows, tag="eval")
                    return (w_srd, t, ms), (cd[0], cd[1]) + extra
                c, _ = transport.all_reduce(
                    vq.distortion(ev, w_srd), axis, op="mean",
                    calls=n_windows, tag="eval")
                return (w_srd, t, ms), ((c,) + extra if dynamic else c)

            (w_srd, _, ms_out), ys = jax.lax.scan(
                window, (w0_in, t0_in, ms0), xs)
            ms_out = jax.tree.map(lambda x: x[None], ms_out)
            if observe and dynamic:
                return w_srd, ys[0], ys[1], ys[2], ms_out
            if observe:
                return w_srd, ys[0], ys[1], ms_out
            if dynamic:
                return w_srd, ys[0], ys[1], ms_out
            return w_srd, ys, ms_out

        cache_key = ("sync", scheme, mesh, w0.shape, data.shape,
                     eval_data.shape, tau, eps0, decay, use_pallas, fused,
                     vmem_budget, observe, self._transport_frac_key())
        if quorum:
            cache_key += ("quorum", self.quorum_frac, self.staleness_gamma)
        if dynamic:
            cache_key += ("dynamic", self.divergence_thresh,
                          self.staleness_gamma, self.max_stale)

        def build():
            # replicated outputs: w_shared + curve (+ divergence when
            # observing, + trigger bits when dynamic), then the sharded
            # merge state
            n_rep = 2 + (1 if observe else 0) + (1 if dynamic else 0)
            out_specs = tuple(P() for _ in range(n_rep)) + (P(axis),)
            in_specs = (P(), P(), P(axis), P(axis), P(axis))
            if quorum:
                in_specs += (P(axis),)
            return jax.jit(compat.shard_map(
                body, mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                axis_names=frozenset(axes), check_vma=False))

        args = (w0, jnp.asarray(t0, jnp.int32), merge_state, data, eval_data)
        if quorum:
            args += (jnp.asarray(late_np),)
        freshly_compiled = cache_key not in self._compiled
        mark2 = self.transport.log.mark()
        out = self._call_compiled(cache_key, build, *args)
        if self.profiler is not None:
            self.profiler.note_segment(
                program=cache_key, scheme=scheme,
                transport=self.transport.name, topology=self._topology_label,
                m=m, n_windows=n_windows, d=w0.shape[-1], kappa=w0.shape[0],
                tau=tau, n_eval=eval_data.shape[1],
                compiled=freshly_compiled)
        trig = None
        if observe and dynamic:
            w_final, curve, divergence, trig, ms_out = out
        elif observe:
            w_final, curve, divergence, ms_out = out
        elif dynamic:
            (w_final, curve, trig, ms_out), divergence = out, None
        else:
            (w_final, curve, ms_out), divergence = out, None
        trig_np = None
        if dynamic:
            # honest wire accounting: SPMD can't skip a collective at trace
            # time, so the traced merge records claim every window synced;
            # re-price them to the windows that actually TRIGGERED (the
            # probe stays at full calls — its psum runs every window)
            trig_np = np.asarray(trig)
            n_trig = int(trig_np.sum())

            def _reprice(r):
                if r.tag != "merge" or r.calls == n_trig:
                    return r
                if n_trig == 0:
                    return None
                return dataclasses.replace(r, calls=n_trig)

            self.transport.log.rewrite_since(mark2, _reprice)
            # dynamic segments re-derive the tier split from the CORRECTED
            # records (merge at n_trig calls + the every-window probe)
            # instead of the trace-time cache snapshot
            tier_wire = {}
            for r in self.transport.log.since(mark2):
                if r.tag in ("merge", "probe"):
                    tier_wire[r.tier] = (tier_wire.get(r.tier, 0)
                                         + r.wire_bytes * r.calls)
        else:
            # each tier's measured per-window merge bytes is charged at that
            # link class's bandwidth (slow-DCN tier 1 vs ICI tier 0)
            tier_wire = self._merge_wire_by_tier(cache_key)
        wt = self.network.window_ticks(tau)
        for tier, total in tier_wire.items():
            wt += self.network.transfer_ticks(total / max(n_windows, 1),
                                              tier=tier)
        ticks = jnp.arange(1, n_windows + 1, dtype=jnp.int32) * wt
        if observe:
            self._emit_sync_obs(scheme=scheme, m=m, n_windows=n_windows,
                                tau=tau, wt=wt, tier_wire=tier_wire,
                                w_start=t0 // tau, curve=curve,
                                divergence=divergence, trig_np=trig_np)
            if quorum:
                self._emit_chaos_obs(w_start=t0 // tau, n_windows=n_windows,
                                     wt=wt, late_np=late_np)
        return SchemeResult(w_shared=w_final, wall_ticks=ticks,
                            distortion=curve), ms_out

    def _emit_chaos_obs(self, *, w_start: int, n_windows: int, wt: int,
                        late_np) -> None:
        """Render injected faults on the trace: one ``chaos_*`` span per
        scheduled event in this segment's window range (each on its own
        track — fault intervals overlap freely, and the trace checker pins
        same-track spans to nest-or-disjoint), plus counters for the
        quorum merge's late worker-windows and per-kind event totals."""
        tr, mt = self.tracer, self.metrics
        n_late = int(late_np.sum())
        if mt is not None and n_late:
            mt.counter("chaos_late_worker_windows").inc(n_late)
        if tr.enabled:
            tr.counter("chaos_late_workers_per_window", 0.0,
                       ts_us=float(w_start * wt))
            for wi in range(n_windows):
                tr.counter("chaos_late_workers_per_window",
                           float(late_np[:, wi].sum()),
                           ts_us=float((w_start + wi + 1) * wt))
        events_between = getattr(self.network, "events_between", None)
        if events_between is None:
            return
        for ev in events_between(w_start, w_start + n_windows):
            if mt is not None:
                mt.counter(f"chaos_{ev.kind}s").inc()
            if tr.enabled:
                dur = 1 if ev.kind == "kill" else ev.duration
                tr.add_span(
                    f"chaos_{ev.kind}", float(ev.window * wt),
                    float(dur * wt),
                    track=f"chaos {ev.kind} {ev.target}@{ev.window}",
                    window=ev.window, target=ev.target, kind=ev.kind)

    def _emit_sync_obs(self, *, scheme: str, m: int, n_windows: int,
                       tau: int, wt: int, tier_wire: dict, w_start: int,
                       curve, divergence, trig_np=None) -> None:
        """Mirror one sync segment onto the tick timeline and the registry.

        The window scan is a fused device program, so the per-worker
        timeline is *modeled* from the same ``NetworkModel`` arithmetic
        that produced ``wall_ticks`` (1 tick = 1 us in the trace): each
        worker computes for ``tau`` ticks, then the merge occupies the
        rest of the window, split across tiers in proportion to their
        measured wire bytes.  Distortion and divergence are the real
        per-window reduced values."""
        tr, mt = self.tracer, self.metrics
        curve_np = np.asarray(curve)
        div_np = None if divergence is None else np.asarray(divergence)
        n_trig = None if trig_np is None else int(trig_np.sum())
        if mt is not None:
            mt.counter("windows_total", scheme=scheme).inc(n_windows)
            if n_trig is not None:
                mt.counter("divergence_trigger", scheme=scheme).inc(n_trig)
                mt.counter("merge_skipped_total",
                           scheme=scheme).inc(n_windows - n_trig)
            h = mt.histogram("distortion", scheme=scheme)
            for c in curve_np:
                h.observe(float(c))
            if div_np is not None:
                g = mt.gauge("codebook_divergence", scheme=scheme)
                for dv in div_np:
                    g.set(float(dv))
            for tier, total in tier_wire.items():
                mt.counter(
                    "merge_wire_bytes",
                    tier="flat" if tier is None else tier,
                    scheme=scheme).inc(total)
        if not tr.enabled:
            return
        merge_total = max(wt - tau, 0)
        wire_sum = sum(tier_wire.values()) or 1
        # hoist the window-invariant geometry: track names and the tier
        # split are the same every window, only timestamps advance
        tracks = [f"worker {w}" for w in range(m)]
        tier_rows = []                   # (track, tier_attr, wire, dur)
        for tier, total in sorted(tier_wire.items(),
                                  key=lambda kv: (kv[0] is None,
                                                  kv[0] or 0)):
            tier_rows.append((
                "merge flat" if tier is None else f"merge tier {tier}",
                "flat" if tier is None else tier,
                int(round(total / max(n_windows, 1))),
                merge_total * (total / wire_sum)))
        add = tr.add_span
        for wi in range(n_windows):
            win = w_start + wi
            t_start = float(win * wt)
            for worker, track in enumerate(tracks):
                add("window", t_start, wt, track=track, window=win,
                    worker=worker, scheme=scheme)
                add("compute", t_start, tau, track=track, window=win,
                    worker=worker)
            t_m = t_start + tau
            # dynamic merges tag each span with whether this window's
            # divergence probe actually fired the sync
            tag = ({} if trig_np is None
                   else {"triggered": bool(trig_np[wi])})
            for track, tier_attr, wire, dur in tier_rows:
                add("merge", t_m, dur, track=track, tier=tier_attr,
                    wire_bytes=wire, window=win, scheme=scheme, **tag)
                t_m += dur
            t_end = t_start + wt
            tr.counter("distortion", float(curve_np[wi]), ts_us=t_end)
            if div_np is not None:
                tr.counter("codebook_divergence", float(div_np[wi]),
                           ts_us=t_end)
            if trig_np is not None:
                tr.counter("divergence_trigger", float(trig_np[wi]),
                           ts_us=t_end)

    # -- asynchronous scheme (eq. 9) ----------------------------------------

    def _run_async(self, mesh: Mesh, w0, data, eval_data, *, tau: int,
                   eps0: float, decay: float,
                   key: jax.Array | None) -> SchemeResult:
        axis = self._spec
        axes = self._axes
        m, n, _ = data.shape
        key = jax.random.PRNGKey(0) if key is None else key
        max_rounds = n // tau + 2
        lengths = self.network.round_lengths(key, m, max_rounds, tau)
        done_at = jnp.cumsum(lengths, axis=1)        # (M, max_rounds)
        eval_every = self.eval_every
        eval_ticks = np.arange(eval_every - 1, n, eval_every)
        transport = self.transport
        use_pallas = self.use_pallas
        fused = self.fused
        vmem_budget = self.vmem_budget_bytes

        def body(w0_in, data_l, eval_l, done_at_l):
            stream = data_l[0]                       # (n, d)
            ev = eval_l[0]
            my_done_at = done_at_l[0]                # (max_rounds,)

            def tick(carry, z):
                w, w_srd, snap, dcur, dinf, nd, t, ridx, cs = carry
                eps = vq.default_steps(t + 1, eps0=eps0, decay=decay)
                # local VQ step (1st line of eq. 9), Pallas hot path
                if use_pallas:
                    counts, zsum = ops.vq_delta_routed(
                        z[None, :], w, budget_bytes=vmem_budget, fused=fused)
                    h = counts[:, None] * w - zsum
                else:
                    h = vq.H(z, w)
                step = eps * h
                w_tmp = w - step
                dcur = dcur + step

                done = nd == t                       # this worker completes?
                donef = done.astype(w.dtype)
                # masked merge: ONLY completing workers' in-flight deltas
                # land on the reducer (4th line of eq. 9)
                landed, cs = transport.masked_all_reduce(
                    dinf, donef, axis, state=cs, calls=n)
                w_srd = w_srd - landed
                # completed: adopt downloaded snapshot + replay local delta
                # (3rd line); others keep the plain step (2nd line)
                w = jnp.where(done, snap - dcur, w_tmp)
                snap = jnp.where(done, w_srd, snap)
                dinf = jnp.where(done, dcur, dinf)
                dcur = jnp.where(done, jnp.zeros_like(dcur), dcur)
                ridx = ridx + done.astype(jnp.int32)
                nd = jnp.where(
                    done,
                    jnp.take(my_done_at, jnp.minimum(ridx, max_rounds - 1)),
                    nd)
                return (w, w_srd, snap, dcur, dinf, nd, t + 1, ridx, cs), \
                    w_srd

            zeros = jnp.zeros_like(w0_in)
            cs0 = transport.init_state(w0_in)
            init = (w0_in, w0_in, w0_in, zeros, zeros, my_done_at[0],
                    jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                    cs0)
            carry, traj = jax.lax.scan(tick, init, stream)
            w_srd_final = carry[1]
            sel = traj[eval_ticks]                   # (n_evals, kappa, d)
            c_local = jax.vmap(lambda w_: vq.distortion(ev, w_))(sel)
            curve, _ = transport.all_reduce(c_local, axis, op="mean",
                                            tag="eval")
            return w_srd_final, curve

        cache_key = ("async", mesh, w0.shape, data.shape, eval_data.shape,
                     tau, eps0, decay, eval_every, use_pallas, fused,
                     vmem_budget)

        def build():
            return jax.jit(compat.shard_map(
                body, mesh, in_specs=(P(), P(axis), P(axis), P(axis)),
                out_specs=(P(), P()),
                axis_names=frozenset(axes), check_vma=False))

        freshly_compiled = cache_key not in self._compiled
        w_final, curve = self._call_compiled(cache_key, build, w0, data,
                                             eval_data, done_at)
        if self.profiler is not None:
            # eq. 9 has no window barrier — attribute against the nominal
            # window count n // tau; the distortion probe runs once per
            # eval_every ticks, folded in as an effective per-window n_eval
            nominal_windows = max(n // tau, 1)
            self.profiler.note_segment(
                program=cache_key, scheme="async_delta",
                transport=self.transport.name, topology=self._topology_label,
                m=m, n_windows=nominal_windows, d=w0.shape[-1],
                kappa=w0.shape[0], tau=tau,
                n_eval=int(eval_data.shape[1] * len(eval_ticks)
                           / nominal_windows),
                compiled=freshly_compiled)
        if self.tracer.enabled or self.metrics is not None:
            self._emit_async_obs(m=m, n=n, tau=tau, done_at=done_at,
                                 eval_ticks=eval_ticks, curve=curve,
                                 cache_key=cache_key)
        return SchemeResult(
            w_shared=w_final,
            wall_ticks=jnp.asarray(eval_ticks + 1, jnp.int32),
            distortion=curve)

    def _emit_async_obs(self, *, m: int, n: int, tau: int, done_at,
                        eval_ticks, curve, cache_key: tuple) -> None:
        """Per-worker round timeline for eq. 9 (1 tick = 1 us in the trace).

        Each worker's round r computes for ``tau`` ticks and then keeps
        computing while its upload is in flight; the round *lands* at
        ``done_at[worker, r]``, where the in-flight delta joins the masked
        reduce.  Rendering compute and the in-flight ``merge`` span on the
        same worker track is what makes the paper's compute/communication
        overlap visible: worker A's merge span runs concurrently with
        worker B's compute span on the adjacent track.  Wire bytes are the
        per-tick masked-reduce charge attributed to the round's span."""
        tr, mt = self.tracer, self.metrics
        scheme = "async_delta"
        done_np = np.asarray(done_at)
        curve_np = np.asarray(curve)
        tier_wire = self._merge_wire_by_tier(cache_key)
        if mt is not None:
            h = mt.histogram("distortion", scheme=scheme)
            for c in curve_np:
                h.observe(float(c))
            rounds = int((done_np <= n).sum())
            mt.counter("async_rounds_total", scheme=scheme).inc(rounds)
            for tier, total in tier_wire.items():
                mt.counter(
                    "merge_wire_bytes",
                    tier="flat" if tier is None else tier,
                    scheme=scheme).inc(total)
        if not tr.enabled:
            return
        for worker in range(m):
            prev = 0
            for r in range(done_np.shape[1]):
                if prev >= n:
                    break
                end = min(int(done_np[worker, r]), n)
                if end <= prev:
                    continue
                track = f"worker {worker}"
                tr.add_span("round", prev, end - prev, track=track,
                            worker=worker, round=r, scheme=scheme)
                tr.add_span("compute", prev, min(tau, end - prev),
                            track=track, worker=worker, round=r)
                m_start = prev + min(tau, end - prev)
                for tier, total in tier_wire.items():
                    tr.add_span(
                        "merge", m_start, end - m_start,
                        track=track,
                        tier="flat" if tier is None else tier,
                        wire_bytes=int(round(total / n * (end - prev))),
                        worker=worker, round=r)
                prev = end
        for k, t in enumerate(eval_ticks):
            tr.counter("distortion", float(curve_np[k]), ts_us=float(t + 1))
