"""Seeded failure injection — the cloud the paper actually ran on.

The paper's headline numbers come from Azure VMs: a platform that loses
workers mid-run, slows them unpredictably, and partitions whole host
groups.  ``ChaosSchedule`` is a deterministic, seed-reproducible list of
such faults on the *global window* axis; ``ChaosNetwork`` composes the
schedule over any existing ``NetworkModel`` so the executors see faults
through the same two hooks they already consult (round lengths for the
eq.-9 async loop, the per-window late matrix for the quorum merge).

Fault taxonomy (one ``ChaosEvent`` each):

  * ``kill``      — worker ``target`` dies at ``window`` and never returns.
    The ``ElasticMeshExecutor`` turns this into an UNSCHEDULED resize at
    the next window barrier (checkpoint -> fold the dead worker's late
    delta via the eq.-8 stale rule -> remesh the survivors); a plain
    ``MeshExecutor`` models it as the worker being late forever.
  * ``slow``      — worker ``target`` straggles for ``duration`` windows:
    its delta misses the merge deadline and is folded late, damped by
    ``staleness_scale`` (the ``QuorumMerge`` path).
  * ``partition`` — host group ``target`` drops off the inter-host (tier-1)
    wire for ``duration`` windows: EVERY worker in the group is late at
    once — the failure mode only a topology-aware schedule can express.

Everything here is host-side numpy seeded by ``numpy.random.Philox``, so
the same seed produces the identical event sequence on the 1-device and
8-device CI legs (and on a real mesh) — the chaos suite's determinism pin.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.engine.network import NetworkModel

KINDS = ("kill", "slow", "partition")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One injected fault at global window ``window`` (>= 1)."""

    window: int     # global window index the fault fires at
    kind: str       # 'kill' | 'slow' | 'partition'
    target: int     # worker index (kill/slow) or host-group index (partition)
    duration: int = 1   # windows the fault lasts (kill: permanent)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown chaos kind {self.kind!r}; choose from {KINDS}")
        if self.window < 1:
            raise ValueError(
                f"chaos window must be >= 1 (after at least one merge), "
                f"got {self.window}")
        if self.target < 0:
            raise ValueError(f"chaos target must be >= 0, got {self.target}")
        if self.duration < 1:
            raise ValueError(
                f"chaos duration must be >= 1 window, got {self.duration}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ChaosSchedule:
    """An ordered, seed-reproducible list of ``ChaosEvent``s.

    ``hosts`` is the logical host grouping partition targets index into
    (workers ``[g*wph, (g+1)*wph)`` belong to group ``g``); it defaults to
    the grouping the schedule was generated with and is independent of the
    mesh actually running — a flat mesh can still suffer a tier-1-shaped
    outage, which is exactly the Azure regime the paper describes.
    """

    def __init__(self, events, *, seed: int = 0, hosts: int = 1):
        evs = sorted(
            (e if isinstance(e, ChaosEvent) else ChaosEvent(*e)
             for e in events),
            key=lambda e: (e.window, KINDS.index(e.kind), e.target))
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        kills = [e.target for e in evs if e.kind == "kill"]
        if len(set(kills)) != len(kills):
            raise ValueError(
                f"a worker can only die once; duplicate kill targets in "
                f"{kills}")
        self.events: tuple[ChaosEvent, ...] = tuple(evs)
        self.seed = seed
        self.hosts = hosts

    # -- constructors --------------------------------------------------------

    @classmethod
    def generate(cls, seed: int, *, windows: int, m: int, kills: int = 0,
                 slows: int = 0, partitions: int = 0, hosts: int = 2,
                 slow_duration: int = 3,
                 partition_duration: int = 2) -> "ChaosSchedule":
        """Draw a deterministic schedule from ``seed`` (numpy Philox — no
        jax key, so the draw is identical on every device count).

        Faults land in the middle half of the run ``[windows//4,
        3*windows//4)`` so the run both reaches the fault and has windows
        left to recover in; all event windows are distinct, kill targets
        are distinct workers, and a worker is not simultaneously killed
        and slowed.
        """
        if windows < 8:
            raise ValueError(
                f"need >= 8 windows to place faults with recovery room, "
                f"got {windows}")
        n_events = kills + slows + partitions
        if n_events == 0:
            return cls([], seed=seed, hosts=hosts)
        if kills >= m:
            raise ValueError(
                f"cannot kill {kills} of {m} workers — at least one must "
                f"survive")
        lo, hi = max(1, windows // 4), max(2, 3 * windows // 4)
        if hi - lo < n_events:
            raise ValueError(
                f"{n_events} events do not fit in the fault span "
                f"[{lo}, {hi}) of a {windows}-window run")
        rng = np.random.Generator(np.random.Philox(key=abs(int(seed))))
        wins = lo + rng.permutation(hi - lo)[:n_events]
        victims = rng.permutation(m)            # distinct kill/slow targets
        groups = rng.permutation(max(hosts, 1))
        events: list[ChaosEvent] = []
        i = 0
        for k in range(kills):
            events.append(ChaosEvent(int(wins[i]), "kill", int(victims[k])))
            i += 1
        for s in range(slows):
            events.append(ChaosEvent(
                int(wins[i]), "slow", int(victims[(kills + s) % m]),
                duration=slow_duration))
            i += 1
        for p in range(partitions):
            events.append(ChaosEvent(
                int(wins[i]), "partition", int(groups[p % max(hosts, 1)]),
                duration=partition_duration))
            i += 1
        return cls(events, seed=seed, hosts=hosts)

    @classmethod
    def from_spec(cls, spec: str, *, windows: int, m: int,
                  hosts: int = 2) -> "ChaosSchedule":
        """Parse the CLI form ``"SEED:kill=2,slow=1,part=1"``.

        The part after the colon is the fault-count schedule; counts
        default to 0, so ``"7:kill=1"`` is one kill drawn from seed 7.
        """
        head, sep, tail = spec.partition(":")
        if not sep or not head.strip():
            raise ValueError(
                f"bad chaos spec {spec!r} (want 'SEED:kill=K,slow=S,"
                f"part=P')")
        try:
            seed = int(head)
        except ValueError:
            raise ValueError(
                f"bad chaos seed {head!r} (want an integer)") from None
        counts = {"kill": 0, "slow": 0, "part": 0}
        for part in tail.split(","):
            part = part.strip()
            if not part:
                continue
            name, eq, val = part.partition("=")
            if not eq or name not in counts:
                raise ValueError(
                    f"bad chaos schedule entry {part!r} (want "
                    f"'kill=K' | 'slow=S' | 'part=P')")
            try:
                counts[name] = int(val)
            except ValueError:
                raise ValueError(
                    f"bad chaos count {val!r} in {part!r}") from None
        return cls.generate(seed, windows=windows, m=m, hosts=hosts,
                            kills=counts["kill"], slows=counts["slow"],
                            partitions=counts["part"])

    # -- queries -------------------------------------------------------------

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    @property
    def kill_events(self) -> tuple[ChaosEvent, ...]:
        return tuple(e for e in self.events if e.kind == "kill")

    def events_between(self, w0: int, w1: int) -> tuple[ChaosEvent, ...]:
        """Events firing in the global window span ``[w0, w1)``."""
        return tuple(e for e in self.events if w0 <= e.window < w1)

    def describe(self) -> str:
        if not self.events:
            return f"seed={self.seed}: no faults"
        return f"seed={self.seed}: " + ",".join(
            f"{e.kind}@{e.window}:{e.target}" for e in self.events)

    def _group_members(self, group: int, m: int) -> range:
        """Flat worker indices of logical host group ``group`` under the
        schedule's grouping, clamped to the live worker count ``m``."""
        wph = max(1, m // max(self.hosts, 1))
        return range(min(group * wph, m), min((group + 1) * wph, m))

    def late_matrix(self, m: int, n_windows: int, *,
                    window0: int = 0) -> np.ndarray:
        """(m, n_windows) float32 lateness bits over global windows
        ``[window0, window0 + n_windows)``: 1.0 = that worker's delta
        misses that window's merge deadline.

        slow: the target worker for ``duration`` windows.  partition: every
        worker of the target host group for ``duration`` windows.
        kill: the target worker from its death window onward (the model a
        non-elastic run sees; an elastic run removes the worker instead).
        Targets outside the live worker count are ignored (they already
        departed).
        """
        late = np.zeros((m, n_windows), np.float32)
        for e in self.events:
            w = e.window - window0
            if e.kind == "kill":
                if e.target < m and w < n_windows:
                    late[e.target, max(w, 0):] = 1.0
                continue
            lo, hi = max(w, 0), min(w + e.duration, n_windows)
            if hi <= lo:
                continue
            if e.kind == "slow":
                if e.target < m:
                    late[e.target, lo:hi] = 1.0
            else:  # partition: the whole host group drops off the wire
                for worker in self._group_members(e.target, m):
                    late[worker, lo:hi] = 1.0
        return late


class ChaosNetwork(NetworkModel):
    """A ``NetworkModel`` wrapper injecting a ``ChaosSchedule``'s faults.

    Composes over any inner model: tick pricing (``window_ticks`` /
    ``transfer_ticks``) passes through untouched — a fault changes WHO
    arrives, not what the healthy wire costs — while the two fault-visible
    hooks overlay the schedule:

      * ``round_lengths`` (async, eq. 9): slowed workers' rounds stretch by
        ``slow_factor`` for the fault's duration, partitioned groups
        likewise, and killed workers' post-death rounds never complete.
      * ``late_matrix`` (sync quorum): the union of the inner model's
        stragglers (e.g. ``GeometricDelayNetwork``'s geometric tail) and
        the schedule's injected lateness.
    """

    name = "chaos"
    #: sentinel round length for a dead worker: longer than any run, so the
    #: worker's next round never completes within the data budget
    DEAD_TICKS = 10 ** 7

    def __init__(self, inner: NetworkModel, schedule: ChaosSchedule, *,
                 topology=None, slow_factor: int = 4):
        if slow_factor < 1:
            raise ValueError(f"slow_factor must be >= 1, got {slow_factor}")
        self.inner = inner
        self.schedule = schedule
        self.slow_factor = slow_factor
        if topology is not None and not topology.is_flat:
            # a real topology overrides the schedule's logical grouping:
            # partition targets then index ACTUAL host groups
            self.schedule = ChaosSchedule(schedule.events,
                                          seed=schedule.seed,
                                          hosts=topology.hosts)

    def window_ticks(self, tau: int) -> int:
        return self.inner.window_ticks(tau)

    def transfer_ticks(self, wire_bytes, *, tier=None) -> int:
        return self.inner.transfer_ticks(wire_bytes, tier=tier)

    def events_between(self, w0: int, w1: int):
        return self.schedule.events_between(w0, w1)

    def round_lengths(self, key, m: int, max_rounds: int, tau: int):
        import jax.numpy as jnp
        base = np.asarray(self.inner.round_lengths(key, m, max_rounds, tau))
        lengths = base.astype(np.int64).copy()
        # the async loop has no window barrier; round r of a healthy worker
        # covers roughly window r, so faults map window -> round index
        for e in self.schedule:
            if e.kind == "kill":
                if e.target < m and e.window < max_rounds:
                    lengths[e.target, e.window:] = self.DEAD_TICKS
                continue
            lo, hi = e.window, min(e.window + e.duration, max_rounds)
            if hi <= lo:
                continue
            targets = ([e.target] if e.kind == "slow"
                       else self.schedule._group_members(e.target, m))
            for worker in targets:
                if worker < m:
                    lengths[worker, lo:hi] *= self.slow_factor
        return jnp.asarray(np.minimum(lengths, self.DEAD_TICKS), jnp.int32)

    def late_matrix(self, m: int, n_windows: int, tau: int, *,
                    window0: int = 0) -> np.ndarray:
        inner = self.inner.late_matrix(m, n_windows, tau, window0=window0)
        sched = self.schedule.late_matrix(m, n_windows, window0=window0)
        return np.maximum(np.asarray(inner, np.float32), sched)
