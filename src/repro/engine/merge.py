"""Pluggable merge strategies — the paper's reducing phases as pytree ops.

One implementation serves both consumers:

  * ``engine.mesh.MeshExecutor`` calls a strategy on raw (kappa, d) prototype
    arrays inside its shard_map body (an array is a one-leaf pytree);
  * ``training.steps.make_window_step`` calls the same strategy on full LM
    parameter pytrees, so the paper-scheme window step and the VQ engine
    share one merge implementation.

The collectives themselves live one layer down, behind ``repro.comm``'s
``Transport`` API: a strategy decides *what* to reduce (means of versions,
sums of displacements, last window's stale deltas), the transport decides
*how* the bytes move (dense XLA, Pallas ring, top-k sparse) and accounts
the wire.  The f32 merge-traffic convention is the transport's, defined
once in ``comm.api``.

A strategy is ``(merged, new_state) = strategy(w0, w_local, axis, state)``
where ``w0`` is the window's starting version, ``w_local`` the worker's
version after tau local steps, and ``axis`` the mesh axis to reduce over.
``state`` threads both strategy-owned state (``AsyncDeltaMerge`` carries
last window's delta) and transport state (``SparseTransport`` carries the
error-feedback residual); with the default stateless transport the async
state stays the bare delta tree it has always been.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import comm

Pytree = Any


def tree_sub_f32(a: Pytree, b: Pytree) -> Pytree:
    """Leafwise ``a - b`` in f32 (the displacement Delta of paper eq. 7)."""
    return jax.tree.map(
        lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_apply_delta(base: Pytree, delta: Pytree) -> Pytree:
    """``base - delta`` with the subtraction in f32, result in base dtype."""
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) - d).astype(p.dtype), base, delta)


class MergeStrategy:
    """Base strategy.  ``stateful`` strategies must be fed ``init_state``.

    ``transport`` is any ``repro.comm.Transport`` (default: the dense XLA
    oracle); ``calls`` at call time is the static trip count of the
    surrounding scan, folded into the transport's wire accounting.
    """

    name = "base"
    own_state = False  # strategy-owned state, beyond the transport's

    def __init__(self, transport: comm.Transport | None = None):
        self.transport = (transport if transport is not None
                          else comm.get_transport("xla"))

    @property
    def stateful(self) -> bool:
        return self.own_state or self.transport.stateful

    # -- state threading: strategy-owned + transport state in one carry ----

    def _init_own_state(self, params: Pytree) -> Pytree | None:
        return None

    def init_state(self, params: Pytree) -> Pytree | None:
        own = self._init_own_state(params)
        tsp = self.transport.init_state(params)
        if own is None:
            return tsp
        if tsp is None:
            return own
        return {"own": own, "comm": tsp}

    def _split_state(self, state):
        if self.own_state and self.transport.stateful:
            state = {} if state is None else state
            return state.get("own"), state.get("comm")
        if self.own_state:
            return state, None
        return None, state

    def _join_state(self, own, tsp):
        if self.own_state and self.transport.stateful:
            return {"own": own, "comm": tsp}
        if self.own_state:
            return own
        return tsp

    def __call__(self, w0: Pytree, w_local: Pytree, axis: str,
                 state: Pytree | None = None, *,
                 calls: int = 1) -> tuple[Pytree, Pytree | None]:
        raise NotImplementedError


class AverageMerge(MergeStrategy):
    """Paper eq. (3): w_srd = mean_i w^i(tau) — the scheme that does NOT
    speed convergence up (Section 2's negative result)."""

    name = "average"

    def __call__(self, w0, w_local, axis, state=None, *, calls=1):
        del w0
        merged, _ = self.transport.all_reduce(w_local, axis, op="mean",
                                              calls=calls)
        # means ride dense on every transport: state passes through
        return merged, state


class DeltaMerge(MergeStrategy):
    """Paper eq. (8): w_srd = w0 - sum_i Delta^i — displacement merging."""

    name = "delta"

    def __call__(self, w0, w_local, axis, state=None, *, calls=1):
        total, state = self.transport.all_reduce(
            tree_sub_f32(w0, w_local), axis, op="sum", state=state,
            calls=calls)
        return tree_apply_delta(w0, total), state


class SparseDeltaMerge(DeltaMerge):
    """Eq. (8) over the top-k/error-feedback ``SparseTransport`` — the LM
    window step's DELTA_SPARSE as an engine-level strategy.  State is the
    residual tree (what ``init_window_state`` stores as ``"residual"``)."""

    name = "delta_sparse"

    def __init__(self, transport: comm.Transport | None = None, *,
                 frac: float | None = None):
        if transport is None:
            transport = comm.get_transport(
                "sparse", frac=0.01 if frac is None else frac)
        elif frac is not None and getattr(transport, "frac", frac) != frac:
            # an explicit transport AND a conflicting frac: refusing beats
            # silently compressing at a rate the caller didn't ask for
            raise ValueError(
                f"frac={frac} conflicts with the supplied transport's "
                f"frac={transport.frac}; configure one place only")
        super().__init__(transport)


class AsyncDeltaMerge(MergeStrategy):
    """Paper eq. (9) in pipelined-collective form: the reduction of window
    k-1's deltas is applied at the end of window k, so the collective has no
    data dependency on window k's compute (one-window-stale merge).

    ``state`` carries last window's local delta (f32, zeros initially)."""

    name = "async_delta"
    own_state = True

    def _init_own_state(self, params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def __call__(self, w0, w_local, axis, state=None, *, calls=1):
        delta_prev, tsp = self._split_state(state)
        if delta_prev is None:
            raise ValueError("AsyncDeltaMerge needs its delta_prev state; "
                             "seed it with init_state(params)")
        stale, tsp = self.transport.all_reduce(delta_prev, axis, op="sum",
                                               state=tsp, calls=calls)
        merged = tree_apply_delta(w_local, stale)
        return merged, self._join_state(tree_sub_f32(w0, w_local), tsp)


class QuorumMerge(MergeStrategy):
    """Straggler-tolerant eq. (8): proceed when K of M deltas arrive.

    Each window, every worker ships its displacement plus any carried
    (not-yet-landed) delta, masked by an arrival bit from the network
    model's late matrix; the merge COUNTS the arrivals on the same masked
    collective and applies the landed sum only when at least
    ``ceil(quorum_frac * M)`` workers made the deadline.  A late worker's
    delta is not lost: it rides the worker's carry, damped by one
    ``staleness_scale(1, gamma)`` factor per window it waits (Patra's
    staleness-tolerant analysis — the same eq.-8 stale-window rule
    ``engine.elastic`` applies to departing workers), and lands with the
    next quorum.  When no ``late`` bit is supplied every worker arrives,
    the quorum is trivially met, and the merge is numerically the plain
    ``DeltaMerge``.

    ``state`` carries the per-worker pending-delta tree (f32, zeros
    initially).  The arrival count rides the transport's masked reduce —
    no raw collective appears at this layer (CI pins engine code
    lax.psum-free), and the scalar's 4 bytes are part of the quorum
    merge's exactly-pinned wire accounting.
    """

    name = "quorum"
    own_state = True

    def __init__(self, transport: comm.Transport | None = None, *,
                 quorum_frac: float = 0.6, gamma: float = 0.5):
        if not 0.0 < quorum_frac <= 1.0:
            raise ValueError(
                f"quorum_frac must be in (0, 1], got {quorum_frac}")
        super().__init__(transport)
        self.quorum_frac = quorum_frac
        self.gamma = gamma

    def _init_own_state(self, params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def __call__(self, w0, w_local, axis, state=None, *, calls=1,
                 late=None):
        from repro.distributed.elastic import staleness_scale
        carry, tsp = self._split_state(state)
        if carry is None:
            raise ValueError("QuorumMerge needs its pending-delta state; "
                             "seed it with init_state(params)")
        m = comm.axis_size(axis)
        k_quorum = max(1, int(math.ceil(self.quorum_frac * m - 1e-9)))
        s = jnp.asarray(staleness_scale(1, gamma=self.gamma), jnp.float32)
        delta = tree_sub_f32(w0, w_local)
        # everything this worker owes the merge: this window's displacement
        # plus the carried backlog, one window staler than last time
        ship = jax.tree.map(lambda d, c: d + s * c, delta, carry)
        arrive = (jnp.asarray(1.0, jnp.float32) if late is None
                  else 1.0 - jnp.asarray(late, jnp.float32))
        landed, tsp = self.transport.masked_all_reduce(
            {"delta": ship, "n": jnp.ones((), jnp.float32)}, arrive, axis,
            state=tsp, calls=calls)
        met = (landed["n"] >= k_quorum).astype(jnp.float32)
        merged = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) - met * d).astype(p.dtype),
            w0, landed["delta"])
        # an arrived worker whose quorum landed owes nothing; everyone else
        # (late, or arrived into a failed quorum) keeps the whole ship
        keep = 1.0 - met * arrive
        carry_new = jax.tree.map(lambda sh: keep * sh, ship)
        return merged, self._join_state(carry_new, tsp)


class DynamicMerge(MergeStrategy):
    """Kamp-style dynamic averaging for eq. (8): merge on measured drift,
    not on a clock.

    Every window each worker computes its pending displacement (this
    window's delta plus the carried, staleness-damped backlog) and the
    workers agree on a GLOBAL drift measure via a 4-byte scalar probe —
    the sum over workers of ``||pending||^2``.  The window merges only
    when that drift crosses ``thresh`` (or when ``max_stale`` windows have
    passed since the last merge, the hysteresis cap that keeps the eq.-8
    staleness damping bounded — Patra's staleness-tolerant analysis covers
    the wait).  The decision is a per-window 0/1 mask on the transport's
    masked all-reduce, so ONE compiled program serves every window; the
    executor reads the trigger bits back and re-prices the traced merge
    records to the triggered count (skipped windows ship only the probe).

    A skipped window's displacement is not lost: it rides the worker's
    carry, damped by one ``staleness_scale(1, gamma)`` factor per window
    it waits (the same stale-window rule ``QuorumMerge`` and
    ``engine.elastic`` apply), and lands whole with the next trigger.

    With ``thresh=0`` the probe is always >= the threshold, every window
    triggers with a zero carry, and the math reduces term-by-term to the
    plain ``DeltaMerge`` — the bitwise-parity contract the adapt suite
    pins.

    ``state`` carries ``{"carry": pending-delta tree, "stale": windows
    since the last merge}``.  ``last_trigger`` exposes the window's traced
    trigger scalar to the surrounding scan body (the executor stacks it
    into the per-window trigger output).
    """

    name = "dynamic"
    own_state = True

    def __init__(self, transport: comm.Transport | None = None, *,
                 thresh: float = 0.0, gamma: float = 0.5,
                 max_stale: int = 8):
        if thresh < 0.0:
            raise ValueError(f"divergence thresh must be >= 0, got {thresh}")
        if max_stale < 1:
            raise ValueError(f"max_stale must be >= 1, got {max_stale}")
        super().__init__(transport)
        self.thresh = thresh
        self.gamma = gamma
        self.max_stale = max_stale
        self.last_trigger = None

    def _init_own_state(self, params):
        return {"carry": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "stale": jnp.zeros((), jnp.float32)}

    def __call__(self, w0, w_local, axis, state=None, *, calls=1):
        from repro.distributed.elastic import staleness_scale
        own, tsp = self._split_state(state)
        if own is None:
            raise ValueError("DynamicMerge needs its carry/staleness state; "
                             "seed it with init_state(params)")
        carry, stale = own["carry"], own["stale"]
        s = jnp.asarray(staleness_scale(1, gamma=self.gamma), jnp.float32)
        delta = tree_sub_f32(w0, w_local)
        pend = jax.tree.map(lambda d, c: d + s * c, delta, carry)
        # the probe: global drift as a scalar all-reduce (tag "probe" — the
        # always-paid signaling cost, accounted apart from merge payload);
        # psum is replicated, so every worker decides identically
        local = jnp.asarray(0.0, jnp.float32)
        for leaf in jax.tree.leaves(pend):
            local = local + jnp.sum(leaf * leaf)
        gdiv, _ = self.transport.all_reduce(local, axis, op="sum",
                                            calls=calls, tag="probe")
        trig = jnp.logical_or(gdiv >= self.thresh,
                              stale + 1.0 >= self.max_stale
                              ).astype(jnp.float32)
        landed, tsp = self.transport.masked_all_reduce(
            pend, trig, axis, state=tsp, calls=calls)
        merged = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) - trig * d).astype(p.dtype),
            w0, landed)
        keep = 1.0 - trig
        carry_new = jax.tree.map(lambda sh: keep * sh, pend)
        self.last_trigger = trig
        return merged, self._join_state(
            {"carry": carry_new, "stale": keep * (stale + 1.0)}, tsp)


_STRATEGIES = {
    "average": AverageMerge,
    "delta": DeltaMerge,
    "delta_sparse": SparseDeltaMerge,
    "async_delta": AsyncDeltaMerge,
    "quorum": QuorumMerge,
    "dynamic": DynamicMerge,
}


def get_merge(name: str, transport: comm.Transport | None = None,
              **kwargs) -> MergeStrategy:
    """Factory: 'average' | 'delta' | 'delta_sparse' | 'async_delta' |
    'quorum' | 'dynamic'.

    ``transport`` plugs any ``repro.comm`` transport under the strategy
    (default: dense XLA); ``delta_sparse`` additionally accepts ``frac``;
    ``quorum`` accepts ``quorum_frac`` and ``gamma``; ``dynamic`` accepts
    ``thresh``, ``gamma``, and ``max_stale``.
    """
    if name not in _STRATEGIES:
        raise ValueError(
            f"unknown merge strategy {name!r}; choose from "
            f"{sorted(_STRATEGIES)}")
    return _STRATEGIES[name](transport, **kwargs)
