"""Pluggable merge strategies — the paper's reducing phases as pytree ops.

One implementation serves both consumers:

  * ``engine.mesh.MeshExecutor`` calls a strategy on raw (kappa, d) prototype
    arrays inside its shard_map body (an array is a one-leaf pytree);
  * ``training.steps.make_window_step`` calls the same strategy on full LM
    parameter pytrees, so the paper-scheme window step and the VQ engine
    share one merge implementation.

All collectives ride in f32: XLA:CPU's bf16 all-reduce promotion
CHECK-fails, and f32 reductions are what real runs use for merge traffic.
A strategy is ``(merged, new_state) = strategy(w0, w_local, axis, state)``
where ``w0`` is the window's starting version, ``w_local`` the worker's
version after tau local steps, and ``axis`` the mesh axis to reduce over.
Only ``AsyncDeltaMerge`` is stateful (it carries last window's delta).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def tree_sub_f32(a: Pytree, b: Pytree) -> Pytree:
    """Leafwise ``a - b`` in f32 (the displacement Delta of paper eq. 7)."""
    return jax.tree.map(
        lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_pmean_f32(tree: Pytree, axis: str) -> Pytree:
    """pmean floating leaves in f32, cast back; non-floating pass through."""
    return jax.tree.map(
        lambda x: jax.lax.pmean(x.astype(jnp.float32), axis).astype(x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_psum_f32(tree: Pytree, axis: str) -> Pytree:
    return jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.float32), axis), tree)


def tree_apply_delta(base: Pytree, delta: Pytree) -> Pytree:
    """``base - delta`` with the subtraction in f32, result in base dtype."""
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) - d).astype(p.dtype), base, delta)


class MergeStrategy:
    """Base strategy.  ``stateful`` strategies must be fed ``init_state``."""

    name = "base"
    stateful = False

    def init_state(self, params: Pytree) -> Pytree | None:
        return None

    def __call__(self, w0: Pytree, w_local: Pytree, axis: str,
                 state: Pytree | None = None) -> tuple[Pytree, Pytree | None]:
        raise NotImplementedError


class AverageMerge(MergeStrategy):
    """Paper eq. (3): w_srd = mean_i w^i(tau) — the scheme that does NOT
    speed convergence up (Section 2's negative result)."""

    name = "average"

    def __call__(self, w0, w_local, axis, state=None):
        del w0
        return tree_pmean_f32(w_local, axis), state


class DeltaMerge(MergeStrategy):
    """Paper eq. (8): w_srd = w0 - sum_i Delta^i — displacement merging."""

    name = "delta"

    def __call__(self, w0, w_local, axis, state=None):
        total = tree_psum_f32(tree_sub_f32(w0, w_local), axis)
        return tree_apply_delta(w0, total), state


class AsyncDeltaMerge(MergeStrategy):
    """Paper eq. (9) in pipelined-collective form: the reduction of window
    k-1's deltas is applied at the end of window k, so the collective has no
    data dependency on window k's compute (one-window-stale merge).

    ``state`` carries last window's local delta (f32, zeros initially)."""

    name = "async_delta"
    stateful = True

    def init_state(self, params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def __call__(self, w0, w_local, axis, state=None):
        if state is None:
            raise ValueError("AsyncDeltaMerge needs its delta_prev state; "
                             "seed it with init_state(params)")
        stale = jax.tree.map(lambda d: jax.lax.psum(d, axis), state)
        merged = tree_apply_delta(w_local, stale)
        return merged, tree_sub_f32(w0, w_local)


_STRATEGIES = {
    "average": AverageMerge,
    "delta": DeltaMerge,
    "async_delta": AsyncDeltaMerge,
}


def get_merge(name: str) -> MergeStrategy:
    """Factory: 'average' | 'delta' | 'async_delta'."""
    if name not in _STRATEGIES:
        raise ValueError(
            f"unknown merge strategy {name!r}; choose from "
            f"{sorted(_STRATEGIES)}")
    return _STRATEGIES[name]()
