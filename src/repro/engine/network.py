"""Pluggable communication-cost models — paper Section 4's cloud model.

A ``NetworkModel`` answers two questions the executors ask:

  * ``round_lengths(key, m, max_rounds, tau)`` — for the ASYNC scheme: how
    many wall ticks does each of a worker's back-to-back upload/download
    rounds take?  A round is always >= ``tau`` (the paper's "as soon as its
    previous uploads and downloads are completed" protocol processes tau
    points per round); the model adds the random communication cost on top.
  * ``window_ticks(tau)`` — for the SYNC schemes: how many wall ticks one
    barriered tau-window costs (compute + the blocking merge round-trip).

Three concrete models:

  * ``InstantNetwork``        — communications are free (the simulated
    architecture of paper Sections 2-3: a window costs exactly tau ticks).
  * ``FixedLatencyNetwork``   — every round pays a constant extra latency
    (a LAN / same-rack datacenter).
  * ``GeometricDelayNetwork`` — extra ticks ~ Geometric(p_delay), the
    paper Section 4 cloud model (mean extra delay (1-p)/p ticks).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


class NetworkModel:
    """Base communication-cost model; subclasses override both hooks."""

    name = "base"

    def round_lengths(self, key: jax.Array, m: int, max_rounds: int,
                      tau: int) -> jax.Array:
        """(m, max_rounds) int32 per-round durations in wall ticks (>= tau)."""
        raise NotImplementedError

    def window_ticks(self, tau: int) -> int:
        """Wall ticks a synchronous tau-window costs under this network."""
        raise NotImplementedError

    def transfer_ticks(self, wire_bytes: float, *,
                       tier: int | None = None) -> int:
        """Extra wall ticks to move ``wire_bytes`` (a window's MEASURED
        merge traffic from the ``repro.comm`` transport records, not a
        modeled figure).  ``tier`` is the link class the bytes crossed
        (None = flat, 0 = intra-host ICI, 1 = inter-host DCN) so a model
        can price the slow inter-host wire separately — the paper's Azure
        regime.  The base model has infinite bandwidth on every tier —
        latency-only models charge 0 — so existing tick accounting is
        unchanged unless a model opts in via ``bytes_per_tick``."""
        del wire_bytes, tier
        return 0

    def late_matrix(self, m: int, n_windows: int, tau: int, *,
                    window0: int = 0):
        """(m, n_windows) float32 lateness bits for the SYNC quorum merge:
        1.0 = that worker's window delta misses the merge deadline (it is
        folded in late, damped by the eq.-8 stale-window rule, instead of
        stalling the barrier).  ``window0`` is the global index of the
        first window (elastic segments resume mid-run).  Host-side numpy,
        deterministic, device-count independent.  Base model: every worker
        is always on time, so quorum-merge runs over a well-behaved
        network degenerate to the plain eq.-8 merge."""
        import numpy as np
        del tau, window0
        return np.zeros((m, n_windows), np.float32)


@dataclasses.dataclass(frozen=True)
class InstantNetwork(NetworkModel):
    name = "instant"

    def round_lengths(self, key, m, max_rounds, tau):
        del key
        return jnp.full((m, max_rounds), tau, jnp.int32)

    def window_ticks(self, tau):
        return tau


@dataclasses.dataclass(frozen=True)
class FixedLatencyNetwork(NetworkModel):
    """Every communication round pays ``latency_ticks`` extra wall ticks.

    ``bytes_per_tick`` > 0 additionally charges ceil(wire/bandwidth) ticks
    per window for the bytes the transport layer measured (0 = the classic
    latency-only model).  ``dcn_bytes_per_tick`` > 0 prices the INTER-HOST
    tier (tier 1 of a hierarchical merge) at its own — typically much
    slower — bandwidth, reproducing the paper's cheap-ICI / slow-DCN
    regime on the wall-tick axis; 0 means tier 1 rides ``bytes_per_tick``
    like everything else."""

    latency_ticks: int = 1
    bytes_per_tick: int = 0
    dcn_bytes_per_tick: int = 0
    name = "fixed"

    def __post_init__(self):
        if self.latency_ticks < 0:
            raise ValueError(f"latency_ticks must be >= 0, "
                             f"got {self.latency_ticks}")
        if self.bytes_per_tick < 0:
            raise ValueError(f"bytes_per_tick must be >= 0, "
                             f"got {self.bytes_per_tick}")
        if self.dcn_bytes_per_tick < 0:
            raise ValueError(f"dcn_bytes_per_tick must be >= 0, "
                             f"got {self.dcn_bytes_per_tick}")

    def transfer_ticks(self, wire_bytes, *, tier=None):
        rate = self.bytes_per_tick
        if tier == 1 and self.dcn_bytes_per_tick > 0:
            rate = self.dcn_bytes_per_tick
        if rate <= 0 or wire_bytes <= 0:
            return 0
        return int(-(-wire_bytes // rate))

    def round_lengths(self, key, m, max_rounds, tau):
        del key
        return jnp.full((m, max_rounds), tau + self.latency_ticks, jnp.int32)

    def window_ticks(self, tau):
        return tau + self.latency_ticks


@dataclasses.dataclass(frozen=True)
class GeometricDelayNetwork(NetworkModel):
    """Paper Section 4: extra round ticks ~ Geometric(p_delay)."""

    p_delay: float = 0.5
    name = "geometric"

    def __post_init__(self):
        if not 0.0 < self.p_delay <= 1.0:
            raise ValueError(f"p_delay must be in (0, 1], got {self.p_delay}")

    def round_lengths(self, key, m, max_rounds, tau):
        # identical sampler to async_vq._round_lengths so that the sim
        # oracle and the mesh engine draw THE SAME delays from one key
        from repro.core.async_vq import _round_lengths
        return _round_lengths(key, (m, max_rounds), tau=tau,
                              p_delay=self.p_delay)

    def window_ticks(self, tau):
        # a barriered window waits for the slowest worker; charging the MEAN
        # extra delay keeps the sync/async comparison conservative
        mean_extra = (1.0 - self.p_delay) / self.p_delay
        return tau + int(round(mean_extra))

    def late_matrix(self, m, n_windows, tau, *, window0=0):
        """Geometric-tail stragglers for the quorum merge: a worker is late
        when its sampled extra delay exceeds a full window of slack
        (extra > tau) — the tail mass ``(1-p)^tau`` of the Section 4 cloud
        model.  Seeded by numpy Philox on ``(p_delay, window0)`` so the
        draw is identical on every device count and an elastic segment
        starting at ``window0`` redraws the same global windows."""
        import numpy as np
        # one Philox stream PER GLOBAL WINDOW: an elastic segment starting
        # at window0=k draws exactly the columns a full run drew for
        # windows k.. — segment boundaries cannot move the fault pattern
        u = np.stack([
            np.random.Generator(np.random.Philox(
                key=[int(self.p_delay * 1e6), window0 + w])).random(m)
            for w in range(n_windows)], axis=1)
        extra = np.floor(np.log(np.maximum(u, 1e-12))
                         / np.log1p(-min(self.p_delay, 1 - 1e-9)))
        return (np.maximum(extra, 0) > tau).astype(np.float32)


class Tier1BudgetController:
    """Host-side bandwidth-adaptive top-k: size ``tier1_frac`` to a wire
    budget per window.

    Closes the loop the accounting layers left open: ``CommLog`` records
    the MEASURED per-tier wire bytes each window moved and
    ``FixedLatencyNetwork.transfer_ticks`` prices the DCN tier — this
    controller reads both after every published chunk and widens/narrows
    the sparse tier's top-k fraction so the inter-host transfer stays on
    ``budget_ticks`` wall ticks per window.

    The step rule is a factor-2 ladder with hysteresis: halve ``frac``
    when the measured transfer overshoots the budget, double it when it
    undershoots ``low_water * budget_ticks`` (a free network never
    overshoots, so it relaxes to ``max_frac`` — send everything when the
    wire is free).  The ladder matters operationally: ``frac`` is
    trace-static (top-k count is a shape), so every distinct value is a
    distinct compiled program — a geometric ladder bounds the recompile
    set to ``log2(max_frac / min_frac)`` programs, which the executor's
    cache then reuses.

    Works on a ``HierarchicalTransport`` (adapts ``transport.tier1.frac``)
    or directly on a flat ``SparseTransport`` (adapts ``transport.frac``).
    """

    def __init__(self, network: NetworkModel, *, budget_ticks: int = 2,
                 min_frac: float = 1.0 / 1024.0, max_frac: float = 1.0,
                 low_water: float = 0.5):
        if budget_ticks < 1:
            raise ValueError(f"budget_ticks must be >= 1, got {budget_ticks}")
        if not 0.0 < min_frac <= max_frac <= 1.0:
            raise ValueError(
                f"need 0 < min_frac <= max_frac <= 1, got "
                f"({min_frac}, {max_frac})")
        if not 0.0 <= low_water < 1.0:
            raise ValueError(f"low_water must be in [0, 1), got {low_water}")
        self.network = network
        self.budget_ticks = budget_ticks
        self.min_frac = min_frac
        self.max_frac = max_frac
        self.low_water = low_water
        self.last_frac: float | None = None

    @staticmethod
    def _target(transport):
        """The object whose ``frac`` this controller owns, or None.  A
        ``QuantizedTransport`` decorator is transparent: the knob lives on
        its inner transport."""
        transport = getattr(transport, "inner", transport)
        tier1 = getattr(transport, "tier1", None)
        if tier1 is not None and hasattr(tier1, "frac"):
            return tier1
        if hasattr(transport, "frac"):
            return transport
        return None

    def update(self, transport, wire_per_window: float) -> float | None:
        """One control step from a chunk's measured tier-1 bytes/window;
        mutates the transport's frac and returns it (None: no sparse tier
        to adapt — dense tiers have no knob)."""
        target = self._target(transport)
        if target is None:
            return None
        frac = float(target.frac)
        ticks = self.network.transfer_ticks(wire_per_window, tier=1)
        if ticks > self.budget_ticks:
            frac = max(frac / 2.0, self.min_frac)
        elif ticks <= self.low_water * self.budget_ticks:
            frac = min(frac * 2.0, self.max_frac)
        target.frac = frac
        self.last_frac = frac
        return frac


_NETWORKS = {
    "instant": InstantNetwork,
    "fixed": FixedLatencyNetwork,
    "geometric": GeometricDelayNetwork,
}


def get_network(name: str, **kwargs) -> NetworkModel:
    """Factory: 'instant' | 'fixed' | 'geometric' (+ model kwargs)."""
    if name not in _NETWORKS:
        raise ValueError(
            f"unknown network model {name!r}; choose from {sorted(_NETWORKS)}")
    return _NETWORKS[name](**kwargs)
