"""``SimExecutor`` — the single-device simulations behind the Executor API.

Wraps ``core.schemes.scheme_average`` / ``scheme_delta`` (vmap over the
worker axis on one chip) and ``core.async_vq.scheme_async`` (tick-by-tick
eq.-9 simulation).  These are the numerical ORACLES the mesh backend is
tested against; the executor only adapts signatures and threads the
``NetworkModel`` draw into the async simulation.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import async_vq, schemes
from repro.core.schemes import SchemeResult
from repro.engine import api
from repro.engine.network import GeometricDelayNetwork, NetworkModel
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer


class SimExecutor:
    """Single-device oracle backend (jit/vmap simulation of M workers)."""

    name = "sim"

    def __init__(self, network: NetworkModel | None = None,
                 eval_every: int = 10, *, tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.network = network or GeometricDelayNetwork()
        self.eval_every = eval_every
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics

    def run(self, scheme: str, w0: jax.Array, data: jax.Array,
            eval_data: jax.Array, *, tau: int, eps0: float = 0.5,
            decay: float = 1.0, key: jax.Array | None = None) -> SchemeResult:
        api.validate_scheme(scheme)
        t_wall = time.perf_counter()
        with self.tracer.span("run", scheme=scheme, executor=self.name,
                              m=data.shape[0]):
            if scheme in ("average", "delta"):
                fn = (schemes.scheme_average if scheme == "average"
                      else schemes.scheme_delta)
                res = fn(w0, data, eval_data, tau=tau, eps0=eps0, decay=decay)
                # the oracles assume instant communications (ticks = k*tau);
                # restate wall time under this executor's NetworkModel so sim
                # and mesh curves share a time axis for any network
                wt = self.network.window_ticks(tau)
                if wt != tau:
                    res = SchemeResult(w_shared=res.w_shared,
                                       wall_ticks=(res.wall_ticks // tau) * wt,
                                       distortion=res.distortion)
            else:
                key = jax.random.PRNGKey(0) if key is None else key
                m, n, _ = data.shape
                lengths = self.network.round_lengths(key, m, n // tau + 2, tau)
                r = async_vq.scheme_async(w0, data, eval_data, key, tau=tau,
                                          eps0=eps0, decay=decay,
                                          eval_every=self.eval_every,
                                          lengths=lengths)
                res = SchemeResult(w_shared=r.w_shared,
                                   wall_ticks=r.wall_ticks,
                                   distortion=r.distortion)
        self._emit_obs(scheme, res, time.perf_counter() - t_wall)
        return res

    def _emit_obs(self, scheme: str, res: SchemeResult,
                  wall_s: float) -> None:
        """Distortion-over-ticks counters on one ``sim`` timeline track."""
        tr, mt = self.tracer, self.metrics
        if mt is not None:
            mt.histogram("run_wall_s", executor=self.name,
                         scheme=scheme).observe(wall_s)
            h = mt.histogram("distortion", scheme=scheme)
            for c in np.asarray(res.distortion):
                h.observe(float(c))
        if tr.enabled:
            ticks = np.asarray(res.wall_ticks)
            curve = np.asarray(res.distortion)
            for t, c in zip(ticks, curve):
                tr.counter("distortion", float(c), ts_us=float(t))
