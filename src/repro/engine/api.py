"""The ``Executor`` protocol — one API over every way to run the schemes.

An executor runs one of the paper's parallelization schemes over M worker
streams and returns the standard ``SchemeResult`` (final shared prototypes +
the wall-time distortion curve).  Three interchangeable backends:

  * ``SimExecutor``    (``engine.sim``)     — the single-device jit/vmap
    simulations in ``core.schemes`` / ``core.async_vq``; the oracles.
  * ``MeshExecutor``   (``engine.mesh``)    — one worker per JAX device on a
    real 1-D device mesh via shard_map + collectives; the headline backend.
  * ``ThreadExecutor`` (``engine.threads``) — the real-thread CloudDALVQ
    runtime in ``core.async_runtime`` (async_delta only).

Scheme names are shared across backends: 'average', 'delta', 'async_delta'
('sequential' is scheme_delta at M=1 and needs no executor).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax

from repro.core.schemes import SchemeResult

SCHEMES = ("average", "delta", "async_delta")


def validate_scheme(scheme: str) -> str:
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; choose from {SCHEMES}")
    return scheme


@runtime_checkable
class Executor(Protocol):
    """Runs a parallelization scheme over M worker streams."""

    name: str

    def run(self, scheme: str, w0: jax.Array, data: jax.Array,
            eval_data: jax.Array, *, tau: int, eps0: float = 0.5,
            decay: float = 1.0, key: jax.Array | None = None) -> SchemeResult:
        """data: (M, n, d) per-worker streams; eval_data: (M, n_eval, d).

        Returns ``SchemeResult`` with the distortion curve indexed by wall
        tick (``ThreadExecutor`` indexes by wall seconds — real threads have
        no tick clock)."""
        ...


def get_executor(name: str, **kwargs) -> Executor:
    """Factory: 'sim' | 'mesh' | 'thread' | 'elastic' (+ backend kwargs).

    'elastic' requires a ``schedule=`` kwarg (a ``ResizeSchedule``, a list of
    ``(window, new_m)`` pairs, or a ``"WINDOW:M,..."`` spec string).

    'mesh' and 'elastic' additionally accept ``transport=`` — a
    ``repro.comm`` transport name ('xla' | 'ring' | 'sparse' | 'hier') or
    instance — selecting how the reducing phases move their bytes; the
    executor's ``last_comm`` then reports the measured wire bytes of each
    run.  They also accept ``topology=`` (a ``repro.topology.Topology``):
    a hierarchical topology runs the schemes on the 2-D (hosts, workers)
    mesh — pair it with a ``HierarchicalTransport`` for per-tier merges —
    and makes elastic resizes move whole host groups."""
    if name == "sim":
        from repro.engine.sim import SimExecutor
        return SimExecutor(**kwargs)
    if name == "mesh":
        from repro.engine.mesh import MeshExecutor
        return MeshExecutor(**kwargs)
    if name == "thread":
        from repro.engine.threads import ThreadExecutor
        return ThreadExecutor(**kwargs)
    if name == "elastic":
        from repro.engine.elastic import ElasticMeshExecutor, ResizeSchedule
        schedule = kwargs.pop("schedule", None)
        if schedule is None:
            raise ValueError(
                "the elastic executor needs a schedule= kwarg "
                "(ResizeSchedule, [(window, new_m), ...], or 'WINDOW:M,...')")
        if isinstance(schedule, str):
            schedule = ResizeSchedule.parse(schedule)
        return ElasticMeshExecutor(schedule, **kwargs)
    raise ValueError(
        f"unknown executor {name!r}; choose from "
        f"('sim', 'mesh', 'thread', 'elastic')")
