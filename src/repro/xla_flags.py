"""Pre-jax process bootstrap helpers.

MUST be importable (and called) before jax first initializes — so this
module imports no jax.  Per https://github.com/google/jax/issues/17188 the
forced-host-device flag cannot be changed after backend init; every entry
point that wants an emulated CPU mesh calls ``force_host_devices()`` at
module top, before its ``import jax`` (the keras distribution_lib_test
idiom, centralized).
"""

from __future__ import annotations

import os


def force_host_devices(n: int = 8) -> None:
    """Ask XLA:CPU for ``n`` host devices unless the operator already chose.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``,
    preserving any other flags; a pre-existing device-count flag wins."""
    xla_flags = os.getenv("XLA_FLAGS") or ""
    if "xla_force_host_platform_device_count" not in xla_flags:
        os.environ["XLA_FLAGS"] = (
            f"{xla_flags} --xla_force_host_platform_device_count={n}".strip())
