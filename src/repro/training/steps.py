"""Train / serve step factories, including the paper's merge strategies.

Three granularities:

  * ``make_train_step``  — one synchronous SGD/Adam step; gradients are
    reduced across all DP axes implicitly by GSPMD (params replicated over
    DP => XLA inserts the all-reduce).  This is the STANDARD baseline.
  * ``make_window_step`` — one tau-step WINDOW with the paper's merge
    protocol across the ``merge_axis`` ('pod' on the multi-pod mesh):
      - AVERAGE      (paper eq. 3): w_srd = pmean(local w(tau))
      - DELTA        (paper eq. 8): w_srd = w0 - psum_i (w0 - w_i(tau))
      - ASYNC_DELTA  (paper eq. 9, TPU-idiomatic): the delta psum of window
        k-1 is applied at the END of window k, so the collective has no data
        dependency on window k's compute and XLA's latency-hiding scheduler
        overlaps it with the tau-step scan (the paper's lock-free reducer
        becomes a one-window-stale pipelined collective).
      - ALLREDUCE    : per-step psum over merge_axis inside the window
        (what the window buys you is measured against this).
    Implemented with shard_map manual over ``merge_axis`` and auto over the
    remaining mesh axes, so TP/FSDP sharding inside each pod is untouched.
  * ``make_serve_step`` / ``make_prefill_step`` — inference.
"""

from __future__ import annotations

import enum
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.engine import merge as merge_lib
from repro.models.api import get_api
from repro.models.common import ModelConfig
from repro.optim.optimizers import Optimizer, clip_by_global_norm


class Merge(enum.Enum):
    ALLREDUCE = "allreduce"
    AVERAGE = "average"          # paper eq. (3) — the scheme that does NOT scale
    DELTA = "delta"              # paper eq. (8)
    ASYNC_DELTA = "async_delta"  # paper eq. (9), pipelined-collective form
    DELTA_SPARSE = "delta_sparse"  # eq. (8) + top-k/error-feedback compression


# ---------------------------------------------------------------------------
# plain synchronous step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    *, clip: float = 1.0) -> Callable:
    api = get_api(cfg)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        loss, grads = jax.value_and_grad(api.loss_fn)(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = optimizer.update(
            grads, state["opt_state"], state["params"])
        new_state = {"params": params, "opt_state": opt_state,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(cfg: ModelConfig, optimizer: Optimizer,
                     key: jax.Array) -> dict:
    api = get_api(cfg)
    params = api.init(key)
    return {"params": params, "opt_state": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# paper-scheme window step
# ---------------------------------------------------------------------------

# displacement / merge tree algebra lives in repro.engine.merge so the LM
# window step and the VQ mesh engine share ONE implementation
_tree_sub = merge_lib.tree_sub_f32


def _tree_addcast(a, b, like):
    return jax.tree.map(
        lambda x, y, l: (x + y).astype(l.dtype), a, b, like)


def _sparse_allsum(leaf: jax.Array, residual: jax.Array, frac: float,
                   axis: str):
    """Top-k sparse cross-worker sum with error feedback (one leaf).

    Each worker keeps only its k largest-|.| entries of (delta + residual);
    the values+indices are all-gathered (wire bytes = M*k*8 instead of the
    dense N*4 — a real, HLO-visible reduction) and scatter-added locally.
    Returns (summed_dense, new_residual)."""
    flat = leaf.reshape(-1).astype(jnp.float32)
    full = flat + residual.reshape(-1)
    k = max(1, int(frac * full.size))
    _, idx = jax.lax.top_k(jnp.abs(full), k)
    vals = full[idx]
    kept = jnp.zeros_like(full).at[idx].set(vals)
    new_residual = (full - kept).reshape(leaf.shape)
    all_vals = jax.lax.all_gather(vals, axis)          # (M, k) — the wire
    all_idx = jax.lax.all_gather(idx, axis)            # (M, k)
    summed = jnp.zeros_like(full).at[all_idx.reshape(-1)].add(
        all_vals.reshape(-1))
    return summed.reshape(leaf.shape), new_residual


def make_window_step(cfg: ModelConfig, optimizer: Optimizer, mesh,
                     *, tau: int, merge: Merge, merge_axis: str = "pod",
                     clip: float = 1.0, compress_frac: float = 0.01
                     ) -> Callable:
    """Returns window_step(state, batches) -> (state, metrics).

    ``batches``: pytree whose leaves have shape (tau, global_batch, ...).
    ``state`` additionally carries ``delta_prev`` for ASYNC_DELTA (init with
    zeros_like(params)).
    """
    api = get_api(cfg)
    axis = merge_axis

    def _pmean_f32(tree):
        # collectives ride in f32: bf16 all-reduce promotion CHECK-fails in
        # XLA:CPU, and f32 reductions are what real runs use for grad sync
        return merge_lib.tree_pmean_f32(tree, axis)

    def local_step(state, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(state["params"], batch)
        if merge is Merge.ALLREDUCE:
            grads = _pmean_f32(grads)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = optimizer.update(
            grads, state["opt_state"], state["params"])
        return ({"params": params, "opt_state": opt_state,
                 "step": state["step"] + 1}, loss)

    def window_body(state, batches):
        w0 = state["params"]
        inner = {k: state[k] for k in ("params", "opt_state", "step")}
        inner, losses = jax.lax.scan(local_step, inner, batches)
        wl = inner["params"]
        out = dict(inner)

        if merge is Merge.AVERAGE:
            out["params"], _ = merge_lib.AverageMerge()(w0, wl, axis)
        elif merge is Merge.DELTA:
            out["params"], _ = merge_lib.DeltaMerge()(w0, wl, axis)  # eq. (8)
        elif merge is Merge.DELTA_SPARSE:
            delta = _tree_sub(w0, wl)
            flat_d, treedef = jax.tree.flatten(delta)
            flat_r = jax.tree.leaves(state["residual"])
            outs = [_sparse_allsum(d, r, compress_frac, axis)
                    for d, r in zip(flat_d, flat_r)]
            total = jax.tree.unflatten(treedef, [o[0] for o in outs])
            out["residual"] = jax.tree.unflatten(
                treedef, [o[1] for o in outs])
            out["params"] = jax.tree.map(
                lambda p0, d: (p0.astype(jnp.float32) - d).astype(p0.dtype),
                w0, total)
        elif merge is Merge.ASYNC_DELTA:
            # merge LAST window's deltas — no data dependency on this
            # window's scan, so the psum overlaps with compute.
            out["params"], out["delta_prev"] = merge_lib.AsyncDeltaMerge()(
                w0, wl, axis, state["delta_prev"])
        else:  # ALLREDUCE merged per-step already
            out["params"] = wl
        if merge in (Merge.AVERAGE, Merge.DELTA):
            # keep local moments except under the barriered schemes, where
            # consensus moments keep workers exchangeable (DESIGN.md §3)
            out["opt_state"] = _pmean_f32(inner["opt_state"])
        if "delta_prev" in state and "delta_prev" not in out:
            out["delta_prev"] = state["delta_prev"]
        if "residual" in state and "residual" not in out:
            out["residual"] = state["residual"]
        return out, {"loss": jnp.mean(losses)}

    def window_step(state, batches):
        # specs: everything unsharded on merge_axis except the batch dim;
        # the TP/FSDP axes stay under GSPMD (manual axes = {merge_axis} only)
        def batch_spec(leaf):
            return P(None, axis, *([None] * (leaf.ndim - 2)))

        in_specs = (
            jax.tree.map(lambda _: P(), state),
            jax.tree.map(batch_spec, batches),
        )
        out_specs = (jax.tree.map(lambda _: P(), state),
                     {"loss": P()})
        fn = shard_map(
            window_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=frozenset({axis}), check_vma=False)
        return fn(state, batches)

    return window_step


def init_window_state(cfg: ModelConfig, optimizer: Optimizer, key: jax.Array,
                      merge: Merge) -> dict:
    state = init_train_state(cfg, optimizer, key)
    if merge is Merge.ASYNC_DELTA:
        state["delta_prev"] = merge_lib.AsyncDeltaMerge().init_state(
            state["params"])
    if merge is Merge.DELTA_SPARSE:
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
    return state


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, *, quantized: bool = False) -> Callable:
    """Decode step.  With ``quantized=True`` the params argument is the
    int8 tree from ``models.quantization.quantize_tree`` — weights are
    dequantized inside the jit (fused into the consuming matmuls), halving
    the HBM weight traffic that dominates decode (§Perf it.9)."""
    api = get_api(cfg)

    def serve_step(params: dict, cache: dict, tokens: jax.Array):
        if quantized:
            from repro.models import quantization
            params = quantization.dequantize_tree(params)
        return api.decode_step(params, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, max_len: int | None = None
                      ) -> Callable:
    """Prefill = one forward over the prompt that ALSO fills the decode
    cache (per-layer K/V at [0, T); SSM conv tails + final state).
    Returns (last-position logits, cache ready for decode at cur_len=T)."""
    api = get_api(cfg)

    def prefill_step(params: dict, batch: dict):
        t = batch["tokens"].shape[1]
        return api.prefill(params, batch, max_len or t)

    return prefill_step
