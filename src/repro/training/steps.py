"""Train / serve step factories, including the paper's merge strategies.

Three granularities:

  * ``make_train_step``  — one synchronous SGD/Adam step; gradients are
    reduced across all DP axes implicitly by GSPMD (params replicated over
    DP => XLA inserts the all-reduce).  This is the STANDARD baseline.
  * ``make_window_step`` — one tau-step WINDOW with the paper's merge
    protocol across the ``merge_axis`` ('pod' on the multi-pod mesh):
      - AVERAGE      (paper eq. 3): w_srd = pmean(local w(tau))
      - DELTA        (paper eq. 8): w_srd = w0 - psum_i (w0 - w_i(tau))
      - ASYNC_DELTA  (paper eq. 9, TPU-idiomatic): the delta psum of window
        k-1 is applied at the END of window k, so the collective has no data
        dependency on window k's compute and XLA's latency-hiding scheduler
        overlaps it with the tau-step scan (the paper's lock-free reducer
        becomes a one-window-stale pipelined collective).
      - ALLREDUCE    : per-step psum over merge_axis inside the window
        (what the window buys you is measured against this).
    Implemented with shard_map manual over ``merge_axis`` and auto over the
    remaining mesh axes, so TP/FSDP sharding inside each pod is untouched.
  * ``make_serve_step`` / ``make_prefill_step`` — inference.
"""

from __future__ import annotations

import enum
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import shard_map
from repro.engine import merge as merge_lib
from repro.models.api import get_api
from repro.models.common import ModelConfig
from repro.optim.optimizers import Optimizer, clip_by_global_norm


class Merge(enum.Enum):
    ALLREDUCE = "allreduce"
    AVERAGE = "average"          # paper eq. (3) — the scheme that does NOT scale
    DELTA = "delta"              # paper eq. (8)
    ASYNC_DELTA = "async_delta"  # paper eq. (9), pipelined-collective form
    DELTA_SPARSE = "delta_sparse"  # eq. (8) + top-k/error-feedback compression


# ---------------------------------------------------------------------------
# plain synchronous step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    *, clip: float = 1.0) -> Callable:
    api = get_api(cfg)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        loss, grads = jax.value_and_grad(api.loss_fn)(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = optimizer.update(
            grads, state["opt_state"], state["params"])
        new_state = {"params": params, "opt_state": opt_state,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(cfg: ModelConfig, optimizer: Optimizer,
                     key: jax.Array) -> dict:
    api = get_api(cfg)
    params = api.init(key)
    return {"params": params, "opt_state": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# paper-scheme window step
# ---------------------------------------------------------------------------

def make_window_step(cfg: ModelConfig, optimizer: Optimizer, mesh,
                     *, tau: int, merge: Merge, merge_axis: str = "pod",
                     clip: float = 1.0, compress_frac: float = 0.01,
                     transport: "comm.Transport | str | None" = None
                     ) -> Callable:
    """Returns window_step(state, batches) -> (state, metrics).

    ``batches``: pytree whose leaves have shape (tau, global_batch, ...).
    ``state`` additionally carries ``delta_prev`` for ASYNC_DELTA (init with
    zeros_like(params)) and ``residual`` for DELTA_SPARSE.

    All cross-pod collectives ride ``transport`` (a ``repro.comm`` name or
    instance; dense XLA by default) — the same merge implementations the VQ
    mesh engine uses, so the f32 wire convention and the wire-byte
    accounting are defined exactly once.  DELTA_SPARSE is the shared
    ``SparseDeltaMerge`` (top-k + error feedback over ``SparseTransport``).
    """
    api = get_api(cfg)
    axis = merge_axis
    if transport == "sparse":
        # the string spelling picks up this step's compression knob; an
        # explicit instance keeps its own frac (SparseDeltaMerge rejects a
        # conflicting pair)
        transport = comm.get_transport("sparse", frac=compress_frac)
    tsp = comm.get_transport(transport if transport is not None else "xla")
    if tsp.stateful and merge is Merge.DELTA:
        raise ValueError(
            "Merge.DELTA over a stateful transport would drop the "
            "error-feedback residual every window (the window step only "
            "carries residual state for DELTA_SPARSE) — use "
            "Merge.DELTA_SPARSE instead")
    # strategy objects are built once; the traced window body closes over
    # them (the merge tree algebra is shared with the VQ mesh engine)
    _average = merge_lib.AverageMerge(tsp)
    _delta = merge_lib.DeltaMerge(tsp)
    _async = merge_lib.AsyncDeltaMerge(tsp)
    _sparse = merge_lib.SparseDeltaMerge(
        tsp if isinstance(tsp, comm.SparseTransport) else None,
        frac=None if isinstance(tsp, comm.SparseTransport)
        else compress_frac)

    def _pmean_f32(tree, *, calls=1, tag="merge"):
        # the f32 wire convention (bf16 all-reduce promotion CHECK-fails in
        # XLA:CPU) lives in the transport layer, defined once for all users
        return tsp.all_reduce(tree, axis, op="mean", calls=calls,
                              tag=tag)[0]

    def local_step(state, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(state["params"], batch)
        if merge is Merge.ALLREDUCE:
            grads = _pmean_f32(grads, calls=tau)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = optimizer.update(
            grads, state["opt_state"], state["params"])
        return ({"params": params, "opt_state": opt_state,
                 "step": state["step"] + 1}, loss)

    def window_body(state, batches):
        w0 = state["params"]
        inner = {k: state[k] for k in ("params", "opt_state", "step")}
        inner, losses = jax.lax.scan(local_step, inner, batches)
        wl = inner["params"]
        out = dict(inner)

        if merge is Merge.AVERAGE:
            out["params"], _ = _average(w0, wl, axis)
        elif merge is Merge.DELTA:
            out["params"], _ = _delta(w0, wl, axis)  # eq. (8)
        elif merge is Merge.DELTA_SPARSE:
            out["params"], out["residual"] = _sparse(
                w0, wl, axis, state["residual"])
        elif merge is Merge.ASYNC_DELTA:
            # merge LAST window's deltas — no data dependency on this
            # window's scan, so the collective overlaps with compute.
            out["params"], out["delta_prev"] = _async(
                w0, wl, axis, state["delta_prev"])
        else:  # ALLREDUCE merged per-step already
            out["params"] = wl
        if merge in (Merge.AVERAGE, Merge.DELTA):
            # keep local moments except under the barriered schemes, where
            # consensus moments keep workers exchangeable (DESIGN.md §3)
            out["opt_state"] = _pmean_f32(inner["opt_state"])
        if "delta_prev" in state and "delta_prev" not in out:
            out["delta_prev"] = state["delta_prev"]
        if "residual" in state and "residual" not in out:
            out["residual"] = state["residual"]
        return out, {"loss": jnp.mean(losses)}

    def window_step(state, batches):
        # specs: everything unsharded on merge_axis except the batch dim;
        # the TP/FSDP axes stay under GSPMD (manual axes = {merge_axis} only)
        def batch_spec(leaf):
            return P(None, axis, *([None] * (leaf.ndim - 2)))

        in_specs = (
            jax.tree.map(lambda _: P(), state),
            jax.tree.map(batch_spec, batches),
        )
        out_specs = (jax.tree.map(lambda _: P(), state),
                     {"loss": P()})
        fn = shard_map(
            window_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=frozenset({axis}), check_vma=False)
        return fn(state, batches)

    return window_step


def init_window_state(cfg: ModelConfig, optimizer: Optimizer, key: jax.Array,
                      merge: Merge,
                      transport: "comm.Transport | str | None" = None
                      ) -> dict:
    """Seed the window-step state.  ``transport`` must match the one given
    to ``make_window_step``: a stateful transport widens ASYNC_DELTA's
    ``delta_prev`` to the joint {own, comm} carry the strategy expects."""
    state = init_train_state(cfg, optimizer, key)
    tsp = (comm.get_transport(transport) if transport is not None else None)
    if merge is Merge.ASYNC_DELTA:
        state["delta_prev"] = merge_lib.AsyncDeltaMerge(tsp).init_state(
            state["params"])
    if merge is Merge.DELTA_SPARSE:
        # the error-feedback residual IS the sparse transport's state
        state["residual"] = merge_lib.SparseDeltaMerge(
            tsp if isinstance(tsp, comm.SparseTransport) else None
        ).init_state(state["params"])
    return state


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, *, quantized: bool = False) -> Callable:
    """Decode step.  With ``quantized=True`` the params argument is the
    int8 tree from ``models.quantization.quantize_tree`` — weights are
    dequantized inside the jit (fused into the consuming matmuls), halving
    the HBM weight traffic that dominates decode (§Perf it.9)."""
    api = get_api(cfg)

    def serve_step(params: dict, cache: dict, tokens: jax.Array):
        if quantized:
            from repro.models import quantization
            params = quantization.dequantize_tree(params)
        return api.decode_step(params, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, max_len: int | None = None
                      ) -> Callable:
    """Prefill = one forward over the prompt that ALSO fills the decode
    cache (per-layer K/V at [0, T); SSM conv tails + final state).
    Returns (last-position logits, cache ready for decode at cur_len=T)."""
    api = get_api(cfg)

    def prefill_step(params: dict, batch: dict):
        t = batch["tokens"].shape[1]
        return api.prefill(params, batch, max_len or t)

    return prefill_step
