"""GPipe-style pipeline parallelism over the ``pod`` axis (SPMD form).

The layer stack is split into S = |pod| stages; stage s holds layers
[s*L/S, (s+1)*L/S) — the stacked block leaves are simply sharded over 'pod'
on their leading L dim, so PP is a STORAGE layout plus this schedule, and
composes with the TP/FSDP sharding of the other axes (auto under the
shard_map).

Schedule: classic GPipe fill-drain over ``n_micro`` microbatches in
``n_micro + S - 1`` ticks.  Every tick each stage (i) picks its input — a
fresh microbatch on stage 0, the neighbor's output elsewhere — (ii) runs its
local layers (lax.scan), (iii) ``collective_permute``s the activation to the
next stage.  Backward falls out of jax.grad: the vjp of collective_permute
is the reverse permute, giving the standard backward-pipeline automatically.

Bubble fraction = (S-1)/(n_micro+S-1); the dry-run lowering
(EXPERIMENTS.md §Perf it.10) shows the activation-permute bytes replacing
the FSDP/TP weight traffic of the non-PP layout.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import comm, compat
from repro.models import blocks
from repro.models.common import ModelConfig, rms_norm

# loss-reduction collective rides the default dense transport so the comm
# grep stays clean: no raw collective call sites outside repro.comm
_COMM = comm.get_transport("xla")


def stage_param_specs(cfg: ModelConfig, base_specs: dict) -> dict:
    """PP layout: block leaves add 'pod' on the leading (layer) dim."""
    out = dict(base_specs)
    out["blocks"] = {
        name: P("pod", *spec) if len(spec) >= 0 else spec
        for name, spec in base_specs["blocks"].items()
    }

    def fix(name, spec):
        # spec for (L, ...) leaf: replace leading None with 'pod'
        rest = tuple(spec)[1:]
        return P("pod", *rest)

    out["blocks"] = {name: fix(name, spec)
                     for name, spec in base_specs["blocks"].items()}
    return out


def make_pp_loss_fn(cfg: ModelConfig, mesh: Mesh, *, n_micro: int
                    ) -> Callable:
    """Pipelined loss for the dense decoder family.

    params: the usual pytree with block leaves sharded P('pod', ...) on L.
    batch: {'tokens','labels'} with batch dim sharded over 'data' (auto).
    Requires cfg.family == 'dense' and n_layers % S == 0.
    """
    assert cfg.family == "dense", "PP demo covers the dense family"
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    s_stages = sizes["pod"]
    assert cfg.n_layers % s_stages == 0

    def body(params, batch):
        stage = jax.lax.axis_index("pod")
        blk = params["blocks"]          # local (L/S, ...) slices
        tokens, labels = batch["tokens"], batch["labels"]
        b, t = tokens.shape
        assert b % n_micro == 0
        mb = b // n_micro

        # replicated-in leaves ride the shard_map boundary in f32: their
        # backward cotangents psum over 'pod', and XLA:CPU's bf16
        # all-reduce promotion CHECK-fails (same workaround as moe_apply_ep)
        embed = params["embed"].astype(cfg.dtype)
        x_all = jnp.take(embed, tokens, axis=0)            # (B, T, D)
        micro = x_all.reshape(n_micro, mb, t, -1)

        def run_stage(x):
            def scan_fn(carry, p):
                return blocks_apply(p, carry), None

            def blocks_apply(p, x):
                x = x + blocks.attention_train(
                    cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps))
                x = x + blocks.swiglu(
                    {k: p[k] for k in ("w_gate", "w_up", "w_down")},
                    rms_norm(x, p["mlp_norm"], cfg.norm_eps))
                return x

            body_fn = jax.checkpoint(
                blocks_apply,
                policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = jax.lax.scan(
                lambda c, p: (body_fn(p, c), None), x, blk)
            return x

        n_ticks = n_micro + s_stages - 1
        perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]

        def tick_fn(carry, i):
            recv, outs = carry
            take = jnp.clip(i, 0, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(
                micro, take, axis=0, keepdims=False)
            x_in = jnp.where(stage == 0, fresh, recv)
            y = run_stage(x_in)
            sent = jax.lax.ppermute(y, "pod", perm)
            # last stage's output for microbatch (i - S + 1) is y at tick i
            out_idx = jnp.clip(i - (s_stages - 1), 0, n_micro - 1)
            valid = (i >= s_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, axis=0),
                lambda o: o, outs)
            return (sent, outs), None

        outs0 = jnp.zeros_like(micro)
        (_, outs), _ = jax.lax.scan(
            tick_fn, (jnp.zeros_like(micro[0]), outs0),
            jnp.arange(n_ticks))

        # only the LAST stage holds real activations: every stage computes
        # the (cheap relative to the stack) loss head on ITS buffer and a
        # masked psum selects the real one — no permutation needed.
        last = s_stages - 1
        x = outs.reshape(b, t, -1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = embed.T if cfg.tie_embeddings \
            else params["lm_head"].astype(cfg.dtype)
        logits = (x @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - gold)
        # only the last stage's ce is real; the masked cross-stage sum
        # selects it (scalar — negligible wire, tagged as instrumentation)
        return _COMM.all_reduce(jnp.where(stage == last, ce, 0.0), "pod",
                                op="sum", tag="eval")[0]

    blocks_spec = {  # leading L dim manual over 'pod'
        name: P("pod") for name in
        ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
         "attn_norm", "mlp_norm")
    }
    param_specs = {
        "embed": P(), "final_norm": P(), "blocks": blocks_spec,
    }
    # lm_head present when embeddings untied
    def loss(params, batch):
        pspec = dict(param_specs)
        params = dict(params)
        params["embed"] = params["embed"].astype(jnp.float32)
        if "lm_head" in params:
            pspec["lm_head"] = P()
            params["lm_head"] = params["lm_head"].astype(jnp.float32)
        fn = compat.shard_map(
            body, mesh,
            in_specs=(pspec, {"tokens": P(), "labels": P()}),
            out_specs=P(),
            axis_names=frozenset({"pod"}), check_vma=False)
        return fn(params, batch)

    return loss
