"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: ShapeDtypeStruct
inputs only (no allocation), ``jit(...).lower(...).compile()`` on 512
placeholder host devices, and extracts memory / cost / collective stats for
the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe_1b_7b \
        --shape train_4k [--multi-pod] [--merge delta --tau 10]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full 40-cell sweep
    PYTHONPATH=src python -m repro.launch.dryrun --comm  # scheme x transport
        # wire bytes: runs the engine suite through every repro.comm
        # transport and reports the MEASURED per-worker merge traffic from
        # the CommRecord stream (not a model)
"""

# MUST run before any other import: jax locks the device count on first init.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.distributed import hlo_analysis, roofline, sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.api import get_api  # noqa: E402
from repro.optim import optimizers  # noqa: E402
from repro.training import steps as steps_lib  # noqa: E402


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               merge: str = "none", tau: int = 10, seq_parallel: bool = True,
               quantized: bool = False):
    """Returns (lower_fn, mesh) — lower_fn() does the lower+compile."""
    from repro.models import common as model_common

    cfg = registry.get_config(arch_id)
    cell = next(s for s in registry.SHAPES if s.name == shape_name)
    ok, why = registry.cell_applicable(cfg, cell)
    if not ok:
        return None, why

    mesh = make_production_mesh(multi_pod=multi_pod)
    use_window = merge != "none" and multi_pod
    # activation sharding constraints (SP) target the mesh directly; inside
    # the shard_map window step constraints would name manual axes, so SP is
    # disabled there (the window lowering measures collectives, not memory).
    model_common.set_run_options(
        mesh=None if use_window else mesh,
        seq_parallel=seq_parallel)
    # FSDP is a TRAINING memory tool (opt-state sharding).  Serving reads
    # every param each step, so 'data'-sharded params would all-gather per
    # token: inference cells are TP-only (EXPERIMENTS.md §Perf it.6).
    use_fsdp = registry.uses_fsdp(arch_id) and cell.kind == "train"
    pspecs = sharding.param_specs(cfg, mesh, use_fsdp=use_fsdp)
    api = get_api(cfg)

    if cell.kind == "train":
        opt = optimizers.adamw(optimizers.cosine_schedule(3e-4))
        state_shapes = jax.eval_shape(
            lambda: steps_lib.init_train_state(
                cfg, opt, jax.random.PRNGKey(0)))
        opt_specs = sharding.opt_specs_like(pspecs, state_shapes["opt_state"])
        state_specs = {"params": pspecs, "opt_state": opt_specs, "step": P()}

        if merge != "none" and multi_pod:
            strategy = steps_lib.Merge(merge)
            step = steps_lib.make_window_step(
                cfg, opt, mesh, tau=tau, merge=strategy, merge_axis="pod")
            state_shapes = jax.eval_shape(
                lambda: steps_lib.init_window_state(
                    cfg, opt, jax.random.PRNGKey(0), strategy))
            state_specs = dict(state_specs)
            for extra in ("delta_prev", "residual"):
                if extra in state_shapes:
                    state_specs[extra] = pspecs
            batch = registry.input_specs(cfg, cell, tau=tau)
            bspecs = jax.tree.map(
                lambda s: P(None, *sharding.batch_specs(
                    cfg, mesh, {"x": jax.ShapeDtypeStruct(
                        s.shape[1:], s.dtype)})["x"]), batch)
        else:
            step = steps_lib.make_train_step(cfg, opt)
            batch = registry.input_specs(cfg, cell)
            bspecs = sharding.batch_specs(cfg, mesh, batch)

        in_shardings = (sharding.named(mesh, state_specs),
                        sharding.named(mesh, bspecs))
        out_shardings = (sharding.named(mesh, state_specs), None)

        def lower():
            with mesh:
                return jax.jit(
                    step, in_shardings=in_shardings,
                    out_shardings=out_shardings, donate_argnums=(0,),
                ).lower(state_shapes, batch)

        return lower, ""

    if cell.kind == "prefill":
        # real prefill: forward over the prompt AND the decode-cache fill
        step = steps_lib.make_prefill_step(cfg, max_len=cell.seq_len)
        batch = registry.input_specs(cfg, cell)
        bspecs = sharding.batch_specs(cfg, mesh, batch)
        param_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        cache_cell = registry.ShapeCell(
            cell.name, "decode", cell.seq_len, cell.global_batch)
        cspecs = sharding.cache_specs(
            cfg, mesh, registry.cache_shapes(cfg, cache_cell))
        in_shardings = (sharding.named(mesh, pspecs),
                        sharding.named(mesh, bspecs))
        out_shardings = (None, sharding.named(mesh, cspecs))

        def lower():
            with mesh:
                return jax.jit(
                    step, in_shardings=in_shardings,
                    out_shardings=out_shardings,
                ).lower(param_shapes, batch)

        return lower, ""

    # decode
    step = steps_lib.make_serve_step(cfg, quantized=quantized)
    batch = registry.input_specs(cfg, cell)
    cache = registry.cache_shapes(cfg, cell)
    cspecs = sharding.cache_specs(cfg, mesh, cache)
    bspecs = sharding.batch_specs(cfg, mesh, batch)
    param_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    if quantized:
        from repro.models import quantization
        param_shapes = jax.eval_shape(
            lambda p: quantization.quantize_tree(p), param_shapes)
        flat_q, td = jax.tree.flatten(
            param_shapes,
            is_leaf=lambda x: isinstance(x, quantization.QuantizedLeaf))
        flat_s = jax.tree.leaves(pspecs)
        pspecs = jax.tree.unflatten(td, [
            quantization.QuantizedLeaf(
                q=s, scale=P(*([None] * q.scale.ndim)), dtype=q.dtype)
            if isinstance(q, quantization.QuantizedLeaf) else s
            for q, s in zip(flat_q, flat_s)])
    in_shardings = (sharding.named(mesh, pspecs),
                    sharding.named(mesh, cspecs),
                    sharding.named(mesh, bspecs)["tokens"])
    out_shardings = (None, sharding.named(mesh, cspecs))

    def lower():
        with mesh:
            return jax.jit(
                step, in_shardings=in_shardings,
                out_shardings=out_shardings, donate_argnums=(1,),
            ).lower(param_shapes, cache, batch["tokens"])

    return lower, ""


def build_vq_cell(shape_name: str, *, multi_pod: bool, tau: int = 10):
    """The PAPER'S OWN workload at pod scale: distributed VQ over a sharded
    dataset.  Shapes: vq_stream (paper-faithful S2 window: per-worker
    sequential scans + delta psum) and vq_batch (MXU-optimal fused
    minibatch displacement).  kappa=16384, d=512 — production codebook
    scale (RQ-VAE-size); one worker per DP device."""
    from repro.core import dvq

    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([sizes[a] for a in ("pod", "data") if a in sizes]))
    kappa, d = 16384, 512
    if shape_name == "vq_stream":
        step = dvq.make_window_vq_step(tau=tau)
        z = jax.ShapeDtypeStruct((dp, tau, d), jnp.float32)
        z_spec = P(tuple(a for a in ("pod", "data") if a in sizes),
                   None, None)
    else:  # vq_batch
        step = dvq.make_minibatch_vq_step(use_kernel=False)
        batch = 1 << 20  # 1M points per step
        z = jax.ShapeDtypeStruct((batch, d), jnp.float32)
        _, z_sh = dvq.vq_shardings(mesh, kappa=kappa, d=d, batch=batch)
        z_spec = z_sh.spec
    w_sh, _ = dvq.vq_shardings(mesh, kappa=kappa, d=d, batch=1)
    w = jax.ShapeDtypeStruct((kappa, d), jnp.float32)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    in_shardings = (w_sh, NamedSharding(mesh, P()),
                    NamedSharding(mesh, z_spec))

    def lower():
        with mesh:
            return jax.jit(step, in_shardings=in_shardings,
                           donate_argnums=(0,)).lower(w, t, z)

    return lower, ""


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             merge: str = "none", tau: int = 10, verbose: bool = True,
             quantized: bool = False) -> dict:
    t0 = time.perf_counter()
    rec: dict = {"arch": arch_id, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "merge": merge}
    if quantized:
        rec["quantized"] = True
    if arch_id == "paper_vq":
        lower_fn, why = build_vq_cell(shape_name, multi_pod=multi_pod,
                                      tau=tau)
    else:
        lower_fn, why = build_cell(arch_id, shape_name, multi_pod=multi_pod,
                                   merge=merge, tau=tau,
                                   quantized=quantized)
    if lower_fn is None:
        rec["status"] = "skipped"
        rec["reason"] = why
        if verbose:
            print(f"SKIP {arch_id} x {shape_name}: {why}")
        return rec
    try:
        lowered = lower_fn()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = hlo_analysis.analyze_collectives(hlo)
        if arch_id == "paper_vq":
            n_dev = 512 if multi_pod else 256
            kappa, d = 16384, 512
            if shape_name == "vq_batch":
                flops = 4.0 * (1 << 20) * kappa * d / n_dev
                hbm = ((1 << 20) * d * 4 / n_dev + kappa * d * 4 * 3
                       / (16 if kappa % 16 == 0 else 1))
            else:
                dp = n_dev // 16
                flops = 4.0 * dp * tau * kappa * d / n_dev
                hbm = kappa * d * 4 * 3
            terms = {
                "t_compute": flops / roofline.PEAK_FLOPS,
                "t_memory": hbm / roofline.HBM_BW,
                "t_collective": coll["total_bytes"] / roofline.ICI_BW,
            }
            terms["dominant"] = max(
                ("compute", "memory", "collective"),
                key=lambda k: terms[f"t_{k}"])
            rec.update({
                "status": "ok",
                "compile_s": round(time.perf_counter() - t0, 1),
                "collectives": coll, "roofline": terms,
                "memory": {"peak_bytes": getattr(
                    mem, "peak_memory_in_bytes", 0)},
            })
            if verbose:
                print(f"OK   paper_vq x {shape_name} [{rec['mesh']}]"
                      f" compile={rec['compile_s']}s"
                      f" coll={coll['total_bytes']:.3e}B"
                      f" t=({terms['t_compute']:.6f},"
                      f"{terms['t_memory']:.6f},"
                      f"{terms['t_collective']:.6f})s"
                      f" dom={terms['dominant']}")
            return rec
        cfg = registry.get_config(arch_id)
        cell = next(s for s in registry.SHAPES if s.name == shape_name)
        # window steps lower tau local steps in one program: normalize the
        # collective term to per-step so cells are comparable
        per_step_div = tau if (merge != "none" and multi_pod) else 1
        terms = roofline.roofline_terms(
            cfg, cell, roofline.mesh_shape(multi_pod),
            coll["total_bytes"] / per_step_div)
        rec["per_step_divisor"] = per_step_div
        rec["t_collective_tpu_adjusted"] = (
            coll["tpu_adjusted_bytes"] / per_step_div / roofline.ICI_BW)
        rec.update({
            "status": "ok",
            "compile_s": round(time.perf_counter() - t0, 1),
            "cost_flops_bodyonce": float(cost.get("flops", 0.0)),
            "cost_bytes_bodyonce": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
            "roofline": terms,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            },
        })
        if verbose:
            gb = rec["memory"]["peak_bytes"] / 2**30
            print(f"OK   {arch_id} x {shape_name} [{rec['mesh']},"
                  f" merge={merge}] compile={rec['compile_s']}s"
                  f" coll={coll['total_bytes']:.3e}B"
                  f" dom={terms['dominant']}"
                  f" t=({terms['t_compute']:.4f},{terms['t_memory']:.4f},"
                  f"{terms['t_collective']:.4f})s"
                  f" mfu<={terms['mfu_bound']:.2f}"
                  f" peak={gb:.2f}GiB/dev")
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            print(f"FAIL {arch_id} x {shape_name} [{rec['mesh']}]: "
                  f"{rec['error'][:300]}")
    return rec


# ---------------------------------------------------------------------------
# scheme x transport comm suite (measured wire bytes)
# ---------------------------------------------------------------------------

def run_comm_suite(*, sparse_frac: float | None = None,
                   verbose: bool = True) -> list[dict]:
    """Run the engine suite through every transport and report the wire
    bytes the ``CommRecord`` stream MEASURED (trace-exact shapes, replayed
    per execution) — not the roofline model's estimate.

    ``sparse_frac`` defaults to k/kappa = 0.25 (k = kappa/4 entries kept of
    the kappa*d displacement), the ISSUE-4 acceptance point where the
    sparse wire must come in >= 4x under dense.  The sweep itself is the
    shared ``repro.comm.sweep`` (one definition for this report and the
    ``--suite comm`` CI gate).

    The flat scheme x transport table is followed by the hierarchical
    cells (2-host topology, per-tier intra/inter columns): sparse tier 1
    must cut the INTER-host wire >= 4x under the dense tier 1 at the same
    acceptance point — the ISSUE-5 bar, exit-coded alongside the flat one.
    """
    from repro.comm import sweep

    cells = sweep.run_comm_cells(sparse_frac=sparse_frac, repeats=0)
    dense_wire = {c["scheme"]: c["merge_wire_bytes"] for c in cells
                  if c["transport"] == "xla"}
    records: list[dict] = []
    for c in cells:
        rec = {"arch": "comm", "shape": c["scheme"],
               "mesh": f"{c['m']}x1", "merge": c["scheme"],
               "transport": c["transport"], "status": "ok", **{
                   k: c[k] for k in (
                       "m", "n", "d", "kappa", "tau", "compile_s",
                       "merge_wire_bytes", "merge_logical_bytes",
                       "collective_calls", "final_C")}}
        if c["transport"] == "sparse":
            rec["sparse_frac"] = c["sparse_frac"]
            rec["wire_reduction_vs_dense"] = (
                dense_wire.get(c["scheme"], 0) / c["merge_wire_bytes"]
                if c["merge_wire_bytes"] else float("inf"))
        records.append(rec)
        if verbose:
            extra = (f" reduction={rec['wire_reduction_vs_dense']:.2f}x"
                     if c["transport"] == "sparse" else "")
            print(f"COMM {c['scheme']:<12s} x {c['transport']:<6s} "
                  f"wire={c['merge_wire_bytes']:>10,}B "
                  f"logical={c['merge_logical_bytes']:>10,}B{extra}")

    hier = sweep.run_hier_cells(tier1_frac=sparse_frac, repeats=0)
    dense_inter = {c["scheme"]: c["tier1_wire_bytes"] for c in hier
                   if c["variant"] == "hier_dense"}
    for c in hier:
        if c["variant"] == "flat":
            continue
        rec = {"arch": "comm_hier", "shape": c["scheme"],
               "mesh": f"{c['hosts']}x{c['workers_per_host']}",
               "merge": c["scheme"], "transport": c["variant"],
               "status": "ok", **{k: c[k] for k in (
                   "m", "n", "d", "kappa", "tau", "compile_s", "hosts",
                   "workers_per_host", "merge_wire_bytes",
                   "tier0_wire_bytes", "tier1_wire_bytes", "final_C",
                   "bitmatch_flat")}}
        if c["variant"] == "hier_sparse":
            rec["tier1_frac"] = c["tier1_frac"]
            rec["inter_reduction_vs_dense"] = (
                dense_inter.get(c["scheme"], 0) / c["tier1_wire_bytes"]
                if c["tier1_wire_bytes"] else float("inf"))
        records.append(rec)
        if verbose:
            extra = (f" inter_reduction="
                     f"{rec['inter_reduction_vs_dense']:.2f}x"
                     if c["variant"] == "hier_sparse" else
                     f" bitmatch_flat={c['bitmatch_flat']}")
            print(f"HIER {c['scheme']:<12s} x {c['variant']:<12s} "
                  f"[{rec['mesh']}] intra={c['tier0_wire_bytes']:>9,}B "
                  f"inter={c['tier1_wire_bytes']:>9,}B{extra}")

    # adaptive cells: {fixed, dynamic} merge x {dense, bf16, int8} wire —
    # the dynamic merge must hold total (merge + probe) wire at or under
    # its fixed counterpart at every quant level, or the probe isn't
    # paying for itself
    adapt = sweep.run_adapt_cells(repeats=0)
    fixed_wire = {c["quant"]: c["total_wire_bytes"] for c in adapt
                  if c["merge"] == "fixed"}
    for c in adapt:
        rec = {"arch": "comm_adapt", "shape": "delta",
               "mesh": f"{c['m']}x1", "merge": c["merge"],
               "transport": c["quant"], "status": "ok", **{
                   k: c[k] for k in (
                       "m", "n", "d", "kappa", "tau", "quant", "thresh",
                       "compile_s", "merge_wire_bytes", "probe_wire_bytes",
                       "total_wire_bytes", "n_windows", "n_triggered",
                       "final_C")}}
        if c["merge"] == "dynamic":
            rec["wire_vs_fixed"] = (c["total_wire_bytes"]
                                    / max(fixed_wire[c["quant"]], 1))
        records.append(rec)
        if verbose:
            extra = (f" vs_fixed={rec['wire_vs_fixed']:.2f}x"
                     if c["merge"] == "dynamic" else "")
            print(f"ADPT {c['merge']:<8s} x {c['quant']:<6s} "
                  f"wire={c['total_wire_bytes']:>8,}B "
                  f"(merge {c['merge_wire_bytes']:,}B + probe "
                  f"{c['probe_wire_bytes']:,}B) "
                  f"trig={c['n_triggered']}/{c['n_windows']}{extra}")
    return records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + ["paper_vq"])
    ap.add_argument("--shape",
                    choices=[s.name for s in registry.SHAPES]
                    + ["vq_batch", "vq_stream"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--merge", default="none",
                    choices=["none", "allreduce", "average", "delta",
                             "async_delta", "delta_sparse"])
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--quantized", action="store_true",
                    help="int8 weight-only decode (decode cells only)")
    ap.add_argument("--comm", action="store_true",
                    help="engine comm suite: measured wire bytes per "
                         "scheme x transport (8-worker mesh)")
    ap.add_argument("--sparse-frac", type=float, default=None,
                    help="--comm: sparse transport keep-fraction "
                         "(default: k/kappa = 0.25, the acceptance point)")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    args = ap.parse_args(argv)

    if args.comm:
        results = run_comm_suite(sparse_frac=args.sparse_frac)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        keyf = lambda r: (r["arch"], r["shape"], r["mesh"],  # noqa: E731
                          r.get("merge", "none"), r.get("quantized", False),
                          r.get("transport", "none"))
        merged = {keyf(r): r for r in existing}
        for r in results:
            merged[keyf(r)] = r
        with open(args.out, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        # compression applies to displacement merges; 'average' ships means,
        # which ride dense on every transport (see comm.sparse docstring)
        worst = min((r["wire_reduction_vs_dense"] for r in results
                     if r.get("transport") == "sparse"
                     and r["merge"] != "average"), default=0.0)
        worst_inter = min((r["inter_reduction_vs_dense"] for r in results
                           if r.get("transport") == "hier_sparse"
                           and r["merge"] != "average"), default=0.0)
        # adaptive invariant: dynamic total wire <= fixed at every quant
        worst_adapt = max((r["wire_vs_fixed"] for r in results
                           if r["arch"] == "comm_adapt"
                           and r["merge"] == "dynamic"), default=0.0)
        print(f"\n{len(results)} comm cells; sparse-vs-dense merge-wire "
              f"reduction (min over displacement schemes) = {worst:.2f}x, "
              f"inter-host tier-1 reduction = {worst_inter:.2f}x "
              f"(acceptance bars: both >= 4x at k/kappa <= 0.25); "
              f"dynamic-vs-fixed wire (max over quant levels) = "
              f"{worst_adapt:.2f}x (bar: <= 1.0)")
        return 0 if (worst >= 4.0 and worst_inter >= 4.0
                     and 0.0 < worst_adapt <= 1.0) else 1

    cells = []
    if args.all:
        for arch in registry.ARCH_IDS:
            for cell in registry.SHAPES:
                cells.append((arch, cell.name))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            results.append(run_cell(arch, shape, multi_pod=mp,
                                    merge=args.merge, tau=args.tau,
                                    quantized=args.quantized))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    keyf = lambda r: (r["arch"], r["shape"], r["mesh"],  # noqa: E731
                      r.get("merge", "none"), r.get("quantized", False),
                      r.get("transport", "none"))
    merged = {keyf(r): r for r in existing}
    for r in results:
        merged[keyf(r)] = r
    with open(args.out, "w") as f:
        json.dump(list(merged.values()), f, indent=1)

    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(bad)} failed")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
