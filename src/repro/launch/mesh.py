"""Launch-layer mesh helpers — thin wrappers over ``repro.topology``.

The hardcoded production shapes that used to live here are gone: every
mesh in the repo is built by ``repro.topology`` (``Topology.make_mesh`` —
pods are the host tier, each group's workers split (data, model)), and
this module only keeps the historical import surface working.  Both
helpers stay FUNCTIONS (never module-level constants) so importing this
module touches no jax device state; the dry-run sets XLA_FLAGS before
first jax init to get 512 host devices.
"""

from __future__ import annotations

from repro.topology import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
