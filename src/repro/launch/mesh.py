"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
XLA_FLAGS before first jax init to get 512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
