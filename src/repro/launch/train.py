"""End-to-end training driver — LM training and the paper's VQ schemes.

LM mode (default):

    PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt [--resume]

VQ mode — the paper's workload through the ``repro.engine`` Executor API,
on any of the three backends:

    PYTHONPATH=src python -m repro.launch.train --mode vq \
        --executor mesh --scheme delta --workers 8 --tau 10 \
        [--network geometric --p-delay 0.5]

Hierarchical VQ — the paper's two-tier platform (cheap intra-host, slow
inter-host): ``--hosts 2`` splits the 8 workers into 2 host groups; tier-0
merges ride the dense ``--transport`` inside each group, tier-1 crosses
groups via ``--tier1-transport`` (sparse top-k by default) with per-tier
measured wire bytes:

    PYTHONPATH=src python -m repro.launch.train --mode vq --executor mesh \
        --workers 8 --hosts 2 [--tier1-transport sparse --tier1-frac 0.03]

Elastic VQ — the mesh run grows/shrinks its worker set mid-stream (a
resharding event per ``--resize`` entry, not a restart); with ``--ckpt-dir``
each resize checkpoints the shared prototypes, and ``--resume`` continues
from the latest resize point:

    PYTHONPATH=src python -m repro.launch.train --mode vq --executor mesh \
        --workers 8 --resize 20:4,40:8 [--ckpt-dir /tmp/ck] [--resume]

Adaptive communication — sync only when the codebooks have drifted, and
ship less when you do: ``--merge dynamic`` triggers the reducing phase on
measured divergence (``--divergence-thresh``, force-synced every
``--max-stale`` windows), ``--wire-quant int8`` quantizes the merge deltas
on the wire with error feedback, and ``--tier1-frac auto`` sizes the
sparse inter-host tier from measured bandwidth:

    PYTHONPATH=src python -m repro.launch.train --mode vq --executor mesh \
        --workers 8 --scheme delta --merge dynamic --divergence-thresh 5 \
        --wire-quant int8

Chaos VQ — seeded fault injection over any of the above: ``--chaos
"7:kill=2,slow=1,part=1"`` draws a deterministic kill/straggler/partition
schedule from seed 7, turns each death into an unscheduled elastic resize,
and rides the slow/partitioned workers through the straggler-tolerant
quorum merge (their deltas fold in late, damped by the stale-window rule):

    PYTHONPATH=src python -m repro.launch.train --mode vq --executor mesh \
        --workers 8 --scheme delta --chaos 7:kill=2,slow=1,part=1 \
        [--quorum-frac 0.6]

Runs on whatever devices exist (CPU smoke through full meshes): builds the
mesh, shards state via the same rules the dry-run proves out, streams the
deterministic synthetic pipeline, checkpoints asynchronously, and restarts
from the latest step when ``--resume`` is given (fault-tolerance path).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs import registry
from repro.data.pipeline import DataConfig, lm_batch
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models import common as model_common
from repro.optim import optimizers
from repro.training import steps as steps_lib


def run_vq(args) -> int:
    """The paper's schemes behind the engine's Executor API."""
    from repro import comm
    from repro.comm.sweep import acceptance_sparse_frac
    from repro.data import synthetic
    from repro.engine import get_executor, get_network
    from repro.obs import ExitFlush, MetricsRegistry, Profiler, Tracer
    from repro.topology import Topology

    # --trace records spans + counters for Perfetto; --metrics dumps the
    # registry as JSONL.  Either flag turns full instrumentation on (the
    # summary table needs the registry, the registry feeds on the tracer's
    # code paths), so one run can produce both artifacts.
    tracer = Tracer() if (args.trace or args.metrics) else None
    metrics = MetricsRegistry() if (args.trace or args.metrics) else None
    if args.profile and args.executor != "mesh":
        # attribution needs the compiled mesh program's HLO — sim replays
        # oracles, threads run eager python; neither has a program to parse
        print(f"error: --profile parses the compiled mesh program; got "
              f"--executor {args.executor}")
        return 2
    profiler = Profiler(metrics=metrics) if args.profile else None

    key = jax.random.PRNGKey(args.seed)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, args.workers, n=args.points,
                                      d=args.dim)
    eval_data = data[:, : min(1000, args.points)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, args.dim), args.kappa)

    net_kw = {}
    if args.network == "fixed":
        net_kw["latency_ticks"] = args.latency
    elif args.network == "geometric":
        net_kw["p_delay"] = args.p_delay
    network = get_network(args.network, **net_kw)
    if (args.transport != "xla" or args.hosts > 1) and args.executor != "mesh":
        # sim replays oracles on one device and threads move blobs in
        # process: neither has a collective for a transport to reroute
        print(f"error: --transport {args.transport} / --hosts {args.hosts} "
              f"needs --executor mesh (the sim/thread backends issue no "
              f"collectives)")
        return 2
    transport = comm.get_transport(
        args.transport,
        **({"frac": args.compress_frac} if args.transport == "sparse"
           else {}))
    tier1_auto = args.tier1_frac == "auto"
    topology = None
    if args.hosts > 1:
        # hierarchical platform: the flat transport becomes tier 0 (dense
        # intra-host), tier 1 crosses the host groups — sparse by default,
        # at the k/kappa = 0.25 acceptance point unless --tier1-frac says
        # otherwise (the paper's slow-DCN regime).  'auto' also starts at
        # the acceptance point; the bandwidth controller takes over from
        # there.
        if args.tier1_frac is None or tier1_auto:
            tier1_frac = acceptance_sparse_frac(args.kappa, args.dim)
        else:
            try:
                tier1_frac = float(args.tier1_frac)
            except ValueError:
                print(f"error: --tier1-frac must be a float or 'auto', "
                      f"got {args.tier1_frac!r}")
                return 2
        try:
            # build the tier-1 transport FIRST: a bad --tier1-frac should
            # report as a frac error even on a box with too few devices
            # for the worker mesh
            tier1 = (comm.get_transport("sparse", frac=tier1_frac)
                     if args.tier1_transport == "sparse"
                     else args.tier1_transport)
            topology = Topology.from_spec(args.workers, hosts=args.hosts)
            transport = comm.HierarchicalTransport(
                tier0=transport, tier1=tier1,
                host_axis=topology.host_axis,
                worker_axis=topology.worker_axis)
        except ValueError as e:  # bad tier-1 frac / hosts split
            print(f"error: {e}")
            return 2
    if args.wire_quant != "off":
        # quantized wire format decorates the WHOLE transport stack (flat
        # or hierarchical): deltas cross every link at the narrow width,
        # the error-feedback residual re-injects the rounding error
        if args.executor != "mesh":
            print(f"error: --wire-quant quantizes the mesh transport's "
                  f"collectives; got --executor {args.executor}")
            return 2
        transport = comm.get_transport("quant", inner=transport,
                                       mode=args.wire_quant)
    tier1_controller = None
    if tier1_auto:
        if args.executor != "mesh":
            print(f"error: --tier1-frac auto adapts the mesh transport's "
                  f"sparse tier; got --executor {args.executor}")
            return 2
        if args.hosts <= 1 and args.transport != "sparse":
            print("error: --tier1-frac auto needs a sparse tier to adapt "
                  "(--hosts > 1 with a sparse --tier1-transport, or a flat "
                  "--transport sparse)")
            return 2
        if args.resize or args.chaos:
            print("error: --tier1-frac auto is a plain-mesh feature; it "
                  "does not compose with --resize/--chaos")
            return 2
        from repro.engine import Tier1BudgetController
        tier1_controller = Tier1BudgetController(
            network, budget_ticks=args.tier1_budget_ticks)
    chaos = None
    if args.chaos:
        # seeded fault injection: parse the schedule against the run's
        # window count, wrap the network model so the executors see the
        # faults, and (below) go elastic if any worker dies
        from repro.engine import ChaosNetwork, ChaosSchedule
        if args.executor != "mesh":
            print(f"error: --chaos injects faults into the mesh executors; "
                  f"got --executor {args.executor}")
            return 2
        try:
            chaos = ChaosSchedule.from_spec(
                args.chaos, windows=args.points // args.tau, m=args.workers,
                hosts=args.hosts if args.hosts > 1 else 2)
        except ValueError as e:
            print(f"error: {e}")
            return 2
        network = ChaosNetwork(network, chaos, topology=topology)
        print(f"chaos: {chaos.describe()}")
    if args.resume and not args.resize:
        # only the elastic path has VQ resume state; a plain executor would
        # silently restart from scratch, which is not a resume
        print("error: --resume in VQ mode needs --resize (elastic runs "
              "checkpoint at resize events; plain runs have no VQ "
              "checkpoint to restore)")
        return 2
    # merge strategy: --chaos/--quorum imply the straggler-tolerant quorum
    # merge (an injected fault must not deadlock the barrier); --merge
    # dynamic opts into divergence-triggered syncs.  Both fold eq.-8
    # displacements, so both ride the delta scheme only.
    merge = args.merge
    if args.chaos or args.quorum:
        if merge == "dynamic":
            print("error: --merge dynamic conflicts with --chaos/--quorum "
                  "(faults ride the quorum merge's late matrix; the "
                  "dynamic merge has no lateness channel)")
            return 2
        merge = "quorum"
    if merge is not None and args.scheme != "delta":
        print(f"error: the {merge} merge folds eq.-8 displacements, so it "
              f"needs --scheme delta; got {args.scheme!r}")
        return 2
    if merge == "dynamic":
        if args.executor != "mesh":
            print(f"error: --merge dynamic runs the divergence probe "
                  f"inside the compiled mesh program; got --executor "
                  f"{args.executor}")
            return 2
        if args.resize:
            print("error: --merge dynamic does not compose with --resize "
                  "(the elastic path reshards quorum/plain merge state "
                  "only)")
            return 2
    ckpt = None
    needs_elastic = bool(args.resize) or (chaos is not None
                                          and chaos.kill_events)
    if needs_elastic:
        if args.executor != "mesh":
            print(f"error: --resize is a mesh-executor feature (elastic "
                  f"resharding of the device mesh); got --executor "
                  f"{args.executor}")
            return 2
        if args.resume and not args.ckpt_dir:
            print("error: --resume needs --ckpt-dir (the elastic resume "
                  "restores the latest resize checkpoint)")
            return 2
        if args.wire_quant != "off":
            print("error: --wire-quant does not compose with elastic "
                  "resizes (the error-feedback residual is per-worker "
                  "state the resharder does not carry across a resize)")
            return 2
        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        ex_name = "elastic"
        ex_kw = {"schedule": args.resize if args.resize else [],
                 "network": network,
                 "transport": transport, "topology": topology,
                 "checkpointer": ckpt, "resume": args.resume,
                 "chaos": chaos, "merge": merge,
                 "quorum_frac": args.quorum_frac}
    elif args.executor == "thread":
        # real threads have no tick clock: tick-based NetworkModels don't
        # apply, and silently dropping them would mislabel the run
        if args.network != "instant":
            print(f"error: --network {args.network} is tick-based; the "
                  f"thread backend models communication in seconds — use "
                  f"--comm-delay-s instead")
            return 2
        ex_name = args.executor
        ex_kw = {"duration_s": args.duration_s,
                 "comm_delay_s": args.comm_delay_s}
    else:
        ex_name = args.executor
        ex_kw = {"network": network}
        if args.executor == "mesh":
            ex_kw["transport"] = transport
            ex_kw["topology"] = topology
            if merge == "quorum":
                ex_kw["merge"] = merge
                ex_kw["quorum_frac"] = args.quorum_frac
            elif merge == "dynamic":
                ex_kw["merge"] = merge
                ex_kw["divergence_thresh"] = args.divergence_thresh
                ex_kw["max_stale"] = args.max_stale
            if tier1_controller is not None:
                ex_kw["tier1_controller"] = tier1_controller
    ex_kw["tracer"] = tracer
    ex_kw["metrics"] = metrics
    if profiler is not None:
        ex_kw["profiler"] = profiler
    try:
        executor = get_executor(ex_name, **ex_kw)
    except ValueError as e:  # bad resize spec
        print(f"error: {e}")
        return 2
    # arm the crash-path flush BEFORE the run: a chaos kill or Ctrl-C must
    # still leave the trace/metrics artifacts on disk (the happy path
    # flushes the same object, so they are written exactly once)
    flusher = None
    if args.trace or args.metrics:
        flusher = ExitFlush(
            tracer=tracer if args.trace else None,
            trace_path=args.trace or None,
            metrics=metrics if args.metrics else None,
            metrics_path=args.metrics or None,
            run=f"train-vq-{args.scheme}-{executor.name}",
            catch_sigterm=True)

    print(f"executor={executor.name} scheme={args.scheme} "
          f"M={args.workers} tau={args.tau} network={args.network} "
          f"transport={transport.name} devices={len(jax.devices())}"
          + (f" topology={topology.describe()}"
             f" tier1={args.tier1_transport}" if topology is not None
             else "")
          + (f" resize={args.resize}" if args.resize else ""))
    t0 = time.perf_counter()
    try:
        res = executor.run(args.scheme, w0, data, eval_data, tau=args.tau,
                           eps0=args.eps0, key=ka)
    except ValueError as e:  # bad scheme/mesh/shape/resume combination
        print(f"error: {e}")
        return 2
    jax.block_until_ready(res.w_shared)
    wall = time.perf_counter() - t0
    curve = np.asarray(res.distortion)
    ticks = np.asarray(res.wall_ticks)
    idx = np.unique(np.linspace(0, len(curve) - 1, 10).astype(int))
    unit = "s" if executor.name == "thread" else "ticks"
    for i in idx:
        print(f"  {unit} {float(ticks[i]):>8.1f}  C = {curve[i]:.5f}")
    for ev in getattr(executor, "resize_events", []):
        ck = (f" ckpt@{ev.checkpoint_step}"
              if ev.checkpoint_step is not None else "")
        print(f"  resize @window {ev.window}: M {ev.old_m} -> {ev.new_m} "
              f"(late points merged: {ev.late_points}, "
              f"{ev.wall_s * 1e3:.1f} ms{ck})")
    pts = args.workers * args.points
    print(f"done: C(final)={curve[-1]:.5f} in {wall:.2f}s wall "
          f"({wall / pts * 1e6:.2f} us/point over {pts} points)")
    last_comm = getattr(executor, "last_comm", None)
    if last_comm:
        merge_b = last_comm["by_tag"].get("merge", {"wire_bytes": 0,
                                                    "logical_bytes": 0})
        print(f"comm[{transport.name}]: merge wire "
              f"{merge_b['wire_bytes']:,} B / logical "
              f"{merge_b['logical_bytes']:,} B per worker "
              f"({last_comm['calls']} collective calls, measured)")
        for tier, t in sorted(merge_b.get("by_tier", {}).items()):
            label = "intra-host" if tier == 0 else "inter-host"
            print(f"  tier {tier} ({label}): wire {t['wire_bytes']:,} B "
                  f"/ logical {t['logical_bytes']:,} B per worker")
    if profiler is not None:
        print("profile (roofline attribution):")
        print(profiler.summary_table())
        profiler.export_json(args.profile)
        print(f"profile: {len(profiler.attributions)} run(s) -> "
              f"{args.profile} (render: python -m repro.obs.report "
              f"--profile {args.profile})")
    if metrics is not None:
        print("metrics:")
        print(metrics.summary_table())
    if flusher is not None:
        flusher.flush()
        if args.trace:
            print(f"trace: {len(tracer.spans())} spans -> {args.trace} "
                  f"(load at https://ui.perfetto.dev)")
        if args.metrics:
            print(f"metrics: appended -> {args.metrics}")
    if ckpt is not None:
        ckpt.wait()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "vq"), default="lm")
    ap.add_argument("--arch", default="granite_8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    # VQ-mode options (--mode vq): engine backend + paper hyperparameters
    ap.add_argument("--executor", choices=("sim", "mesh", "thread"),
                    default="sim")
    ap.add_argument("--scheme",
                    choices=("average", "delta", "async_delta"),
                    default="delta")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--points", type=int, default=2000,
                    help="data points per worker")
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--kappa", type=int, default=16)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--eps0", type=float, default=0.5)
    ap.add_argument("--network",
                    choices=("instant", "fixed", "geometric"),
                    default="instant")
    ap.add_argument("--transport", choices=("xla", "ring", "sparse"),
                    default="xla",
                    help="merge transport (mesh/elastic executors): dense "
                         "XLA collectives, Pallas ring all-reduce (TPU; "
                         "XLA fallback on CPU), or top-k/error-feedback "
                         "sparse")
    ap.add_argument("--compress-frac", type=float, default=0.01,
                    help="sparse transport: fraction of entries each "
                         "worker ships per merge")
    ap.add_argument("--hosts", type=int, default=1,
                    help="hierarchical topology: split the M workers into "
                         "this many host groups (M must divide evenly); "
                         "merges then run dense intra-host (tier 0, the "
                         "--transport choice) and --tier1-transport "
                         "inter-host (tier 1), with per-tier wire "
                         "accounting")
    ap.add_argument("--tier1-transport", choices=("xla", "ring", "sparse"),
                    default="sparse",
                    help="--hosts > 1: the inter-host (DCN) tier's "
                         "transport; sparse (top-k + error feedback) is "
                         "the paper's slow-link answer, xla the dense "
                         "bit-exact baseline")
    ap.add_argument("--tier1-frac", default=None,
                    help="sparse tier 1: keep-fraction of entries per "
                         "inter-host merge (default: the k/kappa = 0.25 "
                         "acceptance point), or 'auto' to size it from "
                         "measured bandwidth — a host-side controller "
                         "halves/doubles the fraction so the inter-host "
                         "transfer stays on --tier1-budget-ticks wall "
                         "ticks per window")
    ap.add_argument("--tier1-budget-ticks", type=int, default=2,
                    help="--tier1-frac auto: target wall ticks per window "
                         "for the tier-1 (DCN) transfer")
    ap.add_argument("--latency", type=int, default=1)
    ap.add_argument("--p-delay", type=float, default=0.5)
    ap.add_argument("--resize", default="",
                    help="elastic resize schedule 'WINDOW:M,...' (e.g. "
                         "'20:4,40:8'); mesh executor only")
    ap.add_argument("--chaos", default="",
                    metavar="SEED:SCHEDULE",
                    help="seeded fault injection, e.g. '7:kill=2,slow=1,"
                         "part=1' — draw that many worker deaths, "
                         "stragglers, and host-group partitions from SEED; "
                         "kills become unscheduled elastic resizes, "
                         "slow/partition ride the quorum merge's late "
                         "matrix; mesh executor + --scheme delta only")
    ap.add_argument("--quorum", action="store_true",
                    help="use the straggler-tolerant quorum merge even "
                         "without --chaos (delta scheme only)")
    ap.add_argument("--quorum-frac", type=float, default=0.6,
                    help="quorum merge: fraction of workers whose deltas "
                         "must arrive for the merge to apply (late deltas "
                         "fold in damped by the stale-window rule)")
    ap.add_argument("--merge", choices=("quorum", "dynamic"), default=None,
                    help="merge strategy override (delta scheme, mesh "
                         "executor): 'quorum' = the straggler-tolerant "
                         "merge (same as --quorum), 'dynamic' = "
                         "divergence-triggered merges — workers sync only "
                         "on windows where the measured codebook drift "
                         "crosses --divergence-thresh (Kamp-style dynamic "
                         "averaging), capped by --max-stale")
    ap.add_argument("--divergence-thresh", type=float, default=0.0,
                    help="--merge dynamic: global squared-drift threshold "
                         "that fires a sync; 0.0 syncs every window "
                         "(bitwise-identical to the plain delta merge)")
    ap.add_argument("--max-stale", type=int, default=8,
                    help="--merge dynamic: force a sync after this many "
                         "consecutive skipped windows (bounds the eq.-8 "
                         "staleness damping)")
    ap.add_argument("--wire-quant", choices=("off", "bf16", "int8"),
                    default="off",
                    help="quantize merge deltas on the wire (mesh "
                         "executor): bf16 halves, int8 quarters the merge "
                         "wire bytes, both with error-feedback residual so "
                         "the quantization error re-enters the next merge")
    ap.add_argument("--duration-s", type=float, default=2.0,
                    help="thread backend: wall seconds to run")
    ap.add_argument("--comm-delay-s", type=float, default=0.0,
                    help="thread backend: per-round comm latency (seconds)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="write a Chrome trace-event file (Perfetto): "
                         "per-worker window/compute spans, per-tier merge "
                         "spans, distortion + codebook-divergence counters")
    ap.add_argument("--metrics", default="", metavar="OUT.jsonl",
                    help="append the metrics registry (counters/gauges/"
                         "histograms) as JSONL, one object per metric")
    ap.add_argument("--profile", default="", metavar="PROF.json",
                    help="roofline-attribute the run (mesh executor only): "
                         "decompose measured per-window wall into analytic "
                         "compute/HBM terms, the compiled program's HLO "
                         "collective bytes, and the host residual; prints "
                         "the attribution table and writes the Profiler "
                         "export (render with repro.obs.report --profile)")
    ap.add_argument("--autotune", choices=("off", "cache", "search"),
                    default="cache",
                    help="Pallas tile selection: 'off' pins the legacy "
                         "(128, 128) tiles, 'cache' picks per shape from "
                         "the roofline model (memoized), 'search' also "
                         "times the top model candidates and keeps the "
                         "fastest")
    ap.add_argument("--autotune-cache", default="", metavar="TILES.json",
                    help="persist tuned tile configs to this JSON file "
                         "(also read at startup; keyed by shape AND device "
                         "kind, so a cache never leaks across accelerators)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.kernels import autotune
    autotune.set_mode(args.autotune)
    if args.autotune_cache:
        autotune.set_cache_path(args.autotune_cache)

    if args.mode == "vq":
        return run_vq(args)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    mesh = make_host_mesh(data=args.data_axis)
    model_common.set_run_options(mesh=mesh)
    print(f"arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.batch)
    opt = optimizers.adamw(optimizers.cosine_schedule(
        args.lr, warmup=20, total=args.steps))
    pspecs = sharding.param_specs(cfg, mesh, use_fsdp=False)
    step_fn = steps_lib.make_train_step(cfg, opt)

    state = steps_lib.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    state_specs = {
        "params": pspecs,
        "opt_state": sharding.opt_specs_like(pspecs, state["opt_state"]),
        "step": jax.sharding.PartitionSpec(),
    }
    state = jax.device_put(state, sharding.named(mesh, state_specs))
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, state,
                                 shardings=sharding.named(mesh, state_specs))
            start = latest
            print(f"resumed from step {start}")

    t0 = time.perf_counter()
    with mesh:
        for i in range(start, args.steps):
            batch = lm_batch(dcfg, i)  # step-indexed: restart-deterministic
            state, metrics = jit_step(state, batch)
            if (i + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                tps = ((i + 1 - start) * args.batch * args.seq_len
                       / (time.perf_counter() - t0))
                print(f"step {i + 1:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"tok/s {tps:,.0f}")
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save_async(i + 1, state)
    if ckpt:
        ckpt.wait()
    print(f"done: {args.steps - start} steps in {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
