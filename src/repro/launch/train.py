"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt [--resume] [--merge delta --tau 10]

Runs on whatever devices exist (CPU smoke through full meshes): builds the
mesh, shards state via the same rules the dry-run proves out, streams the
deterministic synthetic pipeline, checkpoints asynchronously, and restarts
from the latest step when ``--resume`` is given (fault-tolerance path).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs import registry
from repro.data.pipeline import DataConfig, lm_batch
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_api
from repro.models import common as model_common
from repro.optim import optimizers
from repro.training import steps as steps_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    mesh = make_host_mesh(data=args.data_axis)
    model_common.set_run_options(mesh=mesh)
    print(f"arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.batch)
    opt = optimizers.adamw(optimizers.cosine_schedule(
        args.lr, warmup=20, total=args.steps))
    pspecs = sharding.param_specs(cfg, mesh, use_fsdp=False)
    step_fn = steps_lib.make_train_step(cfg, opt)

    state = steps_lib.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    state_specs = {
        "params": pspecs,
        "opt_state": sharding.opt_specs_like(pspecs, state["opt_state"]),
        "step": jax.sharding.PartitionSpec(),
    }
    state = jax.device_put(state, sharding.named(mesh, state_specs))
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, state,
                                 shardings=sharding.named(mesh, state_specs))
            start = latest
            print(f"resumed from step {start}")

    t0 = time.time()
    with mesh:
        for i in range(start, args.steps):
            batch = lm_batch(dcfg, i)  # step-indexed: restart-deterministic
            state, metrics = jit_step(state, batch)
            if (i + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                tps = ((i + 1 - start) * args.batch * args.seq_len
                       / (time.time() - t0))
                print(f"step {i + 1:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"tok/s {tps:,.0f}")
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save_async(i + 1, state)
    if ckpt:
        ckpt.wait()
    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
