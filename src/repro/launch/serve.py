"""Serving drivers — the LM decode smoke and the VQ quantization service.

LM mode (default): continuous-batching style loop over request waves —
prefill each wave once, decode to completion, report throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \
        --waves 3 --batch 4 --prompt 16 --gen 16

VQ mode: the online quantization service end to end — a ``CodebookStore``
fed by a background training run (hot-swapping codebooks mid-load when
``--train-publish`` is set), a micro-batching ``QuantizeService`` over the
sharded lookup engine, and an open-loop load generator with the paper's
cloud arrival process:

    PYTHONPATH=src python -m repro.launch.serve --mode vq --requests 500 \
        --kappa 64 --dim 32 [--network geometric --p-delay 0.5] \
        [--train-publish] [--smoke]
"""

from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp


def run_vq(args) -> int:
    """Drive the quantization service: store -> service -> load -> report."""
    from repro.data import synthetic
    from repro.engine import (ElasticMeshExecutor, InstantNetwork,
                              ResizeSchedule, get_network)
    from repro.obs import MetricsRegistry, Tracer
    from repro.serve import (CodebookStore, QuantizeService, ShardedLookup,
                             run_load)

    tracer = Tracer() if (args.trace or args.metrics) else None
    metrics = MetricsRegistry() if (args.trace or args.metrics) else None
    if args.smoke:
        args.requests = min(args.requests, 100)
        args.points = min(args.points, 200)
        if args.train_publish:
            # stretch the smoke load across several training windows so the
            # monotonic-versions check actually sees hot swaps mid-load
            args.tick_ms = max(args.tick_ms, 4.0)
    key = jax.random.PRNGKey(args.seed)
    kd, kw, ka = jax.random.split(key, 3)
    n_dev = len(jax.devices())
    m_train = min(8, n_dev)
    data = synthetic.replicate_stream(kd, m_train, n=args.points, d=args.dim)
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, args.dim), args.kappa)

    net_kw = {}
    if args.network == "fixed":
        net_kw["latency_ticks"] = args.latency
    elif args.network == "geometric":
        net_kw["p_delay"] = args.p_delay
    network = get_network(args.network, **net_kw)

    store = CodebookStore(w0)
    lookup = ShardedLookup(n_devices=n_dev)
    plan = lookup.plan(args.kappa, args.dim)
    print(f"serve: devices={n_dev} plan={plan} "
          f"max_batch={lookup.n_shards * 128} "
          f"max_delay={args.max_delay_ms}ms network={args.network}"
          + (" train-publish" if args.train_publish else ""))

    trainer = None
    trainer_err: list[Exception] = []
    if args.train_publish:
        # a live elastic training run publishes into the store mid-load:
        # grow/shrink the worker set AND hot-swap the served codebook
        n_windows = args.points // args.tau
        schedule = ResizeSchedule(
            [(max(1, n_windows // 3), max(1, m_train // 2)),
             (max(2, 2 * n_windows // 3), m_train)])
        ex = ElasticMeshExecutor(schedule, network=InstantNetwork(),
                                 on_window=store.publisher(),
                                 publish_every=args.publish_every,
                                 tracer=tracer, metrics=metrics)
        eval_data = data[:, : min(100, args.points)]

        def train():
            try:
                ex.run("delta", w0, data, eval_data, tau=args.tau)
            except Exception as e:  # noqa: BLE001 — reported after the load
                trainer_err.append(e)

        trainer = threading.Thread(target=train, name="train-publish")

    t0 = time.perf_counter()
    with QuantizeService(store, lookup,
                         max_delay_s=args.max_delay_ms * 1e-3,
                         tracer=tracer, metrics=metrics) as service:
        if trainer is not None:
            trainer.start()
            # don't let the load race the trainer's compile: wait for the
            # first fresh publication so the requests actually overlap the
            # remaining hot-swaps (otherwise the monotonic-versions exit
            # check below would only ever see version 1)
            if not store.wait_for(2, timeout=300.0):
                print("error: trainer never published a codebook")
                return 1
        report = run_load(service, n_requests=args.requests, d=args.dim,
                          rows_per_request=args.rows, network=network,
                          tick_s=args.tick_ms * 1e-3, key=ka,
                          tracer=tracer, metrics=metrics)
        if trainer is not None:
            trainer.join()
    wall = time.perf_counter() - t0

    print(report.summary())
    st = service.stats
    print(f"flushes={st.flushes} (full={st.full_flushes} "
          f"deadline={st.deadline_flushes}) mean_fill={st.mean_fill:.1f} "
          f"rows/flush, padded_rows={st.padded_rows}")
    if trainer is not None:
        print(f"trainer published {store.version} codebook versions "
              f"(served {report.versions_min}..{report.versions_max}, "
              f"max staleness {report.staleness_max})")
    print(f"done in {wall:.2f}s wall")
    if metrics is not None:
        print("metrics:")
        print(metrics.summary_table())
    if args.trace:
        tracer.export_chrome(args.trace)
        print(f"trace: {len(tracer.spans())} spans -> {args.trace} "
              f"(load at https://ui.perfetto.dev)")
    if args.metrics:
        n_rows = metrics.dump_jsonl(args.metrics, run="serve-vq")
        print(f"metrics: {n_rows} rows appended -> {args.metrics}")
    if trainer_err:
        print(f"error: training thread failed: {trainer_err[0]}")
        return 1
    if report.failed:
        print(f"error: {report.failed} requests failed")
        return 1
    if not report.versions_monotonic:
        print("error: served codebook versions were not monotonic")
        return 1
    return 0


def run_lm(args) -> int:
    from repro.configs import registry
    from repro.launch.mesh import make_host_mesh
    from repro.models import common as model_common
    from repro.training import steps as steps_lib

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    mesh = make_host_mesh()
    model_common.set_run_options(mesh=mesh)
    from repro.models.api import get_api
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    max_len = args.prompt + args.gen
    prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len=max_len))
    serve = jax.jit(steps_lib.make_serve_step(cfg))

    total_tok, t0 = 0, time.perf_counter()
    with mesh:
        for wave in range(args.waves):
            prompts = jax.random.randint(
                jax.random.fold_in(key, wave),
                (args.batch, args.prompt), 0, cfg.vocab)
            batch = {"tokens": prompts}
            if cfg.family == "encdec":
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(key, 1000 + wave),
                    (args.batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
            if cfg.family == "vlm":
                batch["patch_embeds"] = jax.random.normal(
                    jax.random.fold_in(key, 2000 + wave),
                    (args.batch, cfg.img_tokens, cfg.d_model), cfg.dtype)
            logits, cache = prefill(params, batch)
            tok = jnp.argmax(logits.reshape(args.batch, -1), -1)[:, None]
            for _ in range(args.gen):
                logits, cache = serve(params, cache, tok)
                tok = jnp.argmax(logits[:, -1], -1)[:, None]
                total_tok += args.batch
            print(f"wave {wave}: generated {args.gen} tokens x "
                  f"{args.batch} requests")
    dt = time.perf_counter() - t0
    print(f"served {args.waves * args.batch} requests, "
          f"{total_tok} tokens in {dt:.1f}s ({total_tok / dt:,.0f} tok/s)")
    return 0


def main(argv=None) -> int:
    from repro.configs import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "vq"), default="lm")
    ap.add_argument("--arch", default="granite_8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    # VQ-mode options (--mode vq): service + load + optional live trainer
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--rows", type=int, default=1,
                    help="query vectors per request")
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--kappa", type=int, default=64)
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="micro-batcher flush deadline")
    ap.add_argument("--network",
                    choices=("instant", "fixed", "geometric"),
                    default="geometric",
                    help="arrival process (geometric = paper cloud model)")
    ap.add_argument("--latency", type=int, default=1)
    ap.add_argument("--p-delay", type=float, default=0.5)
    ap.add_argument("--tick-ms", type=float, default=0.05,
                    help="seconds per arrival tick (0 = saturating)")
    ap.add_argument("--train-publish", action="store_true",
                    help="run an elastic training in the background, "
                         "hot-swapping the served codebook at windows")
    ap.add_argument("--publish-every", type=int, default=2,
                    help="training windows per codebook publication")
    ap.add_argument("--points", type=int, default=400,
                    help="training points per worker (--train-publish)")
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="write a Chrome trace-event file (Perfetto): "
                         "flush spans, load spans, trainer windows")
    ap.add_argument("--metrics", default="", metavar="OUT.jsonl",
                    help="append the metrics registry (latency/fill/queue "
                         "histograms) as JSONL")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "vq":
        return run_vq(args)
    return run_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
