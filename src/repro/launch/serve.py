"""Batched serving driver: continuous-batching style loop over request
waves — prefill each wave once, decode to completion, report throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \
        --waves 3 --batch 4 --prompt 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.models import common as model_common
from repro.training import steps as steps_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    mesh = make_host_mesh()
    model_common.set_run_options(mesh=mesh)
    from repro.models.api import get_api
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    max_len = args.prompt + args.gen
    prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len=max_len))
    serve = jax.jit(steps_lib.make_serve_step(cfg))

    total_tok, t0 = 0, time.time()
    with mesh:
        for wave in range(args.waves):
            prompts = jax.random.randint(
                jax.random.fold_in(key, wave),
                (args.batch, args.prompt), 0, cfg.vocab)
            batch = {"tokens": prompts}
            if cfg.family == "encdec":
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(key, 1000 + wave),
                    (args.batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
            if cfg.family == "vlm":
                batch["patch_embeds"] = jax.random.normal(
                    jax.random.fold_in(key, 2000 + wave),
                    (args.batch, cfg.img_tokens, cfg.d_model), cfg.dtype)
            logits, cache = prefill(params, batch)
            tok = jnp.argmax(logits.reshape(args.batch, -1), -1)[:, None]
            for _ in range(args.gen):
                logits, cache = serve(params, cache, tok)
                tok = jnp.argmax(logits[:, -1], -1)[:, None]
                total_tok += args.batch
            print(f"wave {wave}: generated {args.gen} tokens x "
                  f"{args.batch} requests")
    dt = time.time() - t0
    print(f"served {args.waves * args.batch} requests, "
          f"{total_tok} tokens in {dt:.1f}s ({total_tok / dt:,.0f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
