"""Version compatibility shims for the jax API surface this repo uses.

The repo targets the modern ``jax.shard_map`` API (axis_names / check_vma);
older jax (< 0.5, e.g. the 0.4.37 toolchain baked into the CPU image) only
ships ``jax.experimental.shard_map.shard_map`` with the (auto / check_rep)
spelling.  ``repro.compat.shard_map`` presents the modern signature on both:

  * ``axis_names`` — the MANUAL axes.  On old jax the body runs manual over
    ALL mesh axes instead: partial-manual (``auto=...``) CHECK-fails inside
    0.4.37's GSPMD partitioner (``hlo_sharding_util.cc:
    IsManualSubgroup()``) on scanned bodies, so axes the caller wanted auto
    are simply replicated.  Same numerics, no GSPMD parallelism over those
    axes — an acceptable trade on the CPU fallback toolchain; new jax gets
    the real partial-manual lowering.
  * ``check_vma``  — maps to ``check_rep`` on old jax.

Everything that shard_maps (``training/steps.py``, ``training/pipeline.py``,
``models/blocks.py``, ``engine/mesh.py``) must import from here, never from
jax directly.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f: Callable, mesh: Any, in_specs: Any, out_specs: Any, *,
              axis_names: frozenset | set | None = None,
              check_vma: bool = True) -> Callable:
    """``jax.shard_map`` if present, else the experimental one, one spelling.

    ``axis_names``: the mesh axes the body is manual over (None = all).
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _legacy
    return _legacy(f, mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma, auto=frozenset())
