"""Sharded synthetic LM data pipeline.

Deterministic, stateless token stream: batch ``i`` is a pure function of
(seed, step) so restart-from-checkpoint replays the exact stream with no
stored iterator state — the fault-tolerance property real pipelines buy with
checkpointable readers, for free.

Tokens follow a Zipfian unigram distribution with a Markov bigram kick so the
CE loss has learnable structure (tests assert loss decreases).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _zipf_logits(vocab: int) -> jax.Array:
    return -jnp.log(jnp.arange(1, vocab + 1, dtype=jnp.float32))


def lm_batch(cfg: DataConfig, step: int | jax.Array) -> dict:
    """One (tokens, labels) batch; labels are next-token shifted."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    logits = _zipf_logits(cfg.vocab)
    base = jax.random.categorical(
        k1, logits, shape=(cfg.global_batch, cfg.seq_len + 1))
    # Markov kick: with p=0.5 the next token repeats (token+1) mod V —
    # a simple learnable bigram structure.
    flip = jax.random.bernoulli(k2, 0.5, base.shape)
    shifted = jnp.roll(base, 1, axis=1)
    stream = jnp.where(flip, (shifted + 1) % cfg.vocab, base)
    return {"tokens": stream[:, :-1].astype(jnp.int32),
            "labels": stream[:, 1:].astype(jnp.int32)}


def vq_batch(cfg: DataConfig, step: int | jax.Array, *, d: int,
             n_centers: int = 10, noise: float = 0.05) -> jax.Array:
    """(global_batch, d) mixture samples for the VQ trainer (same generator
    family as repro.data.synthetic, streamed)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    ka, kn = jax.random.split(key)
    centers = jax.random.uniform(
        jax.random.PRNGKey(cfg.seed + 7919), (n_centers, d))
    assign = jax.random.randint(ka, (cfg.global_batch,), 0, n_centers)
    return centers[assign] + noise * jax.random.normal(
        kn, (cfg.global_batch, d))
