"""Synthetic data generators.

``mixture_data`` follows the family used in the paper's experiments (Patra's
thesis Section 4.2: random centers with local noise, uniformly scattered mass):
an isotropic Gaussian mixture over ``n_centers`` uniform random centers in
``[0, 1]^d``.  The paper notes its conclusions are "more sensitive to the loss
function smoothness and convexity than to the data choice" — this generator
reproduces exactly that non-smooth, non-convex quantization landscape.

``split_workers`` shards a stream across M workers the way the paper does
(dataset split among the local memories of the computing instances).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mixture_data(key: jax.Array, *, n: int, d: int, n_centers: int = 10,
                 noise: float = 0.05, dtype=jnp.float32) -> jax.Array:
    """(n, d) samples from a uniform-center isotropic Gaussian mixture."""
    kc, ka, kn = jax.random.split(key, 3)
    centers = jax.random.uniform(kc, (n_centers, d), dtype=dtype)
    assign = jax.random.randint(ka, (n,), 0, n_centers)
    eps = noise * jax.random.normal(kn, (n, d), dtype=dtype)
    return centers[assign] + eps


def split_workers(data: jax.Array, m: int) -> jax.Array:
    """(n, d) -> (m, n // m, d): disjoint per-worker streams (paper setup)."""
    n = data.shape[0] // m * m
    return data[:n].reshape(m, -1, data.shape[-1])


def replicate_stream(key: jax.Array, m: int, *, n: int, d: int,
                     **kw) -> jax.Array:
    """(m, n, d): m i.i.d. streams of length n from the same mixture.

    Matches the paper's speed-up experiments where every worker owns n local
    points (total data grows with M).
    """
    keys = jax.random.split(key, m + 1)
    centers_key = keys[0]
    # all workers draw from the SAME mixture: fix the centers across workers
    d_ = d

    def one(k):
        ka, kn = jax.random.split(k)
        kc = centers_key
        n_centers = kw.get("n_centers", 10)
        noise = kw.get("noise", 0.05)
        centers = jax.random.uniform(kc, (n_centers, d_))
        assign = jax.random.randint(ka, (n,), 0, n_centers)
        eps = noise * jax.random.normal(kn, (n, d_))
        return centers[assign] + eps

    return jax.vmap(one)(keys[1:])


def kmeanspp_init(key: jax.Array, data: jax.Array, kappa: int) -> jax.Array:
    """k-means++ style initialization used for w(0): sample kappa points."""
    idx = jax.random.choice(key, data.shape[0], (kappa,), replace=False)
    return data[idx]
