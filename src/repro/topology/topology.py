"""First-class platform topology — tiers of device groups.

The paper's final scheme exists because the platform it ran on was
HIERARCHICAL: intra-machine communication on Azure was cheap, inter-machine
communication slow and synchronization costly.  Until now the engine
modeled a flat ``workers`` axis; this module makes the two-tier shape a
first-class object that every mesh-building layer consumes:

  * ``Topology`` — a ``(hosts, workers_per_host)`` device grid.  Tier 0 is
    the worker axis inside one host group (ICI-class links, dense merges);
    tier 1 is the host axis across groups (DCN-class links, where the
    sparse/delayed merges of Kamp et al.'s periodic-averaging shape and
    Patra's staleness-tolerant analysis pay off).
  * ``Topology.make_mesh()`` — the ONLY place in ``src/repro`` that turns
    a device grid into a ``jax.sharding.Mesh`` (a CI test pins this: no
    module outside ``src/repro/topology/`` constructs a mesh directly).
    ``hosts == 1`` builds the 1-D flat mesh the engine has always used, so
    the degenerate topology is bit-identical to the pre-topology path.
  * constructors — ``detect()`` groups real ``jax.devices()`` by process
    boundary (multi-host runs); ``simulate(hosts=H)`` partitions the
    forced-host-platform devices into H groups (the CI story: a 2x4
    hierarchical run on 8 forced CPU devices compiles the same SPMD
    program an actual 2-host x 4-chip deployment runs).

The LM production meshes live here too (``make_production_mesh`` /
``make_host_mesh``): pods are the host tier and each group's workers split
into (data, model) via ``make_mesh(model=...)`` — the old hardcoded
``(16, 16)`` shapes in ``launch/mesh.py`` are gone.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh

#: TP width of one production worker group (the PR-1 era (16, 16) grid,
#: now derived through ``Topology.make_mesh(model=...)`` instead of being
#: hardcoded at the launch layer).
PRODUCTION_MODEL = 16
#: DP workers per pod in the production grid.
PRODUCTION_DATA = 16


def grid_mesh(devices: np.ndarray, axes: tuple[str, ...]) -> Mesh:
    """The single raw ``Mesh`` constructor in ``src/repro``.

    Everything else — worker meshes, hierarchical meshes, LM production
    meshes — goes through a ``Topology`` (or this helper for legacy grid
    shapes), so there is exactly one place where device order is decided.
    """
    devices = np.asarray(devices)
    if devices.ndim != len(axes):
        raise ValueError(
            f"device grid rank {devices.ndim} != {len(axes)} axes {axes}")
    if any(not name for name in axes):
        raise ValueError(f"mesh axis names must be non-empty, got {axes}")
    return Mesh(devices, axes)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Tiers of device groups: ``hosts`` groups of ``workers_per_host``.

    ``device_grid`` is the (hosts, workers_per_host) object array of jax
    devices; row h is host group h.  A valid topology PARTITIONS its
    devices: every device appears exactly once (checked), and all groups
    are the same size (rectangularity of the grid).
    """

    device_grid: np.ndarray
    host_axis: str = "hosts"
    worker_axis: str = "workers"

    def __post_init__(self):
        grid = np.asarray(self.device_grid, dtype=object)
        object.__setattr__(self, "device_grid", grid)
        if not self.host_axis or not self.worker_axis:
            raise ValueError(
                f"topology axis names must be non-empty, got "
                f"({self.host_axis!r}, {self.worker_axis!r})")
        if self.host_axis == self.worker_axis:
            raise ValueError(
                f"host and worker axes must be distinct, both are "
                f"{self.host_axis!r}")
        if grid.ndim != 2 or grid.size == 0:
            raise ValueError(
                f"device grid must be a non-empty (hosts, workers_per_host) "
                f"array, got shape {grid.shape}")
        ids = [getattr(d, "id", d) for d in grid.reshape(-1)]
        if len(set(ids)) != len(ids):
            raise ValueError(
                "topology device groups must partition the devices — some "
                "device appears in more than one slot")

    # -- shape ---------------------------------------------------------------

    @property
    def hosts(self) -> int:
        return int(self.device_grid.shape[0])

    @property
    def workers_per_host(self) -> int:
        return int(self.device_grid.shape[1])

    @property
    def total_workers(self) -> int:
        return int(self.device_grid.size)

    @property
    def is_flat(self) -> bool:
        """One host group: today's flat worker axis, bit-identical."""
        return self.hosts == 1

    @property
    def axes(self) -> tuple[str, ...]:
        """Mesh axis names, outermost first."""
        if self.is_flat:
            return (self.worker_axis,)
        return (self.host_axis, self.worker_axis)

    @property
    def spec(self):
        """The ``PartitionSpec`` entry sharding a leading worker dim: the
        bare worker axis when flat, the (host, worker) tuple when not."""
        if self.is_flat:
            return self.worker_axis
        return (self.host_axis, self.worker_axis)

    @property
    def manual_axes(self) -> frozenset[str]:
        return frozenset(self.axes)

    def describe(self) -> str:
        return f"{self.hosts}x{self.workers_per_host}"

    def group_of(self, worker: int) -> int:
        """Host group owning flat worker index ``worker`` (row-major)."""
        if not 0 <= worker < self.total_workers:
            raise ValueError(f"worker {worker} outside 0..{self.total_workers - 1}")
        return worker // self.workers_per_host

    def group_members(self, host: int) -> range:
        """Flat worker indices living on host group ``host`` — the inverse
        of ``group_of``, e.g. the workers a tier-1 partition takes down."""
        if not 0 <= host < self.hosts:
            raise ValueError(f"host {host} outside 0..{self.hosts - 1}")
        return range(host * self.workers_per_host,
                     (host + 1) * self.workers_per_host)

    # -- mesh construction ---------------------------------------------------

    def make_mesh(self, *, model: int | None = None,
                  data_axis: str = "data",
                  model_axis: str = "model") -> Mesh:
        """Build the device mesh for this topology.

        ``model=None`` (the engine form): a flat topology builds the 1-D
        ``(worker_axis,)`` mesh (bit-identical to the pre-topology path);
        a hierarchical one builds the 2-D ``(host_axis, worker_axis)``
        grid, row-major, so the joint collective group enumerates devices
        in exactly the flat order — the property the dense tier-1 merge's
        bit-for-bit acceptance test rides on.

        ``model=k`` (the LM form, k >= 1): each host group's workers split
        into ``(data, model)`` — a flat topology yields ``(data, model)``,
        a multi-pod one ``(host_axis, data, model)``.  This is where the
        production meshes come from (``make_production_mesh``).
        """
        if model is None:
            if self.is_flat:
                return grid_mesh(self.device_grid[0], (self.worker_axis,))
            return grid_mesh(self.device_grid, (self.host_axis,
                                                self.worker_axis))
        if model < 1:
            raise ValueError(f"model axis size must be >= 1, got {model}")
        if self.workers_per_host % model:
            raise ValueError(
                f"model={model} must divide workers_per_host="
                f"{self.workers_per_host}")
        grid = self.device_grid.reshape(
            self.hosts, self.workers_per_host // model, model)
        if self.is_flat:
            return grid_mesh(grid[0], (data_axis, model_axis))
        return grid_mesh(grid, (self.host_axis, data_axis, model_axis))

    # -- constructors --------------------------------------------------------

    @classmethod
    def flat(cls, m: int, *, worker_axis: str = "workers",
             host_axis: str = "hosts") -> "Topology":
        """1 x m: the classic flat worker axis over the first m devices."""
        return cls.simulate(1, m, worker_axis=worker_axis,
                            host_axis=host_axis)

    @classmethod
    def simulate(cls, hosts: int, workers_per_host: int, *,
                 host_axis: str = "hosts",
                 worker_axis: str = "workers") -> "Topology":
        """Partition the available devices into ``hosts`` contiguous groups
        of ``workers_per_host`` — the CI story for hierarchical runs on a
        forced-host-platform device count."""
        if hosts < 1 or workers_per_host < 1:
            raise ValueError(
                f"need hosts >= 1 and workers_per_host >= 1, got "
                f"{hosts}x{workers_per_host}")
        devices = jax.devices()
        need = hosts * workers_per_host
        if need > len(devices):
            raise ValueError(
                f"need 1 <= M <= {len(devices)} devices for a worker mesh, "
                f"got M={need} ({hosts}x{workers_per_host}) "
                f"(hint: --xla_force_host_platform_device_count)")
        grid = np.asarray(devices[:need], dtype=object).reshape(
            hosts, workers_per_host)
        return cls(grid, host_axis=host_axis, worker_axis=worker_axis)

    @classmethod
    def detect(cls, *, host_axis: str = "hosts",
               worker_axis: str = "workers") -> "Topology":
        """Real platform shape: group ``jax.devices()`` by process index.

        On a genuine multi-host mesh (``jax.distributed.initialize``) the
        process boundary IS the host boundary; a single-process run (every
        CPU/forced-host leg) detects as one flat group.  Ragged groups
        (hosts with different device counts) are rejected — the engine's
        one-worker-per-device data split needs a rectangular grid.
        """
        devices = jax.devices()
        by_proc: dict[int, list] = {}
        for d in devices:
            by_proc.setdefault(getattr(d, "process_index", 0), []).append(d)
        sizes = {len(v) for v in by_proc.values()}
        if len(sizes) != 1:
            raise ValueError(
                f"ragged host groups {sorted((k, len(v)) for k, v in by_proc.items())} "
                f"— the topology needs the same device count per host")
        rows = [by_proc[k] for k in sorted(by_proc)]
        grid = np.asarray(rows, dtype=object)
        return cls(grid, host_axis=host_axis, worker_axis=worker_axis)

    @classmethod
    def from_spec(cls, m: int, hosts: int | None = None, *,
                  host_axis: str = "hosts",
                  worker_axis: str = "workers") -> "Topology":
        """``m`` total workers split over ``hosts`` groups (None/1 = flat).

        The ``--hosts H`` CLI form: M must divide into H equal host groups
        (the partition invariant), so ``--workers 8 --hosts 2`` is a 2x4
        topology and ``--workers 8 --hosts 3`` is an error, not a silent
        rounding.
        """
        if hosts is None or hosts == 1:
            return cls.flat(m, worker_axis=worker_axis, host_axis=host_axis)
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        if m % hosts:
            raise ValueError(
                f"M={m} workers cannot split into {hosts} equal host "
                f"groups — the topology must partition the workers")
        return cls.simulate(hosts, m // hosts, host_axis=host_axis,
                            worker_axis=worker_axis)


# ---------------------------------------------------------------------------
# mesh helpers absorbed from launch/mesh.py and engine/mesh.py
# ---------------------------------------------------------------------------

def make_worker_mesh(m: int, axis: str = "workers") -> Mesh:
    """1-D mesh over the first ``m`` available devices (the engine's flat
    worker mesh, now built through ``Topology.flat``)."""
    if not axis:
        raise ValueError("mesh axis name must be a non-empty string")
    return Topology.flat(m, worker_axis=axis).make_mesh()


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Production LM mesh from the platform topology: pods are the host
    tier, each pod's workers split (data, model) = (16, 16).

    A FUNCTION (never a module-level constant) so importing this module
    touches no jax device state; the dry-run sets XLA_FLAGS before first
    jax init to get 512 host devices.
    """
    topo = Topology.simulate(2 if multi_pod else 1,
                             PRODUCTION_DATA * PRODUCTION_MODEL,
                             host_axis="pod")
    return topo.make_mesh(model=PRODUCTION_MODEL)


def make_host_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Small (data, model) mesh over whatever devices exist (tests / CPU
    smoke runs), clamped like the old launch-layer helper."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return Topology.flat(data * model).make_mesh(model=model)
