"""Platform topology layer — tiers of device groups, and every mesh.

``Topology`` models the paper's hierarchical platform (cheap intra-host
links, slow inter-host links) as a ``(hosts, workers_per_host)`` device
grid; ``Topology.make_mesh`` is the only mesh constructor in ``src/repro``
(CI-pinned).  See ``repro.comm.hier`` for the transport that rides the two
tiers.
"""

from repro.topology.topology import (PRODUCTION_DATA, PRODUCTION_MODEL,
                                     Topology, grid_mesh, make_host_mesh,
                                     make_production_mesh, make_worker_mesh)

__all__ = [
    "Topology", "grid_mesh", "make_worker_mesh", "make_host_mesh",
    "make_production_mesh", "PRODUCTION_DATA", "PRODUCTION_MODEL",
]
