"""Int8 weight-only quantization for serving.

Decode is weight-bandwidth bound (§Roofline: every decode cell is
memory-dominant), so halving the bytes read per step ~halves the step-time
bound.  Symmetric per-output-channel int8: ``w ≈ q * scale`` with
``q ∈ int8[..., :]``, ``scale = max|w| / 127`` per last-dim column.

``quantize_tree`` converts every large floating-point weight leaf; small
leaves (norms, biases, scalars) stay in their original dtype.
``dequantize_tree`` restores (inside the jitted serve step — XLA fuses the
dequant multiply into the consuming matmul, so full-precision weights never
round-trip to HBM on TPU).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLeaf:
    q: jax.Array          # int8, original shape
    scale: jax.Array      # f32, shape broadcastable over the last dim
    dtype: Any            # original dtype (static)

    def tree_flatten(self):
        return (self.q, self.scale), self.dtype

    @classmethod
    def tree_unflatten(cls, dtype, children):
        q, scale = children
        return cls(q=q, scale=scale, dtype=dtype)

    def materialize(self) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(self.dtype)


def _quantize_leaf(w: jax.Array) -> QuantizedLeaf:
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True) if w.ndim >= 2 \
        else jnp.max(jnp.abs(w32), keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return QuantizedLeaf(q=q, scale=scale, dtype=w.dtype)


def quantize_tree(params, *, min_size: int = 4096):
    """int8-quantize every float leaf with >= min_size elements."""
    def leaf(w):
        if (hasattr(w, "dtype")
                and jnp.issubdtype(w.dtype, jnp.floating)
                and w.size >= min_size):
            return _quantize_leaf(w)
        return w

    return jax.tree.map(leaf, params)


def dequantize_tree(params):
    return jax.tree.map(
        lambda x: x.materialize() if isinstance(x, QuantizedLeaf) else x,
        params, is_leaf=lambda x: isinstance(x, QuantizedLeaf))


def quantization_error(params, qparams) -> float:
    """Max relative Frobenius error across quantized leaves (sanity)."""
    flat_p = jax.tree.leaves(params)
    flat_q, _ = jax.tree.flatten(
        qparams, is_leaf=lambda x: isinstance(x, QuantizedLeaf))
    errs = []
    for w, qx in zip(flat_p, flat_q):
        if isinstance(qx, QuantizedLeaf):
            d = qx.materialize().astype(jnp.float32) - w.astype(jnp.float32)
            errs.append(float(jnp.linalg.norm(d)
                              / (jnp.linalg.norm(w.astype(jnp.float32))
                                 + 1e-9)))
    return max(errs) if errs else 0.0
