"""Family dispatcher — one uniform API over all model families."""

from __future__ import annotations

from typing import Callable, NamedTuple

from repro.models import encdec, transformer
from repro.models.common import ModelConfig


class ModelAPI(NamedTuple):
    init: Callable
    forward: Callable
    loss_fn: Callable
    init_cache: Callable
    decode_step: Callable
    prefill: Callable          # (params, batch, max_len) -> (logits, cache)


def _encdec_prefill(cfg, params, batch, max_len):
    cache = encdec.init_cache(cfg, params, batch["frames"], max_len)
    logits = encdec.forward(cfg, params, batch)[:, -1]
    # teacher-forced prompt positions are filled by the caller's decode loop;
    # the decoder self-cache starts empty (whisper prompts are short).
    return logits, cache


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            init=lambda key: encdec.init(cfg, key),
            forward=lambda params, batch: encdec.forward(cfg, params, batch),
            loss_fn=lambda params, batch: encdec.loss_fn(cfg, params, batch),
            init_cache=lambda params, batch, max_len: encdec.init_cache(
                cfg, params, batch["frames"], max_len),
            decode_step=lambda params, cache, tokens: encdec.decode_step(
                cfg, params, cache, tokens),
            prefill=lambda params, batch, max_len: _encdec_prefill(
                cfg, params, batch, max_len),
        )
    return ModelAPI(
        init=lambda key: transformer.init(cfg, key),
        forward=lambda params, batch: transformer.forward(cfg, params, batch),
        loss_fn=lambda params, batch: transformer.loss_fn(cfg, params, batch),
        init_cache=lambda params, batch, max_len: transformer.init_cache(
            cfg, batch["tokens"].shape[0], max_len),
        decode_step=lambda params, cache, tokens: transformer.decode_step(
            cfg, params, cache, tokens),
        prefill=lambda params, batch, max_len: transformer.prefill(
            cfg, params, batch, max_len),
    )
