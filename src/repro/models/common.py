"""Shared model machinery: config dataclass, init, norms, RoPE, sharding rules.

All models are pure-functional pytrees: ``init(cfg, key) -> params``,
``apply(cfg, params, batch) -> logits``.  Layer stacks are stored stacked on a
leading ``L`` dim and executed with ``jax.lax.scan`` (+ per-layer remat), which
keeps the HLO size independent of depth — essential for the 512-device
dry-run compiles.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # --- hybrid (hymba) ---
    window: int = 0                # sliding-window size; 0 = full attention
    global_every: int = 0          # every k-th layer is full-attention
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 0        # stub frontend: precomputed frame embeddings
    # --- vlm (internvl2) ---
    img_tokens: int = 0            # stub frontend: precomputed patch embeddings
    # --- misc ---
    rope_theta: float = 10000.0
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:      # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        per_layer = 0
        if self.family in ("dense", "moe", "encdec", "vlm", "hybrid"):
            per_layer += d * hq * dh + 2 * d * hkv * dh + hq * dh * d  # attn
            per_layer += 2 * d  # norms
        if self.family == "moe":
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * ff
        elif self.family in ("dense", "encdec", "vlm"):
            per_layer += 3 * d * ff
        elif self.family == "hybrid":
            per_layer += 3 * d * ff
            per_layer += self._ssm_params() + d
        if self.family == "ssm":
            per_layer += self._ssm_params() + d
        total = self.n_layers * per_layer
        total += v * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        if self.family == "encdec":
            enc_layer = 4 * d * d + 3 * d * ff + 2 * d
            cross = 4 * d * d + d
            total += self.encoder_layers * enc_layer + self.n_layers * cross
        return total

    def _ssm_params(self) -> int:
        di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
        # in_proj -> (z, x, B, C, dt), conv on (x,B,C), out_proj, A, D, dt_bias
        return (self.d_model * (2 * di + 2 * n + h)
                + self.ssm_conv * (di + 2 * n) + di * self.d_model + 3 * h)

    def active_params(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense = self.n_params() - self.n_layers * self.n_experts * 3 * d * ff
        return dense + self.n_layers * self.top_k * 3 * d * ff


# ---------------------------------------------------------------------------
# run options (runtime knobs, not arch identity) — set by launchers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunOptions:
    # Megatron-style sequence parallelism: shard the residual stream's T dim
    # over the TP axis between blocks.  Cuts the scan's saved activations by
    # tp_size (the dominant train-memory term); GSPMD inserts the
    # all-gather / reduce-scatter pair around each attention/MLP.
    seq_parallel: bool = True
    # query-chunk size for the memory-efficient attention scan
    q_chunk: int = 512
    # the mesh sharding constraints should target (set by launchers; None
    # disables all activation constraints, e.g. in single-device tests)
    mesh: Any = None
    # opt-in shard_map expert parallelism for MoE (EXPERIMENTS.md §Perf it.3)
    moe_ep: bool = False


_RUN_OPTIONS = RunOptions()


def set_run_options(**kw) -> RunOptions:
    for k, v in kw.items():
        setattr(_RUN_OPTIONS, k, v)
    return _RUN_OPTIONS


def get_run_options() -> RunOptions:
    return _RUN_OPTIONS


def shard_heads(x: jax.Array) -> jax.Array:
    """Head-parallel constraint on a (B, T, H, Dh) attention tensor.

    Pins q/k/v to heads-over-'model' so the query-chunk scan runs with zero
    per-chunk collectives (the all-gather of K/V happens once per layer,
    hoisted out of the loop).  No-op if heads don't divide the TP axis."""
    mesh = _RUN_OPTIONS.mesh
    if mesh is None or x.ndim != 4 or "model" not in mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if x.shape[2] % sizes["model"] != 0:
        return x
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]
    b_spec = dp if dp and x.shape[0] % dp_total == 0 else None
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_spec, None, "model", None)))


def shard_seq(x: jax.Array) -> jax.Array:
    """Sequence-parallel constraint on a (B, T, D) residual-stream tensor.

    No-op unless enabled, a mesh with a 'model' axis is current, and T
    divides the axis.  (Decode tensors with T == 1 fall through.)
    """
    mesh = _RUN_OPTIONS.mesh
    if not _RUN_OPTIONS.seq_parallel or x.ndim != 3 or mesh is None:
        return x
    if "model" not in mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    t = x.shape[1]
    if t < 2 or t % sizes["model"] != 0:
        return x
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]
    b_spec = dp if dp and x.shape[0] % dp_total == 0 else None
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_spec, "model", None)))


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., T, H, Dh), positions: (..., T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_dense(key: jax.Array, shape, dtype, *, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
# Logical axes; mapping to mesh axes depends on divisibility per-arch.
#   "embed"  : d_model                    -> usually unsharded (residual stream)
#   "vocab"  : vocabulary                 -> 'model' if divisible
#   "heads"  : q-head count * head_dim    -> 'model' if n_heads % tp == 0
#   "kv"     : kv-head count * head_dim   -> 'model' if n_kv_heads % tp == 0
#   "mlp"    : d_ff / d_inner             -> 'model' if divisible
#   "expert" : expert count               -> 'model' if divisible
#   "layers" : stacked layer dim          -> never sharded
#   "fsdp"   : extra param shard over 'data' (ZeRO-3) on the given dim


def axis_ok(size: int, mesh_axis_size: int) -> bool:
    return mesh_axis_size > 0 and size % mesh_axis_size == 0


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved logical->mesh mapping for one (config, mesh) pair."""
    tp: str | None            # mesh axis used for tensor parallelism ('model')
    fsdp: str | None          # mesh axis for param/optstate sharding ('data')
    dp: tuple[str, ...]       # batch axes, e.g. ('pod', 'data')
    tp_size: int
    fsdp_size: int

    def heads(self, n: int) -> str | None:
        return self.tp if axis_ok(n, self.tp_size) else None

    def dim(self, size: int) -> str | None:
        return self.tp if axis_ok(size, self.tp_size) else None

    def fsdp_dim(self, size: int) -> str | None:
        return self.fsdp if axis_ok(size, self.fsdp_size) else None


def make_rules(mesh: jax.sharding.Mesh, *, use_fsdp: bool) -> ShardingRules:
    names = mesh.axis_names
    tp = "model" if "model" in names else None
    fsdp = "data" if (use_fsdp and "data" in names) else None
    dp = tuple(a for a in ("pod", "data") if a in names)
    sizes = dict(zip(names, mesh.devices.shape))
    return ShardingRules(
        tp=tp, fsdp=fsdp, dp=dp,
        tp_size=sizes.get("model", 1), fsdp_size=sizes.get("data", 1),
    )
