"""Encoder-decoder transformer (whisper-family backbone).

The conv/mel frontend is a STUB per the assignment: ``batch["frames"]`` are
precomputed frame embeddings (B, F, d_model) provided by ``input_specs()``.
Encoder: non-causal self-attention + GELU MLP.  Decoder: causal self-attention
+ cross-attention + GELU MLP.  RoPE replaces whisper's sinusoidal/learned
positions (TPU-idiomatic; documented in DESIGN.md §8).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import blocks, common
from repro.models.common import ModelConfig, rms_norm


def _init_mlp(cfg: ModelConfig, key: jax.Array, L: int) -> dict:
    ks = jax.random.split(key, 2)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_up": common.init_dense(ks[0], (L, d, f), cfg.dtype),
        "w_down": common.init_dense(ks[1], (L, f, d), cfg.dtype),
    }


def init(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)
    Le, Ld, d = cfg.encoder_layers, cfg.n_layers, cfg.d_model
    enc = {
        **blocks.init_attention(cfg, ks[0], Le),
        **_init_mlp(cfg, ks[1], Le),
        "attn_norm": jnp.ones((Le, d), jnp.float32),
        "mlp_norm": jnp.ones((Le, d), jnp.float32),
    }
    h, dh = cfg.n_heads, cfg.head_dim
    dec = {
        **blocks.init_attention(cfg, ks[2], Ld),
        **_init_mlp(cfg, ks[3], Ld),
        "attn_norm": jnp.ones((Ld, d), jnp.float32),
        "mlp_norm": jnp.ones((Ld, d), jnp.float32),
        "cross_norm": jnp.ones((Ld, d), jnp.float32),
        "cwq": common.init_dense(ks[4], (Ld, d, h * dh), cfg.dtype),
        "cwk": common.init_dense(ks[5], (Ld, d, h * dh), cfg.dtype),
        "cwv": common.init_dense(ks[6], (Ld, d, h * dh), cfg.dtype),
        "cwo": common.init_dense(ks[7], (Ld, h * dh, d), cfg.dtype),
    }
    return {
        "enc_blocks": enc,
        "dec_blocks": dec,
        "embed": common.init_dense(
            jax.random.fold_in(key, 99), (cfg.vocab, d), cfg.dtype, scale=1.0),
        "enc_norm": jnp.ones((d,), jnp.float32),
        "final_norm": jnp.ones((d,), jnp.float32),
    }


def _cross_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                     ck: jax.Array, cv: jax.Array) -> jax.Array:
    """x: (B, T, D) queries; ck/cv: (B, F, H, Dh) precomputed from encoder."""
    b, t, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["cwq"]).reshape(b, t, h, dh)
    scores = jnp.einsum("bthd,bfhd->bhtf", q, ck).astype(jnp.float32)
    probs = jax.nn.softmax(
        scores / jnp.sqrt(jnp.asarray(dh, jnp.float32)), -1).astype(x.dtype)
    out = jnp.einsum("bhtf,bfhd->bthd", probs, cv).reshape(b, t, h * dh)
    return out @ p["cwo"]


def _cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    b, f, _ = enc_out.shape
    h, dh = cfg.n_heads, cfg.head_dim
    ck = (enc_out @ p["cwk"]).reshape(b, f, h, dh)
    cv = (enc_out @ p["cwv"]).reshape(b, f, h, dh)
    return ck, cv


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    def block(p, x):
        x = common.shard_seq(x)
        x = x + blocks.attention_train(
            cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps), causal=False)
        x = x + blocks.gelu_mlp(p, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        return x

    body = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(
        lambda c, p: (body(p, c), None),
        frames.astype(cfg.dtype), params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)

    def block(p, x):
        x = common.shard_seq(x)
        x = x + blocks.attention_train(
            cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps))
        ck, cv = _cross_kv(cfg, p, enc_out)
        x = x + _cross_attention(
            cfg, p, rms_norm(x, p["cross_norm"], cfg.norm_eps), ck, cv)
        x = x + blocks.gelu_mlp(p, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        return x

    body = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda c, p: (body(p, c), None), x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T  # whisper ties embeddings


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def init_cache(cfg: ModelConfig, params: dict, frames: jax.Array,
               max_len: int) -> dict:
    """Run the encoder once, precompute per-layer cross K/V, allocate the
    decoder self-attention cache."""
    enc_out = encode(cfg, params, frames)
    ck, cv = jax.vmap(
        lambda p: _cross_kv(cfg, p, enc_out))(params["dec_blocks"])
    L, b = cfg.n_layers, frames.shape[0]
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "cur_len": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, b, max_len, hkv, dh), cfg.dtype),
        "v": jnp.zeros((L, b, max_len, hkv, dh), cfg.dtype),
        "ck": ck, "cv": cv,  # (L, B, F, H, Dh)
    }


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], tokens, axis=0)
    cur_len = cache["cur_len"]

    def scan_fn(carry, layer):
        p, k, v, ck, cv = layer
        x = carry
        a, k, v = blocks.attention_decode(
            cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps), k, v, cur_len)
        x = x + a
        x = x + _cross_attention(
            cfg, p, rms_norm(x, p["cross_norm"], cfg.norm_eps), ck, cv)
        x = x + blocks.gelu_mlp(p, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        return x, (k, v)

    x, (k, v) = jax.lax.scan(
        scan_fn, x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["ck"], cache["cv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return logits, {"cur_len": cur_len + 1, "k": k, "v": v,
                    "ck": cache["ck"], "cv": cache["cv"]}
