"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layer stacks are stored stacked on a leading ``L`` axis and executed with
``jax.lax.scan`` over rematerialized blocks, so HLO size is depth-independent
(required for 88-layer x 512-device dry-run compiles).

Public API:
  init(cfg, key)                          -> params pytree
  forward(cfg, params, batch)             -> logits (B, T, V)
  loss_fn(cfg, params, batch)             -> scalar CE (+ MoE aux)
  init_cache(cfg, batch_size, max_len)    -> decode cache pytree
  decode_step(cfg, params, cache, tokens) -> (logits (B, 1, V), cache)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import blocks, common
from repro.models.common import ModelConfig, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_windows(cfg: ModelConfig) -> jax.Array:
    """Per-layer attention window (0 = full).  Hybrid (hymba) schedules a few
    global layers (first / middle / last) among sliding-window layers."""
    if cfg.family != "hybrid" or cfg.window <= 0:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    w = jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    glob = [0, cfg.n_layers // 2, cfg.n_layers - 1]
    return w.at[jnp.array(glob)].set(0)


def init(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)
    L, d, v = cfg.n_layers, cfg.d_model, cfg.vocab
    blk: dict = {}
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        blk.update(blocks.init_attention(cfg, ks[0], L))
        blk["attn_norm"] = jnp.ones((L, d), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        blk.update(blocks.init_mamba(cfg, ks[1], L))
        blk["ssm_norm"] = jnp.ones((L, d), jnp.float32)
    if cfg.family == "moe":
        blk.update(blocks.init_moe(cfg, ks[2], L))
        blk["mlp_norm"] = jnp.ones((L, d), jnp.float32)
    elif cfg.family in ("dense", "vlm", "hybrid"):
        blk.update(blocks.init_swiglu(cfg, ks[2], L))
        blk["mlp_norm"] = jnp.ones((L, d), jnp.float32)
    params = {
        "embed": common.init_dense(ks[3], (v, d), cfg.dtype, scale=1.0),
        "blocks": blk,
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.init_dense(ks[4], (d, v), cfg.dtype)
    return params


# ---------------------------------------------------------------------------
# blocks (train path)
# ---------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, p: dict, x: jax.Array,
                 window: jax.Array) -> jax.Array:
    """One layer.  p: this layer's leaves (no L dim)."""
    x = common.shard_seq(x)
    if cfg.family in ("dense", "moe", "vlm"):
        x = x + blocks.attention_train(
            cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps))
    elif cfg.family == "ssm":
        x = x + blocks.mamba_train(
            cfg, p, rms_norm(x, p["ssm_norm"], cfg.norm_eps))
    elif cfg.family == "hybrid":
        # hymba: attention and SSM heads run in PARALLEL on the same input,
        # outputs are averaged (normalized fusion).
        xin = rms_norm(x, p["attn_norm"], cfg.norm_eps)
        a = blocks.attention_train(cfg, p, xin, window=window)
        s = blocks.mamba_train(cfg, p, xin)
        x = x + 0.5 * (a + s)
    if cfg.family == "moe":
        x = x + blocks.moe_apply(
            cfg, p, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
    elif cfg.family in ("dense", "vlm", "hybrid"):
        x = x + blocks.swiglu(
            {k: p[k] for k in ("w_gate", "w_up", "w_down")},
            rms_norm(x, p["mlp_norm"], cfg.norm_eps))
    return x


def _stack(cfg: ModelConfig, blk: dict, x: jax.Array) -> jax.Array:
    windows = _layer_windows(cfg)
    body = jax.checkpoint(
        functools.partial(_block_train, cfg),
        policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(carry, layer):
        p, w = layer
        return body(p, carry, w), None

    x, _ = jax.lax.scan(scan_fn, x, (blk, windows))
    return common.shard_seq(x)


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Token embeddings; VLM prepends stub patch embeddings (precomputed by
    the frontend stub, see input_specs)."""
    emb = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        emb = jnp.concatenate(
            [batch["patch_embeds"].astype(emb.dtype), emb], axis=1)
    return emb


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    x = _embed_inputs(cfg, params, batch)
    x = _stack(cfg, params["blocks"], x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            *, aux_weight: float = 0.01) -> jax.Array:
    """Next-token CE in f32 (+ Switch-style load-balance loss for MoE).

    VLM: patch positions carry no labels — loss is computed on the token
    suffix only.
    """
    logits = forward(cfg, params, batch).astype(jnp.float32)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        logits = logits[:, batch["patch_embeds"].shape[1]:]
    labels = batch["labels"]
    logits = logits[:, : labels.shape[1]]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.family == "moe":
        x = _embed_inputs(cfg, params, batch)
        aux = blocks.moe_aux_loss(
            cfg, jax.tree.map(lambda a: a[0], params["blocks"]), x)
        ce = ce + aux_weight * aux
    return ce


# ---------------------------------------------------------------------------
# decode path (KV / SSM-state caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode cache pytree (leaves have leading L dim for the layer scan)."""
    L = cfg.n_layers
    cache: dict = {"cur_len": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        cache["k"] = jnp.zeros((L, batch, max_len, hkv, dh), cfg.dtype)
        cache["v"] = jnp.zeros((L, batch, max_len, hkv, dh), cfg.dtype)
    if cfg.family in ("ssm", "hybrid"):
        di, n = cfg.d_inner, cfg.ssm_state
        cache["conv_x"] = jnp.zeros(
            (L, batch, cfg.ssm_conv - 1, di), cfg.dtype)
        cache["conv_bc"] = jnp.zeros(
            (L, batch, cfg.ssm_conv - 1, 2 * n), cfg.dtype)
        cache["ssm"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_headdim, n), jnp.float32)
    return cache


def _block_decode(cfg: ModelConfig, p: dict, x: jax.Array, layer_cache: dict,
                  cur_len: jax.Array, window: jax.Array
                  ) -> tuple[jax.Array, dict]:
    new_cache = dict(layer_cache)
    if cfg.family in ("dense", "moe", "vlm"):
        a, new_cache["k"], new_cache["v"] = blocks.attention_decode(
            cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps),
            layer_cache["k"], layer_cache["v"], cur_len)
        x = x + a
    elif cfg.family == "ssm":
        s, new_cache["conv_x"], new_cache["conv_bc"], new_cache["ssm"] = \
            blocks.mamba_decode(
                cfg, p, rms_norm(x, p["ssm_norm"], cfg.norm_eps),
                layer_cache["conv_x"], layer_cache["conv_bc"],
                layer_cache["ssm"])
        x = x + s
    elif cfg.family == "hybrid":
        xin = rms_norm(x, p["attn_norm"], cfg.norm_eps)
        a, new_cache["k"], new_cache["v"] = blocks.attention_decode(
            cfg, p, xin, layer_cache["k"], layer_cache["v"], cur_len,
            window=window)
        s, new_cache["conv_x"], new_cache["conv_bc"], new_cache["ssm"] = \
            blocks.mamba_decode(
                cfg, p, xin, layer_cache["conv_x"], layer_cache["conv_bc"],
                layer_cache["ssm"])
        x = x + 0.5 * (a + s)
    if cfg.family == "moe":
        x = x + blocks.moe_apply(
            cfg, p, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
    elif cfg.family in ("dense", "vlm", "hybrid"):
        x = x + blocks.swiglu(
            {k: p[k] for k in ("w_gate", "w_up", "w_down")},
            rms_norm(x, p["mlp_norm"], cfg.norm_eps))
    return x, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            max_len: int) -> tuple[jax.Array, dict]:
    """Process the whole prompt in one forward pass AND fill the decode
    cache (per-layer K/V written at [0, T); SSM conv tails + final state).

    Returns (last-position logits (B, V), cache with cur_len = T)."""
    x = _embed_inputs(cfg, params, batch)
    b, t, _ = x.shape
    windows = _layer_windows(cfg)

    def body(carry, layer):
        p, w = layer
        x = common.shard_seq(carry)
        outs = {}
        if cfg.family in ("dense", "moe", "vlm"):
            a, k, v = blocks.attention_train(
                cfg, p, rms_norm(x, p["attn_norm"], cfg.norm_eps),
                return_kv=True)
            x = x + a
            outs["k"], outs["v"] = k, v
        elif cfg.family == "ssm":
            s, cx, cbc, st = blocks.mamba_train(
                cfg, p, rms_norm(x, p["ssm_norm"], cfg.norm_eps),
                return_state=True)
            x = x + s
            outs.update(conv_x=cx, conv_bc=cbc, ssm=st)
        elif cfg.family == "hybrid":
            xin = rms_norm(x, p["attn_norm"], cfg.norm_eps)
            a, k, v = blocks.attention_train(
                cfg, p, xin, window=w, return_kv=True)
            s, cx, cbc, st = blocks.mamba_train(cfg, p, xin,
                                                return_state=True)
            x = x + 0.5 * (a + s)
            outs.update(k=k, v=v, conv_x=cx, conv_bc=cbc, ssm=st)
        if cfg.family == "moe":
            x = x + blocks.moe_apply(
                cfg, p, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        elif cfg.family in ("dense", "vlm", "hybrid"):
            x = x + blocks.swiglu(
                {n: p[n] for n in ("w_gate", "w_up", "w_down")},
                rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        return x, outs

    x, per_layer = jax.lax.scan(body, x, (params["blocks"], windows))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x[:, -1] @ head

    cache = init_cache(cfg, b, max_len)
    if "k" in per_layer:
        pad = max_len - t
        cache["k"] = jnp.pad(per_layer["k"].astype(cache["k"].dtype),
                             ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(per_layer["v"].astype(cache["v"].dtype),
                             ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    for name in ("conv_x", "conv_bc", "ssm"):
        if name in per_layer:
            cache[name] = per_layer[name].astype(cache[name].dtype)
    cache["cur_len"] = jnp.asarray(t, jnp.int32)
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step.  tokens: (B, 1) -> logits (B, 1, V), updated cache."""
    x = jnp.take(params["embed"], tokens, axis=0)
    cur_len = cache["cur_len"]
    windows = _layer_windows(cfg)
    layer_caches = {k: v for k, v in cache.items() if k != "cur_len"}

    def scan_fn(carry, layer):
        p, lc, w = layer
        y, nc = _block_decode(cfg, p, carry, lc, cur_len, w)
        return y, nc

    x, new_caches = jax.lax.scan(
        scan_fn, x, (params["blocks"], layer_caches, windows))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    new_cache = dict(new_caches)
    new_cache["cur_len"] = cur_len + 1
    return logits, new_cache
