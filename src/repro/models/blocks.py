"""Block-level forward functions: GQA attention, SwiGLU MLP, MoE, Mamba2 SSD.

All functions are pure and take ``(cfg, params_leafdict, x, ...)``; they are
assembled into layer stacks (lax.scan over a leading L dim) by
``transformer.py`` / ``encdec.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import common
from repro.models.common import ModelConfig, rope


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, -1)


def _pick_chunk(t: int, target: int = 512) -> int:
    for c in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if c <= t and t % c == 0:
            return c
    return 1


def attention_train(cfg: ModelConfig, p: dict, x: jax.Array,
                    *, causal: bool = True,
                    window: jax.Array | int = 0,
                    q_chunk: int = 512, return_kv: bool = False):
    """Self-attention over a (B, T, D) block, chunked over query blocks.

    The (T, T) score matrix is never materialized: a ``lax.scan`` over query
    chunks computes exact softmax per chunk against the full K/V (Rabe &
    Staats-style memory-efficient attention — the pure-JAX analogue of a
    flash kernel; peak transient is (B, H, q_chunk, T) instead of
    (B, H, T, T)).  ``window`` > 0 masks to a sliding window (traced scalar
    ok, for per-layer hybrid schedules)."""
    b, t, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (1, t))
    q = _split_heads(x @ p["wq"], hq)
    k = _split_heads(x @ p["wk"], hkv)
    v = _split_heads(x @ p["wv"], hkv)
    if cfg.rope_theta > 0:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    # NOTE: explicit q/k/v heads-over-'model' constraints were tried and
    # REFUTED (granite_34b collective term 1.28e12 -> 1.52e13 B: forcing the
    # layout fights GSPMD's propagation through RoPE/chunk-scan and inserts
    # per-layer resharding).  See EXPERIMENTS.md §Perf iteration 5.
    g = hq // hkv
    q = q.reshape(b, t, hkv, g, dh)

    c = _pick_chunk(t, q_chunk)
    nc = t // c
    qc = jnp.moveaxis(q.reshape(b, nc, c, hkv, g, dh), 1, 0)  # (nc,b,c,hkv,g,dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    w = jnp.asarray(window)
    kpos = jnp.arange(t, dtype=jnp.int32)

    def chunk_fn(i, qi):
        # qi: (b, c, hkv, g, dh); scores vs full K
        s = jnp.einsum("bthgd,bshd->bhgts", qi, k).astype(jnp.float32) * scale
        qpos = i * c + jnp.arange(c, dtype=jnp.int32)
        mask = jnp.ones((c, t), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        mask &= (w <= 0) | (kpos[None, :] > qpos[:, None] - jnp.maximum(w, 1))
        s = jnp.where(mask[None, None, None], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        return jnp.einsum("bhgts,bshd->bthgd", probs, v)  # (b,c,hkv,g,dh)

    if nc == 1:
        out = chunk_fn(0, qc[0])[:, None]
        out = jnp.moveaxis(out, 1, 0)
    else:
        _, out = jax.lax.scan(
            lambda i, qi: (i + 1, chunk_fn(i, qi)),
            jnp.zeros((), jnp.int32), qc)            # (nc, b, c, hkv, g, dh)
    out = jnp.moveaxis(out, 0, 1).reshape(b, t, hq * dh)
    if return_kv:
        return out @ p["wo"], k, v
    return out @ p["wo"]


def attention_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, window: jax.Array | int = 0
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x: (B, 1, D); caches: (B, S, Hkv, Dh).

    Returns (out (B,1,D), new_k_cache, new_v_cache).  Attends to positions
    [0, cur_len]; the new token is written at index cur_len.
    """
    b, _, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = k_cache.shape[1]
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q = _split_heads(x @ p["wq"], hq)
    k = _split_heads(x @ p["wk"], hkv)
    v = _split_heads(x @ p["wv"], hkv)
    if cfg.rope_theta > 0:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, cur_len, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, cur_len, 0, 0))

    g = hq // hkv
    q = q.reshape(b, 1, hkv, g, dh)
    scores = jnp.einsum("bthgd,bshd->bhgts", q, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    kpos = jnp.arange(s)[None, None, None, None, :]
    mask = kpos <= cur_len
    w = jnp.asarray(window)
    mask &= (w <= 0) | (kpos > cur_len - jnp.maximum(w, 1))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v_cache).reshape(b, 1, hq * dh)
    return out @ p["wo"], k_cache, v_cache


def init_attention(cfg: ModelConfig, key: jax.Array, n_layers: int) -> dict:
    hq, hkv, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    shp = lambda *s: (n_layers, *s)
    return {
        "wq": common.init_dense(ks[0], shp(d, hq * dh), cfg.dtype),
        "wk": common.init_dense(ks[1], shp(d, hkv * dh), cfg.dtype),
        "wv": common.init_dense(ks[2], shp(d, hkv * dh), cfg.dtype),
        "wo": common.init_dense(ks[3], shp(hq * dh, d), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


def init_swiglu(cfg: ModelConfig, key: jax.Array, n_layers: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": common.init_dense(ks[0], (n_layers, d, f), cfg.dtype),
        "w_up": common.init_dense(ks[1], (n_layers, d, f), cfg.dtype),
        "w_down": common.init_dense(ks[2], (n_layers, f, d), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-bounded, sort-free scatter dispatch)
# ---------------------------------------------------------------------------

def _moe_shard(x: jax.Array, spec_dims) -> jax.Array:
    """Sharding constraint helper for MoE internals (no-op without a mesh)."""
    mesh = common.get_run_options().mesh
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]
    spec = []
    for dim, kind in zip(x.shape, spec_dims):
        if kind == "batch" and dp and dim % dp_total == 0:
            spec.append(dp)
        elif kind == "expert" and "model" in sizes \
                and dim % sizes["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def _moe_route(cfg: ModelConfig, router: jax.Array, x: jax.Array):
    """Shared routing: per-row ranks and capacity mask.

    Returns (gates (B,T,k), unit_e (B,U), unit_pos (B,U), keep (B,U), cap).
    """
    b, t, _ = x.shape
    e, k = cfg.n_experts, cfg.top_k
    u = t * k
    logits = (x @ router).astype(jnp.float32)                   # (B, T, E)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)   # (B, T, k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    cap = int(cfg.capacity_factor * t * k / e) or 1
    unit_e = idx.reshape(b, u)
    onehot = jax.nn.one_hot(unit_e, e, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=1) - 1) * onehot             # per-row rank
    unit_pos = jnp.sum(pos, axis=-1)
    keep = unit_pos < cap
    return gates, unit_e, jnp.where(keep, unit_pos, 0), keep, cap


def moe_apply_ep(cfg: ModelConfig, p: dict, x: jax.Array,
                 mesh) -> jax.Array:
    """Expert-parallel MoE via shard_map manual over the TP axis.

    Each 'model' shard owns E/tp experts.  Routing is computed redundantly
    per shard (router is replicated, cheap); each shard scatters only the
    units destined to ITS experts, runs its expert FFNs, applies the
    gate-weighted combine LOCALLY, and contributes a partial (B, T, D) that
    is psum'd once over 'model' — k*8x fewer reduced bytes than psumming the
    per-unit (B, T*k, D) gather, and no (B,U,D) all-gathers (EXPERIMENTS.md
    §Perf iteration 2).
    """
    from jax.sharding import PartitionSpec as P

    e, k = cfg.n_experts, cfg.top_k
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_loc = e // tp

    def body(x_in, router_in, wg, wu, wd):
        # f32 at the shard_map boundary: the backward pass psums the
        # cotangents of the replicated-in operands over 'model', and
        # XLA:CPU's bf16 all-reduce promotion CHECK-fails (real TPUs do
        # bf16 reductions natively; this boundary is the CPU-safe form).
        x_r = x_in.astype(x.dtype)
        router = router_in.astype(x.dtype)
        b, t, d = x_r.shape
        gates, unit_e, unit_pos, keep, cap = _moe_route(cfg, router, x_r)
        shard = jax.lax.axis_index("model")
        lo = shard * e_loc
        mine = keep & (unit_e >= lo) & (unit_e < lo + e_loc)
        e_local = jnp.where(mine, unit_e - lo, 0)
        pos = jnp.where(mine, unit_pos, 0)
        xu = jnp.repeat(x_r, k, axis=1)
        xu = jnp.where(mine[..., None], xu, 0)

        def row_scatter(xu_r, e_r, p_r):
            return jnp.zeros((e_loc, cap, d), x_r.dtype).at[e_r, p_r].add(xu_r)

        buf = jax.vmap(row_scatter)(xu, e_local, pos)           # (B,El,C,D)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) \
            * jnp.einsum("becd,edf->becf", buf, wu)
        yb = jnp.einsum("becf,efd->becd", h, wd)                # (B,El,C,D)

        def row_gather(yb_r, e_r, p_r):
            return yb_r[e_r, p_r]

        yu = jax.vmap(row_gather)(yb, e_local, pos)
        yu = yu * mine[..., None]
        y_part = jnp.sum(yu.reshape(b, t, k, d)
                         * gates[..., None].astype(yu.dtype), axis=2)
        return jax.lax.psum(y_part.astype(jnp.float32), "model")

    fn = compat.shard_map(
        body, mesh,
        in_specs=(P(), P(), P("model"), P("model"), P("model")),
        out_specs=P(),
        axis_names=frozenset({"model"}), check_vma=False)
    return fn(x.astype(jnp.float32), p["router"].astype(jnp.float32),
              p["w_gate"], p["w_up"], p["w_down"]).astype(x.dtype)


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Top-k MoE over a (B, T, D) block — locality-preserving dispatch.

    Routing ranks are computed PER ROW (capacity = capacity_factor*T*k/E per
    sequence), so the rank cumsum never crosses the data-sharded batch dim
    and the scatter into the (B, E, C, D) buffer is local to each data
    shard.  Experts live on the TP axis: the buffer is constrained to
    (B:data, E:model, C, D); the gather-back from the E-sharded buffer
    lowers to mask + psum over 'model' — the same row-parallel reduce as a
    Megatron MLP, instead of the all-to-all storm a global-rank dispatch
    produces (52.6s -> see EXPERIMENTS.md §Perf for the measured drop).
    """
    # moe_ep: shard_map expert parallelism — measured WORSE than the vmap
    # dispatch under XLA:CPU GSPMD (nested manual-model + auto-data causes
    # per-layer (B,U,D) f32 all-gathers; see EXPERIMENTS.md §Perf it.3),
    # so it's opt-in for future re-evaluation on real TPU toolchains.
    opts = common.get_run_options()
    mesh = opts.mesh
    if (getattr(opts, "moe_ep", False)
            and mesh is not None and "model" in mesh.axis_names
            and cfg.n_experts
            % dict(zip(mesh.axis_names, mesh.devices.shape))["model"] == 0):
        return moe_apply_ep(cfg, p, x, mesh)

    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    gates, unit_e, unit_pos, keep, cap = _moe_route(cfg, p["router"], x)
    xu = jnp.repeat(x, k, axis=1)                               # (B, U, D)
    xu = jnp.where(keep[..., None], xu, 0)

    # vmap over batch so B is a true scatter/gather BATCH dim — XLA then
    # partitions B on 'data' and handles the E-sharded dim by index-masking
    # (+ psum on the gather), instead of replicating the whole buffer.
    def row_scatter(xu_r, e_r, p_r):
        return jnp.zeros((e, cap, d), x.dtype).at[e_r, p_r].add(xu_r)

    buf = jax.vmap(row_scatter)(xu, unit_e, unit_pos)
    buf = _moe_shard(buf, ("batch", "expert", None, None))      # (B,E,C,D)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    yb = jnp.einsum("becf,efd->becd", h, p["w_down"])
    yb = _moe_shard(yb, ("batch", "expert", None, None))        # (B,E,C,D)

    def row_gather(yb_r, e_r, p_r):
        return yb_r[e_r, p_r]

    yu = jax.vmap(row_gather)(yb, unit_e, unit_pos)             # (B, U, D)
    yu = yu * keep[..., None]
    y = jnp.sum(yu.reshape(b, t, k, d)
                * gates[..., None].astype(yu.dtype), axis=2)
    return y.astype(x.dtype)


def init_moe(cfg: ModelConfig, key: jax.Array, n_layers: int) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": common.init_dense(ks[0], (n_layers, d, e), cfg.dtype),
        "w_gate": common.init_dense(ks[1], (n_layers, e, d, f), cfg.dtype),
        "w_up": common.init_dense(ks[2], (n_layers, e, d, f), cfg.dtype),
        "w_down": common.init_dense(ks[3], (n_layers, e, f, d), cfg.dtype),
    }


def moe_aux_loss(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style) for one block."""
    logits = (x.reshape(-1, cfg.d_model) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state space duality, chunked scan)
# ---------------------------------------------------------------------------

def _causal_conv(xbc: jax.Array, conv_w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xbc: (B, T, C), conv_w: (W, C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(w))
    return jax.nn.silu(out)


def _segsum(logd: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) lower-triangular pairwise sums of log-decays:
    out[i, j] = sum_{k=j+1..i} logd[k] for i >= j, -inf otherwise."""
    q = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{k=j+1..i}
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_train(cfg: ModelConfig, xh: jax.Array, dt: jax.Array, A: jax.Array,
              B: jax.Array, C: jax.Array, *, chunk: int = 128
              ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD forward (Mamba2 alg. 1, G=1 group).

    xh: (b, T, H, P) head-split inputs; dt: (b, T, H) positive step sizes;
    A: (H,) negative decay rates; B, C: (b, T, N).
    Returns (y: (b, T, H, P), final_state: (b, H, P, N)) — the final state
    feeds decode after a prefill.
    """
    b, t, h, pdim = xh.shape
    q = min(chunk, t)
    assert t % q == 0, "seq_len must divide the SSD chunk"
    nc = t // q
    # reshape into chunks
    xc = xh.reshape(b, nc, q, h, pdim)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, -1)
    Cc = C.reshape(b, nc, q, -1)
    logd = dtc * A  # (b, nc, q, h) log-decay per step (A < 0)

    # ---- intra-chunk (quadratic attention-like) term ----
    L = _segsum(jnp.moveaxis(logd, -1, -2))            # (b, nc, h, q, q)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)          # (b, nc, q, q)
    M = G[:, :, None] * jnp.exp(L)                     # (b, nc, h, q, q)
    M = M * jnp.moveaxis(dtc, -1, -2)[..., None, :]    # weight by dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M.astype(xh.dtype), xc)

    # ---- chunk-final states and inter-chunk recurrence ----
    cum = jnp.cumsum(logd, axis=2)                     # (b, nc, q, h)
    total = cum[:, :, -1]                              # (b, nc, h)
    decay_to_end = jnp.exp(total[:, :, None] - cum)    # (b, nc, q, h)
    # state contribution of chunk c: sum_j decay_to_end_j * dt_j * B_j x_j
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                    (decay_to_end * dtc).astype(jnp.float32),
                    Bc.astype(jnp.float32), xc.astype(jnp.float32))

    def scan_body(s_prev, inp):
        sc, tot = inp  # (b,h,p,n), (b,h)
        s_new = jnp.exp(tot)[..., None, None] * s_prev + sc
        return s_new, s_prev

    s0 = jnp.zeros((b, h, pdim, Sc.shape[-1]), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        scan_body, s0,
        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)              # (b, nc, h, p, n)

    decay_from_start = jnp.exp(cum)                    # (b, nc, q, h)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc.astype(jnp.float32), s_prevs, decay_from_start)
    y = y_intra + y_inter.astype(xh.dtype)
    return y.reshape(b, t, h, pdim), s_final


def mamba_train(cfg: ModelConfig, p: dict, x: jax.Array,
                *, return_state: bool = False):
    """Full Mamba2 mixer over (B, T, D).

    Projections are stored SEPARATELY (in_z / in_x / in_bc / in_dt rather
    than one fused in_proj) so each can carry its own TP sharding without
    slicing across stream boundaries on a sharded dim.  With
    ``return_state`` also returns (conv_x_tail, conv_bc_tail, ssm_state)
    to seed decode after a prefill."""
    b, t, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z = x @ p["in_z"]                       # (B, T, di)
    xs_raw = x @ p["in_x"]                  # (B, T, di) pre-conv
    bc_raw = x @ p["in_bc"]                 # (B, T, 2n)
    xin = _causal_conv(xs_raw, p["conv_x"])             # (B, T, di)
    bc = _causal_conv(bc_raw, p["conv_bc"])             # (B, T, 2n)
    B, C = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, t, h, pdim)
    y, s_final = ssd_train(cfg, xh, dt, A, B, C)
    y = y + p["D"].astype(xh.dtype)[None, None, :, None] * xh
    y = y.reshape(b, t, di) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        w = cfg.ssm_conv
        pad_x = jnp.pad(xs_raw, ((0, 0), (w - 1, 0), (0, 0)))
        pad_bc = jnp.pad(bc_raw, ((0, 0), (w - 1, 0), (0, 0)))
        return out, pad_x[:, t:t + w - 1], pad_bc[:, t:t + w - 1], s_final
    return out


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 conv_x_st: jax.Array, conv_bc_st: jax.Array,
                 ssm_state: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-token Mamba2 step.  x: (B, 1, D); conv_x_st: (B, W-1, di);
    conv_bc_st: (B, W-1, 2n); ssm_state: (B, H, P, N)."""
    b = x.shape[0]
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z = (x @ p["in_z"])[:, 0]                              # (B, di)
    xs = x @ p["in_x"]                                     # (B, 1, di)
    bcs = x @ p["in_bc"]                                   # (B, 1, 2n)
    hist_x = jnp.concatenate([conv_x_st, xs], axis=1)      # (B, W, di)
    hist_bc = jnp.concatenate([conv_bc_st, bcs], axis=1)
    xin = jax.nn.silu(jnp.sum(hist_x * p["conv_x"][None], axis=1))   # (B, di)
    bc = jax.nn.silu(jnp.sum(hist_bc * p["conv_bc"][None], axis=1))  # (B, 2n)
    B, C = jnp.split(bc, 2, axis=-1)
    dt1 = jax.nn.softplus(
        (x @ p["in_dt"])[:, 0].astype(jnp.float32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt1 * A)                                  # (B, H)
    xh = xin.reshape(b, h, pdim)
    ssm_state = (dA[..., None, None] * ssm_state
                 + jnp.einsum("bh,bn,bhp->bhpn",
                              dt1, B.astype(jnp.float32),
                              xh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, C.astype(jnp.float32))
    y = y.astype(x.dtype) + p["D"].astype(x.dtype)[None, :, None] * xh
    y = (y.reshape(b, di) * jax.nn.silu(z))[:, None, :]
    return y @ p["out_proj"], hist_x[:, 1:], hist_bc[:, 1:], ssm_state


def init_mamba(cfg: ModelConfig, key: jax.Array, n_layers: int) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 7)
    shp = lambda *s: (n_layers, *s)
    return {
        "in_z": common.init_dense(ks[0], shp(d, di), cfg.dtype),
        "in_x": common.init_dense(ks[1], shp(d, di), cfg.dtype),
        "in_bc": common.init_dense(ks[2], shp(d, 2 * n), cfg.dtype),
        "in_dt": common.init_dense(ks[3], shp(d, h), cfg.dtype),
        "conv_x": common.init_dense(ks[4], shp(cfg.ssm_conv, di), cfg.dtype,
                                    scale=0.5),
        "conv_bc": common.init_dense(ks[5], shp(cfg.ssm_conv, 2 * n),
                                     cfg.dtype, scale=0.5),
        "out_proj": common.init_dense(ks[6], shp(di, d), cfg.dtype),
        "A_log": jnp.zeros((n_layers, h), jnp.float32),
        "D": jnp.ones((n_layers, h), jnp.float32),
        "dt_bias": jnp.full((n_layers, h), -1.0, jnp.float32),
    }
