"""``HierarchicalTransport`` — two-tier merges over a hierarchical platform.

The paper's final scheme exists because its platform was hierarchical:
intra-machine links were cheap, inter-machine (Azure DCN) links slow and
synchronization costly.  This transport expresses that shape by COMPOSING
the existing transports over the two axes of a ``repro.topology.Topology``
mesh instead of reimplementing any collective:

  * **tier 0** (intra-host, ``worker_axis``): a dense transport — XLA
    collectives or the Pallas ring — reduces inside each host group over
    the cheap links;
  * **tier 1** (inter-host, ``host_axis``): the group partials cross the
    slow links, by default through ``SparseTransport`` (top-k +
    error-feedback — Kamp et al.'s cheap-frequent-local /
    expensive-infrequent-global shape, with Patra's staleness-tolerant
    analysis justifying the lossy-but-error-fed global tier).

Every delegated call's ``CommRecord``s are re-tagged with ``tier=`` before
landing in this transport's log, so executors report intra- vs inter-host
wire bytes separately (``last_comm["by_tag"]["merge"]["by_tier"]``) and the
network model can charge the DCN tier at its own bandwidth.

Numerics contracts:

  * **dense tier 1 is the flat collective** — when both tiers are dense
    (stateless ``XlaTransport``-family), the two-stage reduce is FUSED
    into one collective over the joint ``(host_axis, worker_axis)`` group.
    On a row-major topology grid that group enumerates devices in exactly
    the flat-mesh order, so a hierarchical run with dense tier 1 is
    bit-for-bit the flat run (the acceptance test pins this; a genuinely
    two-stage f32 reduce would re-associate the sum).  The accounting
    still splits per tier: tier 0 charges the dense ring inside a group
    (m = workers_per_host), tier 1 the dense ring across groups
    (m = hosts) — the bytes the two-tier schedule moves on each link
    class.
  * **degenerate hosts == 1 is the flat path** — called with the bare
    worker axis (a flat topology's spec), only tier 0 runs and tier 1 is
    skipped entirely (no record, no wire), so a ``hosts=1`` hierarchical
    run collapses bit-identically to today's engine.
  * **sparse tier 1 compresses partials** — each worker's tier-0 group
    sum rides the top-k/error-feedback gather across the host axis; the
    residual is tier-1 transport state threaded through scan carries like
    any stateful merge state (``init_state`` returns the per-tier dict).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm.api import (Axis, CommRecord, Pytree, Transport, axis_size,
                            get_transport, ring_wire_bytes, tree_f32_bytes)
from repro.comm.xla import XlaTransport


class HierarchicalTransport(Transport):
    """Tier-0 dense intra-host + tier-1 (default sparse) inter-host."""

    name = "hier"

    def __init__(self, tier0: Transport | str = "xla",
                 tier1: Transport | str = "sparse", *,
                 tier1_frac: float | None = None,
                 host_axis: str = "hosts", worker_axis: str = "workers"):
        super().__init__()
        if host_axis == worker_axis or not host_axis or not worker_axis:
            raise ValueError(
                f"hier transport needs two distinct non-empty axes, got "
                f"({host_axis!r}, {worker_axis!r})")
        if isinstance(tier1, str) and tier1 == "sparse":
            tier1 = get_transport(
                "sparse", frac=0.01 if tier1_frac is None else tier1_frac)
        elif tier1_frac is not None:
            frac = getattr(get_transport(tier1), "frac", None)
            if frac != tier1_frac:
                # an explicit tier-1 transport AND a conflicting frac:
                # refusing beats silently compressing at another rate
                raise ValueError(
                    f"tier1_frac={tier1_frac} conflicts with the supplied "
                    f"tier-1 transport (frac={frac}); configure one place "
                    f"only")
        self.tier0 = get_transport(tier0)
        self.tier1 = get_transport(tier1)
        for label, sub in (("tier0", self.tier0), ("tier1", self.tier1)):
            if isinstance(sub, HierarchicalTransport):
                # a nested hier would re-tag records that already carry a
                # tier — ``_delegate`` copies them into the outer log where
                # the inner tier label is overwritten and the inner log's
                # copies double-count the wire; two tiers is the platform
                # model, deeper nesting needs its own accounting design
                raise ValueError(
                    f"{label}= must not be a HierarchicalTransport: nesting "
                    f"would overwrite the inner tier tags and double-count "
                    f"delegated CommRecords")
        self.host_axis = host_axis
        self.worker_axis = worker_axis
        # delegated calls record into the sub-transports' own logs (left in
        # place — SparseTransport's dense sidecar aliases its log at
        # construction, so swapping logs would orphan the mean records);
        # ``_delegate`` mark/since-copies each call's records here, tagged
        # with their tier, so this log is the one coherent stream

    @property
    def stateful(self) -> bool:  # type: ignore[override]
        return self.tier0.stateful or self.tier1.stateful

    @property
    def tier1_frac(self) -> float | None:
        return getattr(self.tier1, "frac", None)

    # -- axis / state plumbing ----------------------------------------------

    def _tiers_of(self, axis: Axis) -> bool:
        """True = two-tier (joint axis), False = tier-0 only (flat spec)."""
        if axis == (self.host_axis, self.worker_axis):
            return True
        if axis == self.worker_axis:
            return False
        raise ValueError(
            f"hier transport reduces over {(self.host_axis, self.worker_axis)} "
            f"(or the bare {self.worker_axis!r} on a flat topology), "
            f"got {axis!r}")

    def init_state(self, tree: Pytree) -> Pytree | None:
        s0 = self.tier0.init_state(tree)
        s1 = self.tier1.init_state(tree)
        if s0 is None and s1 is None:
            return None
        return {"t0": s0, "t1": s1}

    @staticmethod
    def _split_state(state):
        if state is None:
            return None, None
        return state.get("t0"), state.get("t1")

    def _join_state(self, state, s0, s1):
        # a ``state=None`` call runs residual-free and stays None (the
        # one-shot convention every stateful transport follows)
        if state is None:
            return None
        return {"t0": s0, "t1": s1}

    def _delegate(self, sub: Transport, tier: int, method: str, *args,
                  **kwargs):
        """Call ``sub.method`` and re-log its records tagged ``tier=``.

        Each delegated record must be re-tagged EXACTLY once: a record
        that already carries a tier has been through a hier delegation
        before (aliased sub-transport, nested composition the constructor
        missed), and overwriting its tag would misattribute — and its
        earlier copy double-count — the wire bytes the CI gates pin."""
        mark = sub.log.mark()
        out = getattr(sub, method)(*args, **kwargs)
        for r in sub.log.since(mark):
            if r.tier is not None:
                raise RuntimeError(
                    f"CommRecord {r.op!r} on {r.axis!r} already carries "
                    f"tier={r.tier} — delegated records must be re-tagged "
                    f"exactly once (is a sub-transport shared with another "
                    f"hierarchical transport?)")
            self.log.append(dataclasses.replace(r, tier=tier))
        return out

    # -- the fused dense path ------------------------------------------------

    def _dense_fusable(self, op: str) -> bool:
        """Both tiers stateless-dense: one joint-axis collective is the
        same group as the flat mesh (bit-for-bit), so fuse."""
        del op
        return (isinstance(self.tier0, XlaTransport)
                and isinstance(self.tier1, XlaTransport))

    def _record_tiers(self, op: str, logical: int, *, calls: int,
                      tag: str) -> None:
        """Per-tier dense accounting of one fused joint collective: the
        bytes the two-tier schedule moves on each link class."""
        wph = axis_size(self.worker_axis)
        hosts = axis_size(self.host_axis)
        self.log.append(CommRecord(
            op=op, transport=self.tier0.name, axis=self.worker_axis,
            participants=wph, logical_bytes=logical,
            wire_bytes=ring_wire_bytes(logical, wph), calls=calls, tag=tag,
            tier=0))
        self.log.append(CommRecord(
            op=op, transport=self.tier1.name, axis=self.host_axis,
            participants=hosts, logical_bytes=logical,
            wire_bytes=ring_wire_bytes(logical, hosts), calls=calls,
            tag=tag, tier=1))

    def _fused(self, tree: Pytree, joint: tuple, *, op: str, calls: int,
               tag: str, mask=None) -> Pytree:
        rec_op = op if mask is None else "masked_sum"
        if op == "mean":
            self._record_tiers(
                "mean", tree_f32_bytes(tree, floating_only=True),
                calls=calls, tag=tag)
            return jax.tree.map(
                lambda x: self.tier0._mean_leaf(x, joint)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
        self._record_tiers(rec_op, tree_f32_bytes(tree), calls=calls,
                           tag=tag)
        if mask is None:
            return jax.tree.map(
                lambda x: self.tier0._sum_leaf(x, joint), tree)
        return jax.tree.map(
            lambda x: self.tier0._sum_leaf(mask * x, joint), tree)

    # -- Transport API -------------------------------------------------------

    def all_reduce(self, tree: Pytree, axis: Axis, *, op: str = "sum",
                   state: Pytree | None = None, calls: int = 1,
                   tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        if op not in ("sum", "mean"):
            raise ValueError(
                f"unknown reduce op {op!r}; choose 'sum' or 'mean'")
        if not self._tiers_of(axis):
            # flat topology: tier-0 only, bit-identical to the plain path
            return self._delegate(self.tier0, 0, "all_reduce", tree,
                                  self.worker_axis, op=op, state=state,
                                  calls=calls, tag=tag)
        if self._dense_fusable(op):
            return self._fused(tree, axis, op=op, calls=calls,
                               tag=tag), state
        s0, s1 = self._split_state(state)
        partial, s0 = self._delegate(
            self.tier0, 0, "all_reduce", tree, self.worker_axis, op=op,
            state=s0, calls=calls, tag=tag)
        total, s1 = self._delegate(
            self.tier1, 1, "all_reduce", partial, self.host_axis, op=op,
            state=s1, calls=calls, tag=tag)
        return total, self._join_state(state, s0, s1)

    def masked_all_reduce(self, tree: Pytree, mask: jax.Array, axis: Axis, *,
                          state: Pytree | None = None, calls: int = 1,
                          tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        if not self._tiers_of(axis):
            return self._delegate(self.tier0, 0, "masked_all_reduce", tree,
                                  mask, self.worker_axis, state=state,
                                  calls=calls, tag=tag)
        if self._dense_fusable("sum"):
            return self._fused(tree, axis, op="sum", calls=calls, tag=tag,
                               mask=mask), state
        s0, s1 = self._split_state(state)
        # tier 0: only this group's round-completing workers contribute
        partial, s0 = self._delegate(
            self.tier0, 0, "masked_all_reduce", tree, mask,
            self.worker_axis, state=s0, calls=calls, tag=tag)
        # tier 1: the group partials (possibly zero this tick) always sum
        # across hosts — an SPMD program cannot skip a collective, and the
        # error feedback keeps a zero partial from consuming residual mass
        total, s1 = self._delegate(
            self.tier1, 1, "all_reduce", partial, self.host_axis, op="sum",
            state=s1, calls=calls, tag=tag)
        return total, self._join_state(state, s0, s1)

