"""``XlaTransport`` — the stock XLA collectives, and the numerics oracle.

This is today's behavior extracted behind the ``Transport`` API: f32 psum
for sums (the displacement merges of paper eqs. 8-9), f32 pmean cast back
to the input dtype for means (eq. 3 averaging, optimizer-moment consensus),
and the masked psum of the eq.-9 barrier-free reducer.  Every other
transport is tested against this one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.api import (CommRecord, Pytree, Transport, axis_label,
                            axis_size, ring_wire_bytes, tree_f32_bytes)


class XlaTransport(Transport):
    """Dense f32 collectives through XLA's all-reduce (the default)."""

    name = "xla"

    # the single psum/pmean hooks subclasses (RingTransport) override; the
    # f32-cast convention lives HERE, not at call sites
    def _sum_leaf(self, x: jax.Array, axis: str) -> jax.Array:
        return jax.lax.psum(x.astype(jnp.float32), axis)

    def _mean_leaf(self, x: jax.Array, axis: str) -> jax.Array:
        return jax.lax.pmean(x.astype(jnp.float32), axis).astype(x.dtype)

    def _record(self, op: str, axis, logical: int, *, calls: int,
                tag: str) -> None:
        m = axis_size(axis)
        self.log.append(CommRecord(
            op=op, transport=self.name, axis=axis_label(axis),
            participants=m, logical_bytes=logical,
            wire_bytes=ring_wire_bytes(logical, m), calls=calls, tag=tag))

    def all_reduce(self, tree: Pytree, axis: str, *, op: str = "sum",
                   state: Pytree | None = None, calls: int = 1,
                   tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        if op == "sum":
            self._record("sum", axis, tree_f32_bytes(tree), calls=calls,
                         tag=tag)
            return jax.tree.map(
                lambda x: self._sum_leaf(x, axis), tree), state
        if op == "mean":
            self._record("mean", axis,
                         tree_f32_bytes(tree, floating_only=True),
                         calls=calls, tag=tag)
            return jax.tree.map(
                lambda x: self._mean_leaf(x, axis)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, tree), state
        raise ValueError(f"unknown reduce op {op!r}; choose 'sum' or 'mean'")

    def masked_all_reduce(self, tree: Pytree, mask: jax.Array, axis: str, *,
                          state: Pytree | None = None, calls: int = 1,
                          tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        self._record("masked_sum", axis, tree_f32_bytes(tree), calls=calls,
                     tag=tag)
        return jax.tree.map(
            lambda x: self._sum_leaf(mask * x, axis), tree), state
