"""The ``Transport`` protocol — one API over every way to move a merge.

The paper's whole argument is that the *communication pattern* of the
reducing phase decides whether a distributed VQ scheme beats the sequential
one; this module makes that pattern a pluggable object instead of a
hardcoded collective.  A transport answers two calls, both pytree-in /
pytree-out and both legal inside a shard_map body:

  * ``all_reduce(tree, axis, op='sum'|'mean')``  — the barriered reducing
    phase (paper eqs. 3 and 8).  ``op='sum'`` rides in f32 and returns f32
    leaves (displacement merging); ``op='mean'`` casts floating leaves back
    to their input dtype and passes non-floating leaves through untouched.
    This is THE f32-cast convention for merge traffic (XLA:CPU's bf16
    all-reduce promotion CHECK-fails, and f32 reductions are what real runs
    use) — call sites must not re-implement it.
  * ``masked_all_reduce(tree, mask, axis)`` — the barrier-free reducer of
    the paper's cloud scheme (eq. 9): only workers whose ``mask`` is
    non-zero contribute their in-flight delta this tick.

Both return ``(result, state)``: stateful transports (``SparseTransport``
carries an error-feedback residual) thread ``state`` through scan carries
exactly like a stateful ``MergeStrategy`` does.

Wire-byte accounting
--------------------

Every call appends a ``CommRecord`` to the transport's ``CommLog`` **at
trace time** (shapes are static, so the bytes are exact).  Executors
snapshot the records traced for each compiled program and replay them on
cache hits, so the log reflects what actually ran, not what a cost model
guessed.  Conventions, per participant and per call:

  * ``logical_bytes`` — the dense f32 payload a merge logically moves
    (``4 * leaf.size`` summed over reduced leaves).
  * ``wire_bytes``    — what this transport actually puts on the wire.
    Dense transports charge the bandwidth-optimal ring all-reduce cost
    ``2 * (m-1)/m * logical``; the sparse transport charges the ring
    all-gather of its top-k (value f32 + index int32) chunks,
    ``(m-1) * k * 8``.  A 1-participant axis moves nothing.

``tag`` separates merge traffic ("merge") from instrumentation ("eval" —
the distortion-curve pmean), host-side resharding transfers
("late_delta"), and the dynamic merge's per-window divergence probe
("probe" — the scalar every worker pays whether or not the window
triggers), so dry-runs and benches can compare merge wire bytes without
the diagnostics polluting the ratio.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

#: What call sites may pass as a reduce axis: one mesh axis name, or a
#: tuple of names reduced jointly (the hierarchical engine passes
#: ``(host_axis, worker_axis)`` — jax collectives accept either spelling).
Axis = Any


def axis_size(axis: Axis) -> int:
    """Static size of a named mesh axis (or joint size of a tuple of
    axes), usable inside a traced body.

    ``lax.psum`` of a non-tracer constant folds to ``size * x`` without
    emitting a collective, so this is free and exact at trace time.
    """
    try:
        return int(jax.lax.psum(1, axis))
    except Exception:  # noqa: BLE001 — unbound axis (unit tests off-mesh)
        return 1


def axis_label(axis: Axis) -> str:
    """Canonical string form of an axis spec for ``CommRecord.axis``."""
    if isinstance(axis, str):
        return axis
    return "+".join(axis)


def tree_f32_bytes(tree: Pytree, *, floating_only: bool = False) -> int:
    """Dense f32 payload bytes of a pytree (the ``logical_bytes`` unit)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        if floating_only and not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        total += 4 * int(leaf.size)
    return total


def ring_wire_bytes(logical_bytes: int, m: int) -> int:
    """Per-participant wire bytes of a bandwidth-optimal ring all-reduce
    (reduce-scatter + all-gather): ``2 * (m-1)/m * logical``."""
    if m <= 1:
        return 0
    return int(2 * (m - 1) * logical_bytes // m)


@dataclasses.dataclass(frozen=True)
class CommRecord:
    """One collective call site: what it moved, per participant, per call.

    ``calls`` folds in the static trip count of the surrounding scan (a
    merge traced once inside a window scan executes ``n_windows`` times),
    so ``wire_bytes * calls`` is the total a participant put on the wire.
    """

    op: str                # 'sum' | 'mean' | 'masked_sum' | 'host'
    transport: str
    axis: str
    participants: int
    logical_bytes: int     # dense f32 payload per participant per call
    wire_bytes: int        # bytes per participant per call on the wire
    calls: int = 1
    tag: str = "merge"     # 'merge' | 'eval' | 'late_delta' | 'probe'
    # hierarchical transports split one merge over tiers: 0 = intra-host
    # (ICI-class), 1 = inter-host (DCN-class).  None = untiered (flat).
    tier: int | None = None


class CommLog:
    """Bounded stream of ``CommRecord``s with mark/since windows.

    Long-lived executors (a serve loop's train-publish trainer) append and
    replay records on every run forever, so the log keeps only the newest
    ``max_records`` and drops the oldest — marks are ABSOLUTE indices, so
    ``since`` stays correct across trims (records that fell off the window
    are simply gone from old summaries, never misattributed)."""

    def __init__(self, max_records: int = 1 << 16):
        if max_records < 1:
            raise ValueError(f"max_records must be >= 1, got {max_records}")
        self.max_records = max_records
        self.records: list[CommRecord] = []
        self._dropped = 0      # records trimmed off the front, ever
        self._metrics = None   # optional MetricsRegistry mirror

    def attach_metrics(self, registry) -> None:
        """Mirror every record landing in THIS log onto ``registry``
        (``repro.obs.MetricsRegistry``): wire/logical bytes and call
        counts become ``comm_*`` counters labeled by tag/tier/transport.

        Attach only to the top-level transport's log — a
        ``HierarchicalTransport`` copies its sub-transports' records into
        its own log, so attaching to both levels would double-count."""
        self._metrics = registry

    def _record_metrics(self, rec: CommRecord, sign: float = 1.0) -> None:
        if self._metrics is None:
            return
        labels = {"tag": rec.tag,
                  "tier": "flat" if rec.tier is None else rec.tier,
                  "transport": rec.transport}
        self._metrics.counter("comm_wire_bytes", **labels).inc(
            sign * rec.wire_bytes * rec.calls)
        self._metrics.counter("comm_logical_bytes", **labels).inc(
            sign * rec.logical_bytes * rec.calls)
        self._metrics.counter("comm_calls", **labels).inc(sign * rec.calls)

    def _trim(self) -> None:
        excess = len(self.records) - self.max_records
        if excess > 0:
            del self.records[:excess]
            self._dropped += excess

    def append(self, rec: CommRecord) -> None:
        self.records.append(rec)
        self._record_metrics(rec)
        self._trim()

    def extend(self, recs) -> None:
        self.records.extend(recs)
        for rec in recs:
            self._record_metrics(rec)
        self._trim()

    def mark(self) -> int:
        return self._dropped + len(self.records)

    def since(self, mark: int) -> list[CommRecord]:
        return list(self.records[max(0, mark - self._dropped):])

    def rewrite_since(self, mark: int, fn) -> None:
        """Rewrite each record appended after ``mark`` with ``fn(rec)``
        (return the record unchanged to keep it, a replacement to swap it,
        ``None`` to drop it).

        The executor's post-run correction hook: a divergence-triggered
        merge's collective is TRACED with the scan's full trip count (an
        SPMD program cannot skip a collective), but the wire a real
        dynamic protocol ships is only the triggered windows' — known only
        after the run, from the measured trigger bits.  The metrics mirror
        stays consistent: a replaced/dropped record's original contribution
        is backed out of the ``comm_*`` counters and the replacement's
        added, so the counters always equal the log."""
        start = max(0, mark - self._dropped)
        out = []
        for rec in self.records[start:]:
            new = fn(rec)
            if new is not rec:
                self._record_metrics(rec, sign=-1.0)
                if new is not None:
                    self._record_metrics(new)
            if new is not None:
                out.append(new)
        self.records[start:] = out

    def clear(self) -> None:
        self._dropped += len(self.records)
        self.records.clear()

    def logical_bytes_by_tag(self, records=None) -> dict[str, int]:
        """Total logical payload (``logical_bytes * calls``) per tag.

        The profiler's ground truth: a program's HLO all-reduce bytes must
        equal the ``merge``-tag logical bytes the transport recorded for
        that same program (tested in ``tests/test_profile.py``)."""
        out: dict[str, int] = {}
        for r in (self.records if records is None else records):
            out[r.tag] = out.get(r.tag, 0) + r.logical_bytes * r.calls
        return out

    @staticmethod
    def summarize(records) -> dict:
        """Totals (``wire/logical bytes * calls``) overall and per tag.

        Tiered records (a ``HierarchicalTransport`` merge) additionally
        land in a ``by_tier`` sub-dict under their tag, so callers can
        read intra-host (tier 0) vs inter-host (tier 1) traffic without
        walking the raw record stream.  Untiered records add nothing
        there, keeping flat summaries byte-identical to before.
        """
        out: dict = {"calls": 0, "logical_bytes": 0, "wire_bytes": 0,
                     "by_tag": {}}
        for r in records:
            out["calls"] += r.calls
            out["logical_bytes"] += r.logical_bytes * r.calls
            out["wire_bytes"] += r.wire_bytes * r.calls
            t = out["by_tag"].setdefault(
                r.tag, {"calls": 0, "logical_bytes": 0, "wire_bytes": 0})
            t["calls"] += r.calls
            t["logical_bytes"] += r.logical_bytes * r.calls
            t["wire_bytes"] += r.wire_bytes * r.calls
            if r.tier is not None:
                tiers = t.setdefault("by_tier", {})
                tr = tiers.setdefault(
                    r.tier,
                    {"calls": 0, "logical_bytes": 0, "wire_bytes": 0})
                tr["calls"] += r.calls
                tr["logical_bytes"] += r.logical_bytes * r.calls
                tr["wire_bytes"] += r.wire_bytes * r.calls
        return out


class Transport:
    """Base transport.  Stateful transports must be fed ``init_state``."""

    name = "base"
    stateful = False

    def __init__(self):
        self.log = CommLog()

    def init_state(self, tree: Pytree) -> Pytree | None:
        return None

    def all_reduce(self, tree: Pytree, axis: str, *, op: str = "sum",
                   state: Pytree | None = None, calls: int = 1,
                   tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        raise NotImplementedError

    def masked_all_reduce(self, tree: Pytree, mask: jax.Array, axis: str, *,
                          state: Pytree | None = None, calls: int = 1,
                          tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        raise NotImplementedError

    def record_host_transfer(self, *, logical_bytes: int, wire_bytes: int,
                             participants: int, axis: Axis, calls: int = 1,
                             tag: str = "late_delta",
                             tier: int | None = None) -> None:
        """Account a host-side transfer that bypasses the collectives (an
        elastic resize moving departing workers' late deltas).  ``tier``
        is the link class it crossed: the caller knows whether a departure
        left a host group (tier 1) or a flat worker set (untiered)."""
        self.log.append(CommRecord(
            op="host", transport=self.name, axis=axis_label(axis),
            participants=participants, logical_bytes=logical_bytes,
            wire_bytes=wire_bytes, calls=calls, tag=tag, tier=tier))


def get_transport(name, **kwargs) -> Transport:
    """Factory: 'xla' | 'ring' | 'sparse' | 'hier' | 'quant'
    (+ transport kwargs).

    An already-constructed ``Transport`` passes through unchanged, so call
    sites can accept either spelling.  'hier' composes two of the others
    over a two-tier topology (``tier0=``/``tier1=``/``tier1_frac=`` — see
    ``repro.comm.hier``); 'quant' decorates any of them with a narrow wire
    codec (``inner=``/``mode=`` — see ``repro.comm.quant``).
    """
    if isinstance(name, Transport):
        return name
    from repro.comm.hier import HierarchicalTransport
    from repro.comm.quant import QuantizedTransport
    from repro.comm.ring import RingTransport
    from repro.comm.sparse import SparseTransport
    from repro.comm.xla import XlaTransport
    transports = {"xla": XlaTransport, "ring": RingTransport,
                  "sparse": SparseTransport, "hier": HierarchicalTransport,
                  "quant": QuantizedTransport}
    if name not in transports:
        raise ValueError(
            f"unknown transport {name!r}; choose from {sorted(transports)}")
    return transports[name](**kwargs)
