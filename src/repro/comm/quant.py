"""``QuantizedTransport`` — bf16/int8 delta encoding over any transport.

The codebook is itself a quantizer; this decorator uses quantization on
its own merge deltas: each participant encodes its local contribution
(payload + error-feedback residual) to a narrow wire format, the decoded
f32 values ride the INNER transport's collective unchanged, and the skipped
rounding mass is carried into the next call's payload (error feedback,
Stich et al. style — the same discipline ``SparseTransport`` applies to its
top-k truncation), so nothing is lost, only delayed.

Three codecs:

  * ``bf16``     — truncate the f32 payload to bfloat16 (2 bytes/entry).
  * ``int8``     — symmetric per-leaf max-abs scaling:
    ``q = round(x / s).clip(-127, 127)`` with ``s = max|x| / 127``
    (1 byte/entry + one f32 scale per leaf on the wire).
  * ``identity`` — encode/decode is the identity and the wire width stays
    4 bytes/entry: the decorator is bit-transparent (the parity anchor the
    tests pin — wrapping any transport in identity quantization changes
    NOTHING, numerics or accounting).

Wire accounting
---------------

Delegated ``CommRecord``s are mark/since-copied from the inner transport's
log into this transport's log (the ``HierarchicalTransport`` discipline)
with ``wire_bytes`` re-priced at the quantized width:

  * dense records (ring all-reduce of f32 values):
    ``wire * width // 4``;
  * sparse records (all-gather of f32 value + int32 index pairs, 8
    bytes/entry — only the VALUE half narrows):
    ``wire * (width + 4) // 8``;
  * ``int8`` additionally charges ``4 * n_leaves`` bytes per call for the
    per-leaf scales (skipped when the record moved no wire — a
    1-participant axis still moves nothing);
  * ``op='mean'`` and host-transfer records pass through unquantized and
    unchanged: means are consensus values, not compressible displacements
    (the ``SparseTransport`` convention), so ``AverageMerge`` and the
    eval-curve reduces are untouched.

Tier tags are preserved verbatim, so a ``QuantizedTransport`` wrapping a
``HierarchicalTransport`` keeps the per-tier split, and one wrapped INSIDE
a hier tier arrives untiered and is re-tagged exactly once by the outer
``_delegate``.  Composition over another ``QuantizedTransport`` is
rejected — double quantization would double-charge the scale bytes and
hide one codec's error inside the other's residual.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm.api import CommRecord, Pytree, Transport, get_transport

#: wire bytes per payload entry under each codec (dense f32 is 4)
QUANT_WIDTH = {"identity": 4, "bf16": 2, "int8": 1}


def quantize_leaf(x: jax.Array, mode: str) -> jax.Array:
    """Encode -> decode one f32 leaf: the dequantized f32 values the
    receiving side reconstructs (the collective sums THESE, so simulating
    the wire is exact).  Deterministic, shape-preserving."""
    if mode == "identity":
        return x
    if mode == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
        return q * scale
    raise ValueError(
        f"unknown quantization mode {mode!r}; choose from "
        f"{sorted(QUANT_WIDTH)}")


class QuantizedTransport(Transport):
    """Quantize sum payloads before the inner transport's collective."""

    name = "quant"

    def __init__(self, inner: Transport | str = "xla", *,
                 mode: str = "bf16", error_feedback: bool = True,
                 **inner_kwargs):
        super().__init__()
        if mode not in QUANT_WIDTH:
            raise ValueError(
                f"unknown quantization mode {mode!r}; choose from "
                f"{sorted(QUANT_WIDTH)}")
        if isinstance(inner, Transport) and inner_kwargs:
            raise ValueError(
                "pass inner transport kwargs only with a string inner spec; "
                f"got a constructed transport AND {sorted(inner_kwargs)}")
        self.inner = (inner if isinstance(inner, Transport)
                      else get_transport(inner, **inner_kwargs))
        if isinstance(self.inner, QuantizedTransport):
            raise ValueError(
                "inner= must not be a QuantizedTransport: double "
                "quantization would double-charge scale bytes and hide one "
                "codec's error inside the other's residual")
        self.mode = mode
        # identity is exact: no residual to feed back, no state to thread
        self.error_feedback = error_feedback and mode != "identity"
        self.name = f"quant[{mode}:{self.inner.name}]"

    @property
    def stateful(self) -> bool:  # type: ignore[override]
        return self.error_feedback or self.inner.stateful

    # -- state threading: residual + inner state in one carry ---------------

    def init_state(self, tree: Pytree) -> Pytree | None:
        res = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)
               if self.error_feedback else None)
        inner = self.inner.init_state(tree)
        if res is None:
            return inner
        if inner is None:
            return res
        return {"q": res, "inner": inner}

    def _split_state(self, state):
        if self.error_feedback and self.inner.stateful:
            state = {} if state is None else state
            return state.get("q"), state.get("inner")
        if self.error_feedback:
            return state, None
        return None, state

    def _join_state(self, res, inner):
        if self.error_feedback and self.inner.stateful:
            return {"q": res, "inner": inner}
        if self.error_feedback:
            return res
        return inner

    # -- wire re-pricing ----------------------------------------------------

    def _requant(self, r: CommRecord, n_leaves: int) -> CommRecord:
        """Re-price one delegated sum record at the quantized width."""
        if r.op in ("mean", "host"):
            return r                       # rides dense, unquantized
        width = QUANT_WIDTH[self.mode]
        if r.transport.startswith("sparse"):
            # (value f32, index int32) pairs: only the value half narrows
            wire = r.wire_bytes * (width + 4) // 8
        else:
            wire = r.wire_bytes * width // 4
        if self.mode == "int8" and r.wire_bytes > 0:
            wire += 4 * n_leaves           # per-leaf scale broadcast
        return dataclasses.replace(
            r, transport=f"{r.transport}+{self.mode}", wire_bytes=wire)

    def _delegated(self, mark: int, n_leaves: int) -> None:
        for r in self.inner.log.since(mark):
            self.log.append(self._requant(r, n_leaves))

    # -- encode + delegate --------------------------------------------------

    def _encode(self, tree: Pytree, residual: Pytree | None,
                mask: jax.Array | None) -> tuple[Pytree, Pytree | None]:
        """(dequantized payload, new residual).  A masked-out participant
        contributes zero downstream (the inner masked reduce applies the
        mask) and keeps its residual untouched — the ``SparseTransport``
        masking semantics."""
        def enc(x, r):
            payload = x.astype(jnp.float32)
            if r is not None:
                payload = payload + r
            deq = quantize_leaf(payload, self.mode)
            if r is None:
                return deq, None
            new_r = payload - deq
            if mask is not None:
                new_r = jnp.where(mask != 0, new_r, r)
            return deq, new_r
        flat, treedef = jax.tree.flatten(tree)
        flat_r = (jax.tree.leaves(residual) if residual is not None
                  else [None] * len(flat))
        outs = [enc(x, r) for x, r in zip(flat, flat_r)]
        deq = jax.tree.unflatten(treedef, [o[0] for o in outs])
        if residual is None:
            return deq, None
        return deq, jax.tree.unflatten(treedef, [o[1] for o in outs])

    def _quant_reduce(self, tree: Pytree, axis, *, mask, state, calls: int,
                      tag: str) -> tuple[Pytree, Pytree | None]:
        res, inner_state = self._split_state(state)
        # a state=None call runs residual-free and stays None (the one-shot
        # convention every stateful transport follows)
        residual = None
        if self.error_feedback:
            residual = (jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), tree)
                if res is None else res)
        deq, new_res = self._encode(tree, residual, mask)
        mark = self.inner.log.mark()
        if mask is None:
            total, inner_state = self.inner.all_reduce(
                deq, axis, op="sum", state=inner_state, calls=calls, tag=tag)
        else:
            total, inner_state = self.inner.masked_all_reduce(
                deq, mask, axis, state=inner_state, calls=calls, tag=tag)
        self._delegated(mark, len(jax.tree.leaves(tree)))
        if state is None:
            return total, None
        return total, self._join_state(new_res, inner_state)

    # -- Transport API ------------------------------------------------------

    def all_reduce(self, tree: Pytree, axis, *, op: str = "sum",
                   state: Pytree | None = None, calls: int = 1,
                   tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        if op == "mean":
            mark = self.inner.log.mark()
            out, _ = self.inner.all_reduce(tree, axis, op="mean",
                                           calls=calls, tag=tag)
            self._delegated(mark, len(jax.tree.leaves(tree)))
            return out, state
        if op != "sum":
            raise ValueError(
                f"unknown reduce op {op!r}; choose 'sum' or 'mean'")
        return self._quant_reduce(tree, axis, mask=None, state=state,
                                  calls=calls, tag=tag)

    def masked_all_reduce(self, tree: Pytree, mask: jax.Array, axis, *,
                          state: Pytree | None = None, calls: int = 1,
                          tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        return self._quant_reduce(tree, axis,
                                  mask=jnp.asarray(mask, jnp.float32),
                                  state=state, calls=calls, tag=tag)
