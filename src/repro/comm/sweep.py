"""One scheme x transport sweep, shared by the dry-run and the bench gate.

``launch/dryrun.py --comm`` and ``benchmarks/run.py --suite comm`` report
the same quantity — the MEASURED per-worker merge wire bytes of each
(scheme, transport) cell — so the sweep (workload construction, transport
configuration, the k/kappa = 0.25 acceptance frac) is defined exactly once
here; the two callers only shape the output differently.

Imports of the engine are lazy: ``repro.engine`` imports ``repro.comm`` at
module load, so the dependency must not run both ways at import time.
"""

from __future__ import annotations

import time

SCHEMES = ("average", "delta", "async_delta")
TRANSPORTS = ("xla", "ring", "sparse")


def acceptance_sparse_frac(kappa: int, d: int) -> float:
    """The ISSUE-4 acceptance point, k/kappa = 0.25: keep k = kappa/4
    entries of the (kappa, d) displacement, i.e. frac = (kappa/4)/(kappa*d)
    of the flattened leaf — where sparse wire must be >= 4x under dense."""
    return (kappa // 4) / (kappa * d)


def run_comm_cells(*, m: int = 8, n: int = 240, d: int = 8, kappa: int = 16,
                   tau: int = 10, sparse_frac: float | None = None,
                   repeats: int = 1, seed: int = 0) -> list[dict]:
    """Run every scheme x transport cell; returns one dict per cell with
    the shared config, the best-of-``repeats`` wall seconds (first run
    compiles and is excluded), and the measured merge wire/logical bytes
    from the executor's ``last_comm`` record stream."""
    import jax

    from repro import comm
    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor

    m = min(m, len(jax.devices()))
    if sparse_frac is None:
        sparse_frac = acceptance_sparse_frac(kappa, d)
    key = jax.random.PRNGKey(seed)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)

    cells: list[dict] = []
    for tname in TRANSPORTS:
        kwargs = {"frac": sparse_frac} if tname == "sparse" else {}
        for scheme in SCHEMES:
            ex = MeshExecutor(network=InstantNetwork(),
                              transport=comm.get_transport(tname, **kwargs))
            t0 = time.perf_counter()
            res = ex.run(scheme, w0, data, eval_data, tau=tau, key=ka)
            jax.block_until_ready(res.w_shared)   # compile + first run
            compile_s = time.perf_counter() - t0
            samples = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = ex.run(scheme, w0, data, eval_data, tau=tau, key=ka)
                jax.block_until_ready(res.w_shared)
                samples.append(time.perf_counter() - t0)
            merge = ex.last_comm["by_tag"].get(
                "merge", {"wire_bytes": 0, "logical_bytes": 0, "calls": 0})
            cells.append({
                "scheme": scheme, "transport": tname,
                "m": m, "n": n, "d": d, "kappa": kappa, "tau": tau,
                "sparse_frac": sparse_frac if tname == "sparse" else None,
                "compile_s": round(compile_s, 1),
                "wall_s": min(samples) if samples else compile_s,
                "wall_samples": samples,
                "merge_wire_bytes": merge["wire_bytes"],
                "merge_logical_bytes": merge["logical_bytes"],
                "collective_calls": ex.last_comm["calls"],
                "final_C": float(res.distortion[-1]),
            })
    return cells


def sparse_reduction(cells: list[dict]) -> float:
    """Min over displacement schemes of dense (xla) wire over sparse wire
    ('average' ships means, which ride dense on every transport)."""
    wire = {(c["scheme"], c["transport"]): c["merge_wire_bytes"]
            for c in cells}
    return min(wire[(s, "xla")] / max(wire[(s, "sparse")], 1)
               for s in SCHEMES if s != "average")


def ring_parity(cells: list[dict]) -> dict[str, float]:
    """Per-scheme ring/xla wall ratios (gate takes min regression over
    schemes — noise hits single legs, a real ring slowdown hits all)."""
    wall = {(c["scheme"], c["transport"]): c["wall_s"] for c in cells}
    return {s: wall[(s, "ring")] / max(wall[(s, "xla")], 1e-12)
            for s in SCHEMES}


# ---------------------------------------------------------------------------
# hierarchical (two-tier) cells — shared by dryrun --comm and --suite hier
# ---------------------------------------------------------------------------

HIER_VARIANTS = ("flat", "hier_dense", "hier_sparse")


def run_hier_cells(*, m: int = 8, hosts: int = 2, n: int = 240, d: int = 8,
                   kappa: int = 16, tau: int = 10,
                   tier1_frac: float | None = None, repeats: int = 1,
                   seed: int = 0) -> list[dict]:
    """Every scheme through the flat mesh and the hierarchical one (dense
    and sparse tier 1) on the same data; returns one dict per cell with
    the measured per-tier merge wire bytes, wall seconds, final
    distortion, and (for the hierarchical dense cells) whether the run
    bit-matched the flat reference — the tentpole's oracle equivalence.

    Needs ``hosts * (m // hosts)`` devices; ``m`` is clamped to a whole
    number of host groups on small device counts (hosts collapses to 1
    when fewer than ``hosts`` devices exist — the degenerate topology).
    """
    import jax
    import numpy as np

    from repro import comm
    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor
    from repro.topology import Topology

    n_dev = len(jax.devices())
    hosts = min(hosts, n_dev)
    wph = max(1, min(m, n_dev) // hosts)
    m = hosts * wph
    if tier1_frac is None:
        tier1_frac = acceptance_sparse_frac(kappa, d)
    key = jax.random.PRNGKey(seed)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    topo = Topology.from_spec(m, hosts=hosts)

    def make_ex(variant):
        if variant == "flat":
            return MeshExecutor(network=InstantNetwork())
        tier1 = "xla" if variant == "hier_dense" else "sparse"
        transport = comm.HierarchicalTransport(
            tier0="xla", tier1=tier1,
            tier1_frac=tier1_frac if tier1 == "sparse" else None,
            host_axis=topo.host_axis, worker_axis=topo.worker_axis)
        return MeshExecutor(topology=topo, network=InstantNetwork(),
                            transport=transport)

    cells: list[dict] = []
    flat_final: dict[str, tuple] = {}
    for variant in HIER_VARIANTS:
        for scheme in SCHEMES:
            ex = make_ex(variant)
            t0 = time.perf_counter()
            res = ex.run(scheme, w0, data, eval_data, tau=tau, key=ka)
            jax.block_until_ready(res.w_shared)   # compile + first run
            compile_s = time.perf_counter() - t0
            samples = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = ex.run(scheme, w0, data, eval_data, tau=tau, key=ka)
                jax.block_until_ready(res.w_shared)
                samples.append(time.perf_counter() - t0)
            merge = ex.last_comm["by_tag"].get(
                "merge", {"wire_bytes": 0, "logical_bytes": 0, "calls": 0})
            by_tier = merge.get("by_tier", {})
            cell = {
                "scheme": scheme, "variant": variant,
                "hosts": hosts if variant != "flat" else 1,
                "workers_per_host": wph if variant != "flat" else m,
                "m": m, "n": n, "d": d, "kappa": kappa, "tau": tau,
                "tier1_frac": (tier1_frac if variant == "hier_sparse"
                               else None),
                "compile_s": round(compile_s, 1),
                "wall_s": min(samples) if samples else compile_s,
                "wall_samples": samples,
                "merge_wire_bytes": merge["wire_bytes"],
                "tier0_wire_bytes": by_tier.get(0, {}).get("wire_bytes", 0),
                "tier1_wire_bytes": by_tier.get(1, {}).get("wire_bytes", 0),
                "final_C": float(res.distortion[-1]),
            }
            if variant == "flat":
                flat_final[scheme] = (np.asarray(res.w_shared),
                                      np.asarray(res.distortion))
            else:
                fw, fc = flat_final[scheme]
                cell["bitmatch_flat"] = bool(
                    np.array_equal(fw, np.asarray(res.w_shared))
                    and np.array_equal(fc, np.asarray(res.distortion)))
            cells.append(cell)
    return cells


def hier_inter_reduction(cells: list[dict]) -> float:
    """Min over displacement schemes of the dense tier-1 wire over the
    sparse tier-1 wire — the inter-host bytes the sparse tier saves on the
    slow links ('average' ships means, which ride dense everywhere)."""
    wire = {(c["scheme"], c["variant"]): c["tier1_wire_bytes"]
            for c in cells if c["variant"] != "flat"}
    return min(wire[(s, "hier_dense")] / max(wire[(s, "hier_sparse")], 1)
               for s in SCHEMES if s != "average")


def hier_wall_parity(cells: list[dict]) -> dict[str, float]:
    """Per-scheme hier-dense/flat wall ratios (same box, machine divides
    out; the gate takes the min regression over schemes)."""
    wall = {(c["scheme"], c["variant"]): c["wall_s"] for c in cells}
    return {s: wall[(s, "hier_dense")] / max(wall[(s, "flat")], 1e-12)
            for s in SCHEMES}


# ---------------------------------------------------------------------------
# adaptive-communication cells — shared by dryrun --comm and --suite adapt
# ---------------------------------------------------------------------------

ADAPT_QUANTS = ("dense", "bf16", "int8")
# divergence threshold tuned at the bench shape (m=8, n=240, d=8,
# kappa=16, tau=10): triggers 18 of 24 windows, landing the final
# distortion within 0.6% of the best fixed-tau leg at ~76% of its wire —
# inside the gate's rtol=1e-2 / strictly-fewer-bytes acceptance region
# with margin on both sides
ADAPT_THRESH = 2e-5
ADAPT_TAUS = (5, 10, 20)


def _adapt_transport(quant: str):
    from repro import comm
    if quant == "dense":
        return comm.get_transport("xla")
    return comm.get_transport("quant", inner="xla", mode=quant)


def _adapt_wire(last_comm: dict) -> tuple[int, int, int]:
    """(merge, probe, total) per-worker wire bytes of one run — the
    dynamic merge pays for its divergence probe, so the comparison
    charges probe traffic against the bytes the skipped merges saved."""
    by_tag = last_comm["by_tag"]
    merge = by_tag.get("merge", {}).get("wire_bytes", 0)
    probe = by_tag.get("probe", {}).get("wire_bytes", 0)
    return merge, probe, merge + probe


def run_adapt_cells(*, m: int = 8, n: int = 240, d: int = 8,
                    kappa: int = 16, tau: int = 10,
                    thresh: float = ADAPT_THRESH, max_stale: int = 8,
                    repeats: int = 1, seed: int = 0) -> list[dict]:
    """{fixed, dynamic} x {dense, bf16, int8} delta-merge cells on one
    workload: the fixed rows merge every tau-window, the dynamic rows
    merge only when the probed global drift crosses ``thresh`` (synced at
    latest every ``max_stale`` windows).  Each cell reports the measured
    merge + probe wire bytes, how many windows actually triggered, wall
    seconds, and the final distortion."""
    import jax

    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor

    m = min(m, len(jax.devices()))
    key = jax.random.PRNGKey(seed)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    n_windows = n // tau

    cells: list[dict] = []
    for quant in ADAPT_QUANTS:
        for mode in ("fixed", "dynamic"):
            ex_kw = {}
            if mode == "dynamic":
                ex_kw = {"merge": "dynamic", "divergence_thresh": thresh,
                         "max_stale": max_stale}
            ex = MeshExecutor(network=InstantNetwork(),
                              transport=_adapt_transport(quant), **ex_kw)
            t0 = time.perf_counter()
            res = ex.run("delta", w0, data, eval_data, tau=tau, key=ka)
            jax.block_until_ready(res.w_shared)   # compile + first run
            compile_s = time.perf_counter() - t0
            samples = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = ex.run("delta", w0, data, eval_data, tau=tau, key=ka)
                jax.block_until_ready(res.w_shared)
                samples.append(time.perf_counter() - t0)
            merge_w, probe_w, total_w = _adapt_wire(ex.last_comm)
            n_trig = (ex.last_comm["by_tag"].get("merge", {}).get("calls", 0)
                      if mode == "dynamic" else n_windows)
            cells.append({
                "merge": mode, "quant": quant,
                "m": m, "n": n, "d": d, "kappa": kappa, "tau": tau,
                "thresh": thresh if mode == "dynamic" else None,
                "max_stale": max_stale if mode == "dynamic" else None,
                "compile_s": round(compile_s, 1),
                "wall_s": min(samples) if samples else compile_s,
                "wall_samples": samples,
                "merge_wire_bytes": merge_w,
                "probe_wire_bytes": probe_w,
                "total_wire_bytes": total_w,
                "n_windows": n_windows,
                "n_triggered": n_trig,
                "final_C": float(res.distortion[-1]),
            })
    return cells


def run_fixed_tau_legs(*, taus: tuple = ADAPT_TAUS, m: int = 8,
                       n: int = 240, d: int = 8, kappa: int = 16,
                       seed: int = 0) -> list[dict]:
    """Plain delta-merge legs across merge periods — the fixed-tau
    frontier the dynamic merge has to beat (match the BEST leg's final
    distortion within rtol at strictly fewer wire bytes)."""
    import jax

    from repro import comm
    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor

    m = min(m, len(jax.devices()))
    key = jax.random.PRNGKey(seed)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)

    legs: list[dict] = []
    for tau in taus:
        ex = MeshExecutor(network=InstantNetwork(),
                          transport=comm.get_transport("xla"))
        res = ex.run("delta", w0, data, eval_data, tau=tau, key=ka)
        jax.block_until_ready(res.w_shared)
        _, _, total_w = _adapt_wire(ex.last_comm)
        legs.append({
            "tau": tau, "m": m, "n": n, "d": d, "kappa": kappa,
            "total_wire_bytes": total_w,
            "n_windows": n // tau,
            "final_C": float(res.distortion[-1]),
        })
    return legs


def best_fixed_leg(legs: list[dict]) -> dict:
    """The fixed-tau leg with the lowest final distortion — the frontier
    point the dynamic cells are gated against."""
    return min(legs, key=lambda leg: leg["final_C"])


def adapt_dynamic_wire_ok(cells: list[dict]) -> bool:
    """Per quant level, the dynamic cell's total (merge + probe) wire must
    not exceed its fixed counterpart's — the probe must pay for itself."""
    wire = {(c["merge"], c["quant"]): c["total_wire_bytes"] for c in cells}
    return all(wire[("dynamic", q)] <= wire[("fixed", q)]
               for q in ADAPT_QUANTS)


def adapt_bitmatch(*, m: int = 8, n: int = 240, d: int = 8,
                   kappa: int = 16, tau: int = 10, seed: int = 0) -> bool:
    """thresh=0 + quantization off: the dynamic merge must reproduce the
    plain fixed-tau delta merge BITWISE (every window triggers, the probe
    adds no numerics, 1.0 * delta and + 0.0 carry are exact)."""
    import jax
    import numpy as np

    from repro import comm
    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor

    m = min(m, len(jax.devices()))
    key = jax.random.PRNGKey(seed)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)

    ex_f = MeshExecutor(network=InstantNetwork(),
                        transport=comm.get_transport("xla"))
    ref = ex_f.run("delta", w0, data, eval_data, tau=tau, key=ka)
    ex_d = MeshExecutor(network=InstantNetwork(),
                        transport=comm.get_transport("xla"),
                        merge="dynamic", divergence_thresh=0.0)
    dyn = ex_d.run("delta", w0, data, eval_data, tau=tau, key=ka)
    return bool(
        np.array_equal(np.asarray(ref.distortion), np.asarray(dyn.distortion))
        and np.array_equal(np.asarray(ref.w_shared), np.asarray(dyn.w_shared)))
