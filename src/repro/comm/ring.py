"""``RingTransport`` — a Pallas ring all-reduce over neighbor RDMA copies.

The XLA collective in ``XlaTransport`` is a black box to the scheduler; a
hand-rolled ring (pallas guide §Ring Collectives) moves the same bytes as
``make_async_remote_copy`` neighbor hops that the latency-hiding scheduler
can overlap with the inner VQ loop — the ROADMAP "TPU-native merge
kernels" item.  The algorithm is the bandwidth-optimal two-phase ring:

  1. **reduce-scatter** — m-1 hops; after hop s, each device has folded its
     left neighbor's partial for chunk ``(my - s - 1) % m`` into its own.
     Device i ends holding the complete sum of chunk ``(i + 1) % m``.
  2. **all-gather**     — m-1 more hops forwarding completed chunks, so
     every device ends with the full summed array.

Per participant that is ``2 * (m-1)/m`` of the payload on the wire — the
same count ``CommRecord`` charges dense transports, so ring and XLA report
identical wire bytes and must produce identical sums.

Off-TPU the remote-DMA primitives do not exist, so the transport falls
back to the XLA collectives (bit-identical numerics, same accounting, the
records just say ``transport='ring'``).  The fallback is also what CI's
forced-host-device meshes exercise; the Pallas path compiles only on a
real TPU backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.comm.api import axis_size
from repro.comm.xla import XlaTransport

_LANE = 128  # TPU lane width: chunk rows stay lane-aligned


def _ring_kernel(x_ref, o_ref, comm_ref, send_sem, recv_sem, *,
                 axis: str, m: int):
    """Per-device body under shard_map; x_ref/o_ref are (m, chunk) f32."""
    from jax.experimental.pallas import tpu as pltpu

    my = jax.lax.axis_index(axis)
    right = jax.lax.rem(my + 1, m)
    left = jax.lax.rem(my + m - 1, m)

    # neighbor barrier: nobody RDMAs into a peer still outside the kernel
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=(left,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_signal(barrier, inc=1, device_id=(right,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(barrier, 2)

    o_ref[...] = x_ref[...]

    def hop(s: int, send_idx, recv_idx, accumulate: bool):
        """Stage chunk ``send_idx`` into a slot, RDMA it right, fold or
        store the chunk received from the left."""
        slot_s, slot_r = s % 2, (s + 1) % 2
        pl.store(comm_ref, (slot_s, slice(None)),
                 pl.load(o_ref, (pl.ds(send_idx, 1), slice(None)))[0])
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_ref.at[slot_s],
            dst_ref=comm_ref.at[slot_r],
            send_sem=send_sem.at[slot_s],
            recv_sem=recv_sem.at[slot_r],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        got = pl.load(comm_ref, (slot_r, slice(None)))
        if accumulate:
            got = got + pl.load(o_ref, (pl.ds(recv_idx, 1), slice(None)))[0]
        pl.store(o_ref, (pl.ds(recv_idx, 1), slice(None)), got[None, :])

    # phase 1: reduce-scatter — send the running partial for (my - s) % m,
    # fold the left neighbor's partial for (my - s - 1) % m into ours
    for s in range(m - 1):
        hop(s,
            jax.lax.rem(my - s + m, m),
            jax.lax.rem(my - s - 1 + m, m),
            accumulate=True)

    # phase 2: all-gather — forward completed chunks; device i starts with
    # the full sum of chunk (i + 1) % m
    for s in range(m - 1):
        hop(s,
            jax.lax.rem(my + 1 - s + m, m),
            jax.lax.rem(my - s + m, m),
            accumulate=False)


@functools.partial(jax.jit, static_argnames=("axis", "m"))
def _ring_pallas(x: jax.Array, *, axis: str, m: int) -> jax.Array:
    from jax.experimental.pallas import tpu as pltpu

    chunk = x.shape[1]
    try:
        params = {"compiler_params": pltpu.TPUCompilerParams(
            collective_id=0)}
    except AttributeError:  # older pallas spells it as a mosaic dict
        params = {"compiler_params": {"mosaic": {"collective_id": 0}}}
    return pl.pallas_call(
        functools.partial(_ring_kernel, axis=axis, m=m),
        out_shape=jax.ShapeDtypeStruct((m, chunk), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, chunk), jnp.float32),     # double-buffered slots
            pltpu.SemaphoreType.DMA((2,)),           # send
            pltpu.SemaphoreType.DMA((2,)),           # recv
        ],
        **params,
    )(x)


def ring_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Elementwise f32 sum of ``x`` across ``axis`` via the Pallas ring."""
    m = axis_size(axis)
    flat = x.reshape(-1).astype(jnp.float32)
    if m == 1:
        return flat.reshape(x.shape)
    chunk = -(-flat.size // m)                       # ceil split per device
    chunk = -(-chunk // _LANE) * _LANE               # lane-aligned rows
    pad = m * chunk - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = _ring_pallas(flat.reshape(m, chunk), axis=axis, m=m)
    return out.reshape(-1)[: x.size].reshape(x.shape)


class RingTransport(XlaTransport):
    """Dense merges over the Pallas ring; XLA fallback off-TPU.

    ``use_pallas=None`` (default) auto-detects: the ring kernel needs real
    inter-chip RDMA, so anything but the TPU backend takes the XLA path.
    Wire accounting is identical either way — the ring moves exactly the
    bytes the dense convention charges.
    """

    name = "ring"

    def __init__(self, use_pallas: bool | None = None):
        super().__init__()
        self.use_pallas = use_pallas

    def _pallas_ok(self) -> bool:
        if self.use_pallas is not None:
            return self.use_pallas
        return jax.default_backend() == "tpu"

    def _sum_leaf(self, x: jax.Array, axis: str) -> jax.Array:
        if not self._pallas_ok():
            return super()._sum_leaf(x, axis)
        return ring_all_reduce(x, axis)

    def _mean_leaf(self, x: jax.Array, axis: str) -> jax.Array:
        if not self._pallas_ok():
            return super()._mean_leaf(x, axis)
        return (ring_all_reduce(x, axis) / axis_size(axis)).astype(x.dtype)
