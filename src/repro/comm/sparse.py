"""``SparseTransport`` — top-k + error-feedback compressed merges.

The communication-efficient protocol the LM window step carried privately
(``Merge.DELTA_SPARSE``), lifted to a first-class transport so the VQ
engine's displacement merges can ride it too: each participant keeps only
its k largest-|.| entries of (payload + residual), all-gathers the
(value, index) pairs — the wire is ``M * k * 8`` bytes instead of the
dense ``N * 4`` — and scatter-adds them into a dense sum.  The skipped
mass is carried into the next call's payload (error feedback, Stich et
al. style), so nothing is lost, only delayed.

Semantics notes:

  * Only **sums** are compressed (displacements are the compressible
    object — they concentrate; absolute parameter values do not).
    ``op='mean'`` and non-floating leaves ride the dense XLA path, so
    ``AverageMerge`` over this transport is bit-identical to the dense one.
  * The transport is **stateful**: ``init_state`` returns the per-leaf f32
    residual tree, threaded through scan carries like any stateful merge.
    A ``state=None`` call runs residual-free (plain top-k) and discards
    the new residual — correct for one-shot merges, wasteful in a loop.
  * ``masked_all_reduce`` composes compression with the eq.-9 masked
    merge: every participant selects and gathers top-k (the wire cost is
    paid either way — SPMD programs cannot skip a collective), but a
    zero-mask participant contributes zero values and keeps its residual
    untouched, so workers mid-round neither send garbage nor consume
    error feedback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.api import (CommRecord, Pytree, Transport, axis_label,
                            axis_size, tree_f32_bytes)
from repro.comm.xla import XlaTransport


def topk_count(size: int, frac: float) -> int:
    """Entries kept per leaf: ``max(1, int(frac * size))`` (the convention
    shared with ``optim.compression``)."""
    return max(1, int(frac * size))


def topk_threshold_mask(x: jax.Array, frac: float) -> jax.Array:
    """Dense 0/1 mask keeping the ``frac`` largest-|x| entries (>= the
    k-th magnitude, so ties widen the mask).  The TPU-friendly dense-mask
    form used by ``optim.compression.topk_compress``."""
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, topk_count(flat.size, frac))[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def sparse_allsum(leaf: jax.Array, residual: jax.Array, frac: float,
                  axis: str, mask: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Top-k sparse cross-worker sum with error feedback (one leaf).

    Returns ``(summed_dense_f32, new_residual)``.  With ``mask`` given
    (a scalar, 1 = this worker participates this call), masked-out workers
    gather zeros and keep their residual unchanged.
    """
    flat = leaf.reshape(-1).astype(jnp.float32)
    full = flat + residual.reshape(-1)
    k = topk_count(full.size, frac)
    _, idx = jax.lax.top_k(jnp.abs(full), k)
    vals = full[idx]
    kept = jnp.zeros_like(full).at[idx].set(vals)
    new_residual = (full - kept).reshape(leaf.shape)
    if mask is not None:
        vals = vals * mask
        new_residual = jnp.where(
            mask != 0, new_residual, residual.reshape(leaf.shape))
    all_vals = jax.lax.all_gather(vals, axis)          # (M, k) — the wire
    all_idx = jax.lax.all_gather(idx, axis)            # (M, k)
    summed = jnp.zeros_like(full).at[all_idx.reshape(-1)].add(
        all_vals.reshape(-1))
    return summed.reshape(leaf.shape), new_residual


class SparseTransport(Transport):
    """Top-k/error-feedback sums; dense XLA for means and non-floating."""

    name = "sparse"
    stateful = True

    def __init__(self, frac: float = 0.01):
        super().__init__()
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"compression frac must be in (0, 1], "
                             f"got {frac}")
        self.frac = frac
        # the dense sidecar shares this log so mean/diagnostic records
        # land in the same stream, labeled with their own transport name
        self._dense = XlaTransport()
        self._dense.log = self.log

    def init_state(self, tree: Pytree) -> Pytree:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), tree)

    def _wire_bytes(self, tree: Pytree, m: int) -> int:
        """Ring all-gather of (f32 value, int32 index) top-k chunks: each
        participant forwards m-1 chunks of k entries."""
        if m <= 1:
            return 0
        return sum((m - 1) * topk_count(int(leaf.size), self.frac) * 8
                   for leaf in jax.tree.leaves(tree))

    def _sparse_sum(self, tree: Pytree, axis: str, *, op: str,
                    state: Pytree | None, calls: int, tag: str,
                    mask: jax.Array | None) -> tuple[Pytree, Pytree]:
        m = axis_size(axis)
        self.log.append(CommRecord(
            op=op, transport=self.name, axis=axis_label(axis),
            participants=m, logical_bytes=tree_f32_bytes(tree),
            wire_bytes=self._wire_bytes(tree, m), calls=calls, tag=tag))
        residual = self.init_state(tree) if state is None else state
        flat, treedef = jax.tree.flatten(tree)
        flat_r = jax.tree.leaves(residual)
        outs = [sparse_allsum(d, r, self.frac, axis, mask)
                for d, r in zip(flat, flat_r)]
        total = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_state = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return total, (None if state is None else new_state)

    def all_reduce(self, tree: Pytree, axis: str, *, op: str = "sum",
                   state: Pytree | None = None, calls: int = 1,
                   tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        if op == "mean":
            out, _ = self._dense.all_reduce(tree, axis, op="mean",
                                            calls=calls, tag=tag)
            return out, state
        if op != "sum":
            raise ValueError(
                f"unknown reduce op {op!r}; choose 'sum' or 'mean'")
        return self._sparse_sum(tree, axis, op="sum", state=state,
                                calls=calls, tag=tag, mask=None)

    def masked_all_reduce(self, tree: Pytree, mask: jax.Array, axis: str, *,
                          state: Pytree | None = None, calls: int = 1,
                          tag: str = "merge") -> tuple[Pytree, Pytree | None]:
        return self._sparse_sum(tree, axis, op="masked_sum", state=state,
                                calls=calls, tag=tag,
                                mask=jnp.asarray(mask, jnp.float32))
