"""Pluggable communication layer — merge transports + wire-byte accounting.

One ``Transport`` protocol (``comm.api``), four implementations:

  * ``XlaTransport``    (``comm.xla``)    — stock XLA f32 collectives; the
    default and the numerics oracle every other transport is tested against.
  * ``RingTransport``   (``comm.ring``)   — Pallas ring all-reduce built on
    ``make_async_remote_copy`` neighbor hops (TPU); XLA fallback elsewhere.
  * ``SparseTransport`` (``comm.sparse``) — top-k + error-feedback
    compressed sums (the LM DELTA_SPARSE protocol as an engine-level
    citizen).
  * ``HierarchicalTransport`` (``comm.hier``) — two-tier merges over a
    ``repro.topology.Topology``: dense intra-host (tier 0), sparse
    inter-host (tier 1), composing the transports above with per-tier
    ``CommRecord``s.
  * ``QuantizedTransport`` (``comm.quant``) — bf16/int8/identity delta
    codecs with error-feedback residual, decorating any of the above;
    delegated records are re-priced at the quantized wire width.

Every collective the engine/training layers issue goes through a
transport, which appends a ``CommRecord`` (logical + wire bytes, per
participant, per call) to its ``CommLog`` — so dry-runs and benches report
bytes that were measured from the program, not modeled.
"""

from repro.comm.api import (CommLog, CommRecord, Transport, axis_label,
                            axis_size, get_transport, ring_wire_bytes,
                            tree_f32_bytes)
from repro.comm.hier import HierarchicalTransport
from repro.comm.quant import QUANT_WIDTH, QuantizedTransport, quantize_leaf
from repro.comm.ring import RingTransport, ring_all_reduce
from repro.comm.sparse import (SparseTransport, sparse_allsum, topk_count,
                               topk_threshold_mask)
from repro.comm.xla import XlaTransport

__all__ = [
    "CommLog", "CommRecord", "Transport", "axis_label", "axis_size",
    "get_transport", "ring_wire_bytes", "tree_f32_bytes",
    "XlaTransport", "RingTransport", "SparseTransport",
    "HierarchicalTransport", "QuantizedTransport",
    "QUANT_WIDTH", "quantize_leaf",
    "ring_all_reduce", "sparse_allsum", "topk_count", "topk_threshold_mask",
]
