"""hymba-1.5b — NVIDIA Hymba 1.5B [arXiv:2411.13676; hf].

Hybrid: attention and Mamba heads run in PARALLEL in every layer; most
layers use sliding-window attention (window 1024) with 3 global layers
(first / middle / last).  25 q-heads don't divide TP=16, so attention is
replicated on 'model'; the SSM inner dim (3200) and MLP carry the TP shard.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
    ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    window=1024, rope_theta=10000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid", n_layers=3, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, ssm_state=8,
        ssm_expand=2, ssm_headdim=32, ssm_conv=4, window=8,
        dtype=jnp.float32)
