"""internvl2-76b — InternVL2 76B backbone (InternLM2/Llama3-70B-style LLM)
[arXiv:2404.16821; unverified].

The InternViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, d_model) prepended to the token sequence.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256,
    img_tokens=256, rope_theta=500000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=448, vocab=512, img_tokens=8,
        dtype=jnp.float32)
