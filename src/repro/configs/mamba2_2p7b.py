"""mamba2-2.7b — Mamba-2 SSD 2.7B [arXiv:2405.21060; unverified].

Attention-free; state-space duality with d_state=128, headdim=64, expand=2.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    tie_embeddings=True, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=128,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=512, ssm_state=16,
        ssm_expand=2, ssm_headdim=32, ssm_conv=4, tie_embeddings=True,
        dtype=jnp.float32)
