"""Architecture registry: ``get_config(arch_id)`` + shape cells + input specs.

Every assigned architecture is a module ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (exact published dims) and ``smoke_config()`` (reduced same-family
config for CPU tests).  This module adds the shape grid and the
ShapeDtypeStruct input builders used by the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCH_IDS = [
    "granite_34b", "granite_8b", "starcoder2_7b", "command_r_35b",
    "whisper_tiny", "moonshot_v1_16b_a3b", "olmoe_1b_7b", "mamba2_2p7b",
    "internvl2_76b", "hymba_1p5b",
]

# archs whose params+optimizer need ZeRO-3 ('data'-axis) sharding to fit v5e.
# starcoder2: 36 heads don't divide TP=16 => attention params replicate on
# 'model'; without FSDP their f32 Adam moments alone are ~22 GiB/device
# (measured 19.1 GiB peak -> 1.2 GiB with FSDP; EXPERIMENTS.md §Perf).
FSDP_ARCHS = {"granite_34b", "command_r_35b", "internvl2_76b",
              "moonshot_v1_16b_a3b", "starcoder2_7b"}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = [
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
]

# long_500k needs sub-quadratic decode state: run only for SSM/hybrid
LONG_OK_FAMILIES = {"ssm", "hybrid"}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.smoke_config()


def uses_fsdp(arch_id: str) -> bool:
    return arch_id in FSDP_ARCHS


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) pair."""
    if cell.name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, "full quadratic attention at 524k context (DESIGN.md §4)"
    return True, ""


def input_specs(cfg: ModelConfig, cell: ShapeCell, *, tau: int | None = None
                ) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``tau``: if given (window step), a leading tau dim is added to each leaf.
    """
    b, t = cell.global_batch, cell.seq_len

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, jnp.int32)

    if cell.kind in ("train", "prefill"):
        t_text = t
        batch: dict = {}
        if cfg.family == "vlm":
            t_text = t - cfg.img_tokens
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.img_tokens, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        batch["tokens"] = tok((b, t_text))
        if cell.kind == "train":
            batch["labels"] = tok((b, t_text))
        if tau is not None:
            batch = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((tau, *s.shape), s.dtype),
                batch)
        return batch
    # decode: one new token against a cache of length seq_len
    return {"tokens": tok((b, 1))}


def cache_shapes(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs of the decode cache for a decode cell."""
    from repro.models import transformer, encdec as _  # noqa

    if cfg.family == "encdec":
        L, b = cfg.n_layers, cell.global_batch
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        h = cfg.n_heads
        return {
            "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
            "k": jax.ShapeDtypeStruct((L, b, cell.seq_len, hkv, dh), cfg.dtype),
            "v": jax.ShapeDtypeStruct((L, b, cell.seq_len, hkv, dh), cfg.dtype),
            "ck": jax.ShapeDtypeStruct(
                (L, b, cfg.encoder_frames, h, dh), cfg.dtype),
            "cv": jax.ShapeDtypeStruct(
                (L, b, cfg.encoder_frames, h, dh), cfg.dtype),
        }
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, cell.global_batch, cell.seq_len))
