"""command-r-35b — Cohere Command-R v01 [hf:CohereForAI/c4ai-command-r-v01; unverified].

GQA (8 KV heads), no biases, 256k vocab.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000,
    rope_theta=8000000.0, use_bias=False, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=352, vocab=1000, dtype=jnp.float32)
