"""olmoe-1b-7b — AllenAI OLMoE-1B-7B [arXiv:2409.02060; hf].

MoE: 64 experts, top-8, per-expert d_ff 1024.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, rope_theta=10000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab=512, n_experts=8, top_k=2,
        dtype=jnp.float32)
