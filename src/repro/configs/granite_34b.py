"""granite-34b — IBM Granite Code 34B [arXiv:2405.04324; hf].

Llama-arch dense decoder, MQA (1 KV head), code vocab 49152.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152,
    rope_theta=10000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=1, d_ff=512, vocab=512, dtype=jnp.float32)
