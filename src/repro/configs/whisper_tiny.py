"""whisper-tiny — OpenAI Whisper tiny [arXiv:2212.04356; unverified].

Encoder-decoder; conv/mel frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, 384).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    encoder_layers=4, encoder_frames=1500, tie_embeddings=True,
    dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, encoder_layers=2,
        encoder_frames=16, tie_embeddings=True, dtype=jnp.float32)
