"""starcoder2-7b — BigCode StarCoder2 7B [arXiv:2402.19173; hf].

GQA (4 KV heads), RoPE.  36 q-heads do NOT divide the 16-way TP axis, so
attention runs replicated on 'model' and the MLP carries the TP sharding
(see distributed/sharding.py policy).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152,
    rope_theta=1000000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke", family="dense", n_layers=2, d_model=144,
        n_heads=6, n_kv_heads=2, d_ff=512, vocab=512, dtype=jnp.float32)
