"""moonshot-v1-16b-a3b — Moonshot Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B; hf].

MoE: 64 experts, top-6, per-expert d_ff 1408.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, rope_theta=50000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=96, vocab=512, n_experts=8, top_k=2,
        dtype=jnp.float32)
