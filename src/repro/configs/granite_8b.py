"""granite-8b — IBM Granite Code 8B [arXiv:2405.04324; hf]."""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=49152,
    rope_theta=10000.0, dtype=jnp.bfloat16,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=384, vocab=512, dtype=jnp.float32)
