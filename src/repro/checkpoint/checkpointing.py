"""Shard-aware checkpointing with async writes and elastic restore.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json      # step, pytree structure, leaf shapes/dtypes, mesh
        leaf_000.npy ...   # one .npy per leaf (host-local full array)

Design points for 1000+-node deployments (documented; exercised here on one
host):

  * every leaf is written through a temp file + atomic rename, and the
    manifest is written LAST — a partially written checkpoint is never
    restorable, so a crash mid-save can't corrupt the latest good step;
  * ``save_async`` snapshots leaves to host memory (jax.device_get) and hands
    the I/O to a daemon thread — the train loop never blocks on disk;
  * ``restore`` takes an optional target sharding pytree: arrays are laid out
    onto whatever mesh the *restarting* job has (elastic restart: the new job
    may have a different device count than the one that saved);
  * ``latest_step``/``gc_old`` implement retention for long runs.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import queue
from typing import Any

import jax
import ml_dtypes
import numpy as np

# numpy can't natively (de)serialize ml_dtypes types; leaves are stored as
# raw same-width integer views with the logical dtype in the manifest.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    return arr.view(_VIEW[name]) if name in _VIEW else arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _leaf_paths(tree) -> list[str]:
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in paths]


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._queue: queue.Queue = queue.Queue()
        self._worker = threading.Thread(target=self._drain, daemon=True)
        self._worker.start()
        self._errors: list[Exception] = []

    # -- writing ----------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self._queue.put((step, host_tree))

    def save_async(self, step: int, tree: Any) -> None:
        self.save(step, tree, blocking=False)

    def wait(self) -> None:
        self._queue.join()
        if self._errors:
            raise self._errors[0]

    def _drain(self) -> None:
        while True:
            step, host_tree = self._queue.get()
            try:
                self._write(step, host_tree)
            except Exception as e:  # surfaced on wait()
                self._errors.append(e)
            finally:
                self._queue.task_done()

    def _write(self, step: int, host_tree: Any) -> None:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_tree)
        names = _leaf_paths(host_tree)
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"),
                    _to_storable(np.asarray(leaf)))
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "names": names,
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "treedef": str(treedef),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- reading ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any,
                shardings: Any | None = None) -> Any:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic placement on the current mesh."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(target)
        if len(leaves) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"target has {len(leaves)}")
        loaded = [
            _from_storable(np.load(os.path.join(path, f"leaf_{i:05d}.npy")),
                           manifest["dtypes"][i])
            for i in range(len(leaves))]
        for i, (got, want) in enumerate(zip(loaded, leaves)):
            if tuple(got.shape) != tuple(np.shape(want)):
                raise ValueError(
                    f"leaf {manifest['names'][i]}: checkpoint shape "
                    f"{got.shape} != target {np.shape(want)}")
        if shardings is not None:
            shard_leaves = jax.tree.leaves(shardings)
            loaded = [jax.device_put(a, s)
                      for a, s in zip(loaded, shard_leaves)]
        else:
            loaded = [jax.device_put(a) for a in loaded]
        return jax.tree.unflatten(treedef, loaded)
