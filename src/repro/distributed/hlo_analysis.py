"""Post-SPMD HLO accounting: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` and a naive text scan both count a ``while``
body ONCE, but our layer stacks are ``lax.scan``s — a collective inside the
body runs ``n_layers`` times per step.  This module parses the compiled HLO
into computations, builds the while-call graph, infers each loop's trip
count, and multiplies collective bytes by the product of enclosing trip
counts.

Trip-count inference: jax lowers ``scan`` so the stacked xs/ys (leading dim
== trip count) are threaded through the while carry.  We take the mode of
the leading dims (>1) of the while op's carried tuple — cross-checked against
the known layer counts by the caller (``expected_trips``).
"""

from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8, "u64": 8,
          "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16}

# NB: multi-char prefixes before their prefix (f8e4m3fn before f16's f1?
# no overlap, but c128 must precede c64-style matches and s16 before s1...)
# — the alternation is ordered longest-first within each family.
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s16|s32|s64|s8|u16|u32|u64|u8|"
                       r"pred|f8e4m3fn|f8e5m2|c128|c64)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _leading_dims(type_str: str) -> list[int]:
    """Leading dims of the non-predicate tuple elements.

    The VQ async loop's masked all-reduce threads ``pred[M]`` activity
    masks through the while carry; counting those vectors in the
    leading-dim mode lets the worker count M outvote the true trip count
    (the stacked xs/ys leading dim), so predicate shapes are excluded
    from trip inference.  (They still count toward ``_shape_bytes`` —
    the exclusion is only for the trip-count heuristic.)
    """
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt == "pred":
            continue
        parts = [p for p in dims.split(",") if p]
        if parts:
            out.append(int(parts[0]))
    return out


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)


def _parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        is_header = (line and not line[0].isspace()
                     and line.rstrip().endswith("{")
                     and ("->" in line or line.startswith("ENTRY"))
                     and ("%" in line or line.startswith("ENTRY")))
        if is_header:
            name = line.strip().split("(")[0].strip()
            if name.startswith("ENTRY"):
                name = name[len("ENTRY"):].strip()
            name = name.lstrip("%")
            cur = Computation(name=name)
            comps[name] = cur
        elif cur is not None and line.strip() == "}":
            cur = None
        elif cur is not None:
            cur.lines.append(line)
    return comps


_WHILE_RE = re.compile(
    r"=\s*(.*?)\s+while\(.*?body=%?([\w.\-]+)", re.S)
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")


def _trip_from_condition(cond: "Computation | None") -> int | None:
    """jax lowers scan bounds as ``s32[] constant(N)`` compared against the
    induction variable inside the while CONDITION computation — exact."""
    if cond is None:
        return None
    consts = [int(m.group(1)) for line in cond.lines
              for m in [_CONST_RE.search(line)] if m]
    if len(consts) == 1:
        return consts[0]
    return max(consts) if consts else None


def analyze_collectives(hlo: str, *, default_trip: int = 1) -> dict:
    """Collective bytes per device, trip-count-weighted.

    Returns {'total_bytes', 'bytes_by_kind', 'count_by_kind',
             'loops': [(body, trip)], 'in_loop_bytes', 'top_ops'}.
    """
    comps = _parse_computations(hlo)

    # multiplier per computation (product of enclosing loop trips)
    mult: dict[str, int] = {name: 1 for name in comps}
    # map body-computation -> trip count, from each while op
    trips: dict[str, int] = {}
    for comp in comps.values():
        for line in comp.lines:
            if " while(" not in line:
                continue
            m = _WHILE_RE.search(line)
            if not m:
                continue
            body = m.group(2)
            mc = _WHILE_COND_RE.search(line)
            trip = _trip_from_condition(
                comps.get(mc.group(1)) if mc else None)
            if trip is None:  # fallback: mode of carried leading dims
                dims = [d for d in _leading_dims(m.group(1)) if d > 1]
                trip = (Counter(dims).most_common(1)[0][0]
                        if dims else default_trip)
            trips[body] = trip

    # propagate multipliers through the call graph (bounded iterations)
    callers: dict[str, list[tuple[str, int]]] = {}
    for comp in comps.values():
        for line in comp.lines:
            for callee in _CALL_RE.findall(line):
                if callee in comps:
                    callers.setdefault(callee, []).append((comp.name, 1))

    def multiplier(name: str, depth=0) -> int:
        if depth > 20:
            return 1
        if name not in callers:
            return 1
        best = 1
        for caller, _ in callers[name]:
            m = multiplier(caller, depth + 1)
            if name in trips:
                m *= trips[name]
            best = max(best, m)
        return best

    bytes_by_kind: dict[str, float] = {}
    count_by_kind: dict[str, int] = {}
    in_loop = 0.0
    f32_ar_bytes = 0.0
    top_ops: list[tuple[float, str, str]] = []
    for comp in comps.values():
        m = multiplier(comp.name)
        for line in comp.lines:
            s = line.strip()
            if "=" not in s:
                continue
            lhs, rhs = s.split("=", 1)
            kind = None
            result_type = ""
            for k in _COLL_KINDS:
                mm = re.match(rf"\s*(\([^)]*\)|\S+)\s+{k}(-start)?\(", rhs)
                if mm:
                    kind = k
                    result_type = mm.group(1)
                    break
            if kind is None or f"{kind}-done" in rhs:
                continue
            b = _shape_bytes(result_type) * m
            bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
            count_by_kind[kind] = count_by_kind.get(kind, 0) + m
            if kind == "all-reduce" and "f32[" in result_type:
                f32_ar_bytes += b
            if m > 1:
                in_loop += b
            top_ops.append((b, kind, comp.name))
    top_ops.sort(reverse=True)
    total = sum(bytes_by_kind.values())
    # XLA:CPU's AllReducePromotion pass rewrites every bf16 all-reduce to
    # f32 (convert -> f32 AR -> convert); real TPUs reduce bf16 natively.
    # tpu_adjusted halves f32 all-reduce bytes as the TPU-lowering estimate
    # (conservative: legitimately-f32 reductions get halved too, but
    # production grad sync is bf16-dominant).
    ar_f32 = f32_ar_bytes
    adjusted = total - ar_f32 / 2
    return {
        "total_bytes": total,
        "tpu_adjusted_bytes": adjusted,
        "f32_allreduce_bytes": ar_f32,
        "bytes_by_kind": bytes_by_kind,
        "count_by_kind": count_by_kind,
        "loops": sorted(trips.items()),
        "in_loop_bytes": in_loop,
        "top_ops": [(f"{b:.3e}", k, c) for b, k, c in top_ops[:8]],
    }


def flops_corrected(cost_flops: float, hlo: str) -> dict:
    """Estimate total-device flops: cost_analysis counts each while body once;
    we report the loop trip counts so callers can sanity-check against the
    analytic model (exact per-op flop re-attribution is not available from
    the public API)."""
    comps = _parse_computations(hlo)
    trips = {}
    for comp in comps.values():
        for line in comp.lines:
            if " while(" in line:
                m = _WHILE_RE.search(line)
                if m:
                    dims = [d for d in _leading_dims(m.group(1)) if d > 1]
                    if dims:
                        trips[m.group(2)] = Counter(dims).most_common(1)[0][0]
    return {"reported_flops": cost_flops, "loop_trips": sorted(trips.items())}
