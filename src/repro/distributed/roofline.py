"""Three-term roofline model for every (arch x shape x mesh) cell.

TPU v5e constants (per chip): 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link
ICI.

  compute term    = device_FLOPs / peak_FLOP/s
  memory term     = device_HBM_bytes / HBM_bw
  collective term = device_collective_bytes / link_bw

Because XLA's ``cost_analysis`` counts ``while`` (scan) bodies once, the
compute and memory terms are built ANALYTICALLY from the model config and
the known sharding policy (the same arithmetic a perf engineer does by hand)
and cross-checked against cost_analysis; the collective term comes from the
trip-count-corrected HLO parse (``hlo_analysis``).  All terms are per-device
seconds for ONE step of the cell's kind.
"""

from __future__ import annotations

import dataclasses

from repro.configs.registry import ShapeCell
from repro.models.common import ModelConfig

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (per-device collective bandwidth)


@dataclasses.dataclass(frozen=True)
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def n_devices(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


def mesh_shape(multi_pod: bool) -> MeshShape:
    return MeshShape(2 if multi_pod else 1, 16, 16)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


# ---------------------------------------------------------------------------
# analytic FLOPs (global, then / n_devices with replication waste)
# ---------------------------------------------------------------------------

def _attn_proj_flops_token(cfg: ModelConfig) -> int:
    """Per-token projection matmul FLOPs for one attention layer (fwd)."""
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2 * d * (hq * dh) * 2 + 2 * d * (hkv * dh) * 2  # q,o + k,v


def _attn_score_flops_token(cfg: ModelConfig, ctx: int, window: int = 0) -> int:
    """Per-token score+value FLOPs for context length ``ctx`` (fwd)."""
    eff = min(ctx, window) if window else ctx
    return 2 * 2 * cfg.n_heads * cfg.head_dim * eff  # qk^T and pv


def _mlp_flops_token(cfg: ModelConfig) -> int:
    if cfg.family == "moe":
        return 2 * 3 * cfg.d_model * cfg.d_ff * cfg.top_k
    if cfg.family == "encdec":
        return 2 * 2 * cfg.d_model * cfg.d_ff
    return 2 * 3 * cfg.d_model * cfg.d_ff


def _ssm_flops_token(cfg: ModelConfig) -> int:
    d, di, n, h, p = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    proj = 2 * d * (2 * di + 2 * n + h)
    out = 2 * di * d
    # SSD: intra-chunk quadratic (chunk q=128) + state update/output
    q = 128
    intra = 2 * h * p * q + 2 * q * n  # per token vs chunk
    state = 2 * 2 * h * p * n
    return proj + out + intra + state


def layer_flops_token(cfg: ModelConfig, ctx: int, decode: bool = False) -> float:
    """Fwd FLOPs per token per layer (weighted mix for hybrid schedules)."""
    win = cfg.window
    f = 0.0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        f += _attn_proj_flops_token(cfg)
        f += _attn_score_flops_token(cfg, ctx)
        f += _mlp_flops_token(cfg)
        if cfg.family == "encdec":  # cross attention
            f += 2 * cfg.d_model * cfg.n_heads * cfg.head_dim * 2
            f += 2 * 2 * cfg.n_heads * cfg.head_dim * cfg.encoder_frames
    elif cfg.family == "ssm":
        f += _ssm_flops_token(cfg)
    elif cfg.family == "hybrid":
        glob = 3 / cfg.n_layers
        eff = ctx if not win else (glob * ctx + (1 - glob) * min(ctx, win))
        f += _attn_proj_flops_token(cfg)
        f += _attn_score_flops_token(cfg, int(eff))
        f += _ssm_flops_token(cfg)
        f += _mlp_flops_token(cfg)
    return f


def cell_flops(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Global FLOPs for one step of the cell (fwd [+bwd+remat for train])."""
    b, t = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        tokens = b  # one new token per sequence
        ctx = t
        per_tok = layer_flops_token(cfg, ctx, decode=True) * cfg.n_layers
        head = 2 * cfg.d_model * cfg.vocab
        fwd = tokens * (per_tok + head)
        return {"fwd": fwd, "total": fwd,
                "model_flops": 2 * cfg.active_params() * tokens}
    tokens = b * t
    # mean causal context = t/2
    per_tok = layer_flops_token(cfg, t // 2) * cfg.n_layers
    if cfg.family == "encdec":
        enc_tok = cell.global_batch * cfg.encoder_frames
        enc = enc_tok * (_attn_proj_flops_token(cfg)
                         + _attn_score_flops_token(cfg, cfg.encoder_frames)
                         + 2 * 2 * cfg.d_model * cfg.d_ff) * cfg.encoder_layers
    else:
        enc = 0
    head = 2 * cfg.d_model * cfg.vocab
    fwd = tokens * (per_tok + head) + enc
    if cell.kind == "train":
        total = fwd * 4  # bwd = 2x fwd, full remat = +1x fwd
        model = 6 * cfg.active_params() * tokens
    else:
        total = fwd
        model = 2 * cfg.active_params() * tokens
    return {"fwd": fwd, "total": total, "model_flops": model}


def replication_waste(cfg: ModelConfig, mesh: MeshShape) -> float:
    """FLOP multiplier >= 1 for layers whose TP sharding falls back to
    replication (non-divisible head counts): those FLOPs run on every
    'model'-axis device instead of 1/model of them."""
    tp = mesh.model
    if cfg.family == "ssm":
        return 1.0
    hq_ok = _div(cfg.n_heads, tp)
    if hq_ok:
        return 1.0
    # fraction of per-token layer flops that is attention
    ctx = 2048  # representative
    attn = _attn_proj_flops_token(cfg) + _attn_score_flops_token(cfg, ctx)
    total = layer_flops_token(cfg, ctx)
    frac = attn / total
    return (1 - frac) + frac * tp


# ---------------------------------------------------------------------------
# analytic HBM bytes per device
# ---------------------------------------------------------------------------

def cell_bytes(cfg: ModelConfig, cell: ShapeCell, mesh: MeshShape,
               *, seq_parallel: bool = True) -> dict:
    """Per-device HBM traffic for one step (dominant terms)."""
    n = mesh.n_devices
    params = cfg.n_params()
    p_bytes = params * 2  # bf16
    b, t = cell.global_batch, cell.seq_len
    d = cfg.d_model

    if cell.kind == "decode":
        # weights are read once per token step: all local param shards
        # (decode is memory-bound on weights + cache read/write)
        weight_read = p_bytes / mesh.model  # TP-sharded; DP replicas each read
        if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            kv = (cfg.n_layers * 2 * b * t * cfg.n_kv_heads * cfg.head_dim * 2)
            cache = kv / n  # sharded over batch x seq
        else:
            cache = 0
        if cfg.family in ("ssm", "hybrid"):
            cache += (cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_headdim
                      * cfg.ssm_state * 4 * 2) / max(mesh.model, 1)
        if cfg.family == "moe":
            weight_read = (p_bytes * cfg.active_params() / params) / mesh.model
        act = b * cfg.n_layers * d * 2 * 8 / n
        total = weight_read + cache + act
        return {"total": total, "weights": weight_read, "cache": cache}

    # train / prefill: per-device = local params traffic + activations
    tp_shard = mesh.model
    fsdp = mesh.data if uses_fsdp_name(cfg) else 1
    local_params = p_bytes / tp_shard
    passes = 3 if cell.kind == "train" else 1  # fwd read, bwd read, grad write
    opt = (params * 4 * 2 * 2 / (tp_shard * fsdp)) if cell.kind == "train" else 0
    # activations: residual stream + attention internals, with remat ~2x fwd
    toks_local = b * t / (mesh.dp * (tp_shard if seq_parallel else 1))
    act_unit = toks_local * d * 2
    act = act_unit * cfg.n_layers * 12 * (2 if cell.kind == "train" else 1)
    total = local_params * passes + opt + act
    return {"total": total, "weights": local_params * passes, "opt": opt,
            "activations": act}


def uses_fsdp_name(cfg: ModelConfig) -> bool:
    return cfg.name in {
        "granite-34b", "command-r-35b", "internvl2-76b",
        "moonshot-v1-16b-a3b", "starcoder2-7b",
    }


# ---------------------------------------------------------------------------
# VQ cells — the paper's inner loop, per worker (= per device on the mesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VqCell:
    """Shapes of one VQ *window* on ONE device (= one paper worker).

    The engine runs a window as a fused ``lax.scan`` of ``tau`` stochastic
    VQ steps (assign -> delta -> update, the eq. 3/8 inner loop), then an
    eval-set distortion probe and the cross-worker merge.  The analytic
    flop/byte terms below are the hand counts for those phases, specialized
    to the ``(d, kappa, tau, bm)`` shapes the Pallas path tiles over —
    deliberately the same arithmetic style as ``benchmarks/run.py``'s
    ``bench_vq_kernel`` so the numbers cross-check.
    """

    d: int                 # point dimensionality
    kappa: int             # codebook size
    tau: int               # steps per window (merge period)
    n_eval: int = 0        # eval points scored per window (0 = no probe)
    bm: int = 128          # Pallas block rows (HBM tiling granularity)
    dtype_bytes: int = 4   # codebook/point element width (f32)
    bk: int = 128          # Pallas codebook-block rows (blocked/fused regime)

    def step_flops(self) -> float:
        """One stochastic VQ step: distances ``2*kappa*d`` (|z-w|^2 via the
        expanded dot), argmin ``kappa``, one-hot delta scatter ``2*kappa*d``,
        and the eq.-8 update (scale + add + displacement) ``3*kappa*d``."""
        k, d = self.kappa, self.d
        return 2 * k * d + k + 2 * k * d + 3 * k * d

    def eval_flops(self) -> float:
        """Distortion probe: full distance matrix + min-reduce over codes."""
        return 2 * self.n_eval * self.kappa * self.d + 2 * self.n_eval * self.kappa

    def merge_flops(self) -> float:
        """Post-collective combine: scale + add over the codebook."""
        return 3 * self.kappa * self.d

    def window_flops(self) -> float:
        """Device FLOPs for one full window (tau steps + probe + merge)."""
        return self.tau * self.step_flops() + self.eval_flops() + self.merge_flops()

    def window_hbm_bytes(self) -> float:
        """Dominant per-window HBM traffic: each step re-reads the codebook
        (twice: assign + update) and streams its point; the probe streams the
        eval shard; the merge reads + writes the codebook once."""
        b = self.dtype_bytes
        k, d = self.kappa, self.d
        per_step = 2 * k * d * b + d * b + k * b     # codebook x2, point, codes
        probe = self.n_eval * d * b
        merge = 2 * k * d * b
        return self.tau * per_step + probe + merge

    def merge_collective_bytes(self) -> float:
        """Logical all-reduce payload of one dense merge: the codebook."""
        return self.kappa * self.d * self.dtype_bytes

    # -- blocked/fused delta kernel terms (the autotuner's objective) ------

    def delta_grid(self, batch: int) -> tuple[int, int]:
        """(codebook_blocks, batch_blocks) of the fused blocked kernel's
        two-sweep grid, after ``ops.py``'s padding to tile multiples."""
        kb = -(-self.kappa // self.bk)
        nb = -(-batch // self.bm)
        return kb, nb

    def delta_flops(self, batch: int) -> float:
        """One fused assign+delta dispatch over a (batch, d) block of
        points: the distance sweep's expanded dot + argmin and the
        accumulate sweep's one-hot matmul scatter."""
        k, d = self.kappa, self.d
        distance = 2 * batch * k * d + batch * k
        accumulate = 2 * batch * k * d + batch * k
        return distance + accumulate

    def delta_hbm_bytes(self, batch: int) -> float:
        """HBM traffic of the fused blocked kernel INCLUDING refetches:
        both sweeps re-stream each (bm, d) point block once per codebook
        block and each (bk, d) codebook block once per batch block — the
        tile-size-dependent term the autotuner trades against VMEM
        residency (larger tiles => fewer refetches => fewer bytes)."""
        kb, nb = self.delta_grid(batch)
        b = self.dtype_bytes
        k, d = self.kappa, self.d
        sweeps = 2 * (kb * batch * d * b + nb * k * d * b)
        outputs = k * d * b + k * b + 2 * batch * b   # zsum, counts, arg+min
        return sweeps + outputs


def vq_roofline_terms(cell: VqCell,
                      collective_bytes_per_window: float | None = None) -> dict:
    """Per-window roofline terms (seconds) for one VQ worker-device.

    ``collective_bytes_per_window`` should come from the trip-count-
    corrected HLO parse of the *actual* compiled program
    (``hlo_analysis.analyze_collectives``); the analytic
    ``merge_collective_bytes`` is only the dense-merge lower bound used
    when no compiled program is available.
    """
    coll = (cell.merge_collective_bytes()
            if collective_bytes_per_window is None
            else collective_bytes_per_window)
    terms = {
        "compute": cell.window_flops() / PEAK_FLOPS,
        "memory": cell.window_hbm_bytes() / HBM_BW,
        "collective": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dominant,
        "window_flops": cell.window_flops(),
        "window_hbm_bytes": cell.window_hbm_bytes(),
        "collective_bytes": coll,
        "window_time_bound_s": max(terms.values()),   # perfect-overlap bound
    }


# ---------------------------------------------------------------------------
# terms
# ---------------------------------------------------------------------------

def roofline_terms(cfg: ModelConfig, cell: ShapeCell, mesh: MeshShape,
                   collective_bytes_per_dev: float) -> dict:
    fl = cell_flops(cfg, cell)
    waste = replication_waste(cfg, mesh)
    dev_flops = fl["total"] * waste / mesh.n_devices
    by = cell_bytes(cfg, cell, mesh)

    t_compute = dev_flops / PEAK_FLOPS
    t_memory = by["total"] / HBM_BW
    t_coll = collective_bytes_per_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())  # perfect-overlap bound
    mfu = (fl["model_flops"] / mesh.n_devices / PEAK_FLOPS) / step_time \
        if step_time > 0 else 0.0
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dominant,
        "device_flops": dev_flops,
        "device_bytes": by["total"],
        "bytes_detail": by,
        "model_flops": fl["model_flops"],
        "useful_ratio": fl["model_flops"] / (fl["total"] * waste),
        "replication_waste": waste,
        "step_time_bound_s": step_time,
        "mfu_bound": mfu,
    }
