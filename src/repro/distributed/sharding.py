"""PartitionSpec assignment for every param / optimizer / batch / cache leaf.

Policy (see DESIGN.md §5):
  * TP ('model' axis): attention head dims (only when head counts divide the
    axis — GQA archs like starcoder2 (36H) or hymba (25H) keep attention
    replicated and shard the MLP instead), d_ff / d_inner, expert count,
    vocab.
  * FSDP ('data' axis, when cfg asks for it): one additional non-TP dim per
    weight leaf — XLA turns this into per-layer all-gather inside the scan
    (ZeRO-3) and reduce-scatter of the matching grads.
  * DP ('pod', 'data'): the batch dim of inputs.
  * decode caches: batch over DP when divisible, sequence over 'model'
    (+ leftover DP axes when batch can't shard — the long_500k b=1 case).

Everything is derived from (ModelConfig, mesh) — no per-arch hand tables.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import api as model_api
from repro.models.common import ModelConfig, make_rules, ShardingRules


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _wspec(r: ShardingRules, shape: tuple[int, ...], tp_dim: int | None,
           *, has_layer_dim: bool = True) -> P:
    """Spec for a weight leaf: TP on ``tp_dim`` (already validated), FSDP on
    the first other (non-layer) dim divisible by the fsdp axis."""
    spec: list = [None] * len(shape)
    if tp_dim is not None:
        spec[tp_dim] = r.tp
    start = 1 if has_layer_dim else 0
    if r.fsdp:
        for i in range(start, len(shape)):
            if i != tp_dim and shape[i] % r.fsdp_size == 0 and shape[i] >= r.fsdp_size:
                spec[i] = r.fsdp
                break
    return P(*spec)


def _block_specs(cfg: ModelConfig, r: ShardingRules, blk: dict,
                 *, cross_heads: bool = False) -> dict:
    """Specs for one (stacked-L) block dict, keyed by leaf name."""
    hq_ok = r.heads(cfg.n_heads) is not None
    hkv_ok = r.heads(cfg.n_kv_heads) is not None if cfg.n_kv_heads else False
    di_ok = r.dim(cfg.d_inner) is not None
    ff_ok = r.dim(cfg.d_ff) is not None if cfg.d_ff else False
    e_ok = r.dim(cfg.n_experts) is not None if cfg.n_experts else False
    h_ok = r.dim(cfg.ssm_heads) is not None if cfg.ssm_state else False

    out = {}
    for name, leaf in blk.items():
        shape = tuple(np.shape(leaf)) if not hasattr(leaf, "shape") \
            else tuple(leaf.shape)
        nd = len(shape)
        if name in ("wq", "cwq", "cwk", "cwv"):
            out[name] = _wspec(r, shape, 2 if hq_ok else None)
        elif name in ("wk", "wv"):
            out[name] = _wspec(r, shape, 2 if hkv_ok else None)
        elif name in ("wo", "cwo"):
            out[name] = _wspec(r, shape, 1 if hq_ok else None)
        elif name in ("w_gate", "w_up"):
            # dense: (L, D, F) TP on F; moe: (L, E, D, F) TP on E
            tp = (1 if e_ok else None) if nd == 4 else (2 if ff_ok else None)
            out[name] = _wspec(r, shape, tp)
        elif name == "w_down":
            tp = (1 if e_ok else None) if nd == 4 else (1 if ff_ok else None)
            out[name] = _wspec(r, shape, tp)
        elif name == "router":
            out[name] = _wspec(r, shape, 2 if e_ok else None)
        elif name in ("in_z", "in_x"):
            out[name] = _wspec(r, shape, 2 if di_ok else None)
        elif name == "out_proj":
            out[name] = _wspec(r, shape, 1 if di_ok else None)
        elif name == "conv_x":
            out[name] = _wspec(r, shape, 2 if di_ok else None)
        elif name == "in_dt":
            out[name] = _wspec(r, shape, 2 if h_ok else None)
        elif name in ("in_bc", "conv_bc"):
            out[name] = _wspec(r, shape, None)
        elif name in ("A_log", "D", "dt_bias"):
            out[name] = P(None, r.tp) if h_ok else P(None, None)
        else:  # norms and anything small: replicated
            out[name] = P(*([None] * nd))
    return out


def param_specs(cfg: ModelConfig, mesh: Mesh, *, use_fsdp: bool) -> dict:
    """Pytree of PartitionSpec matching ``api.init(cfg, key)``'s structure."""
    r = make_rules(mesh, use_fsdp=use_fsdp)
    api = model_api.get_api(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))

    v_ok = r.dim(cfg.vocab) is not None
    d_ok = r.dim(cfg.d_model) is not None
    embed_spec = _wspec(
        r, (cfg.vocab, cfg.d_model), 0 if v_ok else (1 if d_ok else None),
        has_layer_dim=False)

    specs: dict = {}
    for key, sub in shapes.items():
        if key == "embed":
            specs[key] = embed_spec
        elif key == "lm_head":
            specs[key] = _wspec(r, (cfg.d_model, cfg.vocab),
                                1 if v_ok else None, has_layer_dim=False)
        elif key in ("blocks", "enc_blocks", "dec_blocks"):
            specs[key] = _block_specs(cfg, r, sub)
        else:  # final_norm, enc_norm, ...
            specs[key] = P(*([None] * len(sub.shape)))
    return specs


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: dict) -> dict:
    """Specs for a train/prefill input batch: batch dim over the DP axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    dp_size = int(np.prod([sizes[a] for a in dp])) if dp else 1

    def spec_for(leaf):
        b = leaf.shape[0]
        first = dp if dp_size and b % dp_size == 0 else ()
        rest = [None] * (len(leaf.shape) - 1)
        return P(first if first else None, *rest)

    return jax.tree.map(spec_for, batch)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache: dict) -> dict:
    """Decode-cache specs.  Leaves carry a leading L dim (layer-scanned)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r = make_rules(mesh, use_fsdp=False)
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    dp_size = int(np.prod([sizes[a] for a in dp])) if dp else 1
    tp_size = sizes.get("model", 1)

    def kv_spec(leaf):  # (L, B, S, Hkv, Dh)
        _, b, s = leaf.shape[:3]
        b_axes = dp if b % max(dp_size, 1) == 0 and dp_size > 1 else ()
        s_axes = ["model"] if "model" in sizes else []
        if not b_axes:  # long-context b=1: fold DP axes into the seq shard
            s_axes = list(dp) + s_axes
        s_total = int(np.prod([sizes[a] for a in s_axes])) if s_axes else 1
        if s_total == 0 or s % max(s_total, 1) != 0:
            s_axes = []
        return P(None, b_axes if b_axes else None,
                 tuple(s_axes) if s_axes else None, None, None)

    def generic(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.ndim >= 3 and leaf.shape[1] % max(dp_size, 1) == 0 and dp_size > 1:
            # (L, B, ...): batch over DP
            rest = [None] * (leaf.ndim - 2)
            return P(None, dp, *rest)
        return P(*([None] * leaf.ndim))

    specs = {}
    for name, leaf in cache.items():
        if name in ("k", "v", "ck", "cv"):
            specs[name] = kv_spec(leaf)
        elif name == "ssm":  # (L, B, H, P, N)
            h = leaf.shape[2]
            h_ax = "model" if h % tp_size == 0 and tp_size > 1 else None
            b_ax = dp if leaf.shape[1] % max(dp_size, 1) == 0 and dp_size > 1 else None
            specs[name] = P(None, b_ax, h_ax, None, None)
        elif name in ("conv_x", "conv_bc"):  # (L, B, W-1, C)
            c = leaf.shape[3]
            c_ax = "model" if c % tp_size == 0 and tp_size > 1 else None
            b_ax = dp if leaf.shape[1] % max(dp_size, 1) == 0 and dp_size > 1 else None
            specs[name] = P(None, b_ax, None, c_ax)
        else:
            specs[name] = generic(leaf)
    return specs


def named(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def opt_specs_like(param_specs_tree, opt_state):
    """Specs for an AdamState/SGDState: moments mirror their param's spec."""
    from repro.optim.optimizers import AdamState, SGDState
    if isinstance(opt_state, AdamState):
        return AdamState(mu=param_specs_tree, nu=param_specs_tree, count=P())
    if isinstance(opt_state, SGDState):
        mom = param_specs_tree if opt_state.momentum is not None else None
        return SGDState(momentum=mom, count=P())
    raise TypeError(type(opt_state))
