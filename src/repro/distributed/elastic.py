"""Elastic scaling / failure handling for 1000+-node deployments.

The paper's async scheme (S3) is itself the straggler story: a slow worker
delays only its own delta.  This module supplies the surrounding machinery a
production deployment needs when workers *disappear* rather than just slow
down:

  * ``plan_remesh``: given the surviving host set, pick the largest valid
    (data, model) mesh the framework's sharding rules support, biased to
    keep the TP axis intact (TP size changes invalidate head shardings;
    data-axis shrink only re-spreads FSDP shards — cheap).
  * ``ElasticTrainer``-style restart flow: on failure, rebuild the mesh from
    survivors, ``Checkpointer.restore`` onto the new shardings (elastic by
    construction — leaves are stored unsharded), and continue from the
    step-indexed pipeline (no data-iterator state to recover).
  * ``merge_weights``: the paper-faithful rule for integrating a returning
    or late worker's delta (sum displacement into the shared version —
    eq. 8 applied to the straggler's stale window; optionally scaled by
    staleness as in [4], Zinkevich et al.).

The decision logic is pure and unit-tested; the device-level rebuild goes
through ``repro.topology`` like every other mesh in the repo.
"""

from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    dropped_hosts: int
    tp_preserved: bool


def plan_remesh(n_devices: int, *, prev_data: int, prev_model: int
                ) -> RemeshPlan:
    """Largest (data, model) grid over the survivors.

    Prefers keeping ``model`` (TP) intact: params are TP-sharded by
    divisibility rules, so changing TP width can invalidate head shardings,
    while shrinking ``data`` only re-spreads DP/FSDP shards.
    """
    if n_devices >= prev_model and prev_model > 0:
        data = n_devices // prev_model
        return RemeshPlan(data=data, model=prev_model,
                          dropped_hosts=n_devices - data * prev_model,
                          tp_preserved=True)
    # degenerate: fewer devices than the TP width — fall back to the largest
    # power-of-two TP that fits
    model = 1
    while model * 2 <= n_devices:
        model *= 2
    data = n_devices // model
    return RemeshPlan(data=data, model=model,
                      dropped_hosts=n_devices - data * model,
                      tp_preserved=False)


def build_mesh(plan: RemeshPlan) -> jax.sharding.Mesh:
    from repro.topology import Topology
    return Topology.flat(plan.data * plan.model).make_mesh(model=plan.model)


def staleness_scale(delay_windows: int, *, gamma: float = 0.5) -> float:
    """Weight for a late worker's delta: 1 / (1 + delay)^gamma.

    delay=0 (on-time) => 1.0 — the paper's eq. (9) applies deltas at full
    weight one round late; heavier staleness is damped as in asynchronous
    SGD practice ([4])."""
    return float(1.0 / (1.0 + delay_windows) ** gamma)


def merge_late_delta(w_shared, delta, *, delay_windows: int = 0,
                     gamma: float = 0.5):
    """Paper eq. (8)/(9) merge of one (possibly stale) worker delta."""
    import jax.numpy as jnp
    s = staleness_scale(delay_windows, gamma=gamma)
    return jax.tree.map(
        lambda w, d: (w.astype(jnp.float32)
                      - s * d.astype(jnp.float32)).astype(w.dtype),
        w_shared, delta)
