"""Benchmark harness — one function per paper table/figure + kernel/system
benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --suite engine   # executor bench
    PYTHONPATH=src python -m benchmarks.run --suite elastic  # resize cost
    PYTHONPATH=src python -m benchmarks.run --suite serve    # lookup service
    PYTHONPATH=src python -m benchmarks.run --suite hier     # flat vs 2-tier
    PYTHONPATH=src python -m benchmarks.run --suite obs      # tracing cost
    PYTHONPATH=src python -m benchmarks.run --suite chaos    # fault injection
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.xla_flags import force_host_devices

# the engine suite runs MeshExecutor up to M=8 workers; harmless for the
# single-device benches
force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, iters=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_fig1() -> list[str]:
    from benchmarks.paper_figs import fig1_averaging
    t0 = time.perf_counter()
    res = fig1_averaging()
    us = (time.perf_counter() - t0) * 1e6
    final = {m: float(c[-1]) for m, c in res["curves"].items()}
    ratio = final[10] / final[1]
    return [f"fig1_averaging,{us:.0f},final_C(M=10)/C(M=1)={ratio:.3f}"
            f" (paper: ~1 — no speed-up)"]


def bench_fig2() -> list[str]:
    from benchmarks.paper_figs import fig2_delta
    t0 = time.perf_counter()
    res = fig2_delta()
    us = (time.perf_counter() - t0) * 1e6
    final = {m: float(c[-1]) for m, c in res["curves"].items()}
    ratio = final[10] / final[1]
    return [f"fig2_delta,{us:.0f},final_C(M=10)/C(M=1)={ratio:.3f}"
            f" (paper: <1 — speed-up)"]


def bench_fig3() -> list[str]:
    from benchmarks.paper_figs import fig3_async
    t0 = time.perf_counter()
    res = fig3_async()
    us = (time.perf_counter() - t0) * 1e6
    final = {m: float(c[-1]) for m, c in res["curves"].items()}
    ratio = final[10] / final[1]
    return [f"fig3_async,{us:.0f},final_C(M=10)/C(M=1)={ratio:.3f}"
            f" (paper: async ~ sync delta)"]


def bench_fig4() -> list[str]:
    from benchmarks.paper_figs import fig4_scaleup
    t0 = time.perf_counter()
    res = fig4_scaleup()
    us = (time.perf_counter() - t0) * 1e6
    t = res["ticks_to_threshold"]
    base = t.get(1, -1)
    speed32 = (base / t[32]) if t.get(32, -1) > 0 and base > 0 else float("nan")
    return [f"fig4_scaleup,{us:.0f},speedup(M=32)={speed32:.1f}x ticks={t}"]


def bench_vq_kernel() -> list[str]:
    """Pallas kernel vs jnp reference (interpret mode on CPU: correctness
    harness; wall time is NOT TPU-indicative — roofline numbers live in
    EXPERIMENTS.md §Roofline)."""
    from repro.kernels import ops, ref
    rows = []
    key = jax.random.PRNGKey(0)
    for (b, k, d) in [(4096, 256, 64), (16384, 1024, 64)]:
        z = jax.random.normal(key, (b, d))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
        us_ref = _time_call(lambda: ref.vq_delta_ref(z, w))
        c_ref, s_ref = ref.vq_delta_ref(z, w)
        c, s = ops.vq_delta(z, w)
        err = float(jnp.max(jnp.abs(s - s_ref)))
        # analytic TPU roofline for the fused kernel (bf16):
        flops = 2 * b * k * d + 2 * b * k * d  # dist matmul + scatter matmul
        bytes_ = (b * d + k * d * 2 + k) * 4
        t_c = flops / 197e12
        t_m = bytes_ / 819e9
        bound = "compute" if t_c > t_m else "memory"
        rows.append(
            f"vq_delta_b{b}_k{k}_d{d},{us_ref:.0f},"
            f"oracle_maxerr={err:.1e} tpu_bound={bound}"
            f" t_c={t_c * 1e6:.1f}us t_m={t_m * 1e6:.1f}us")
    return rows


def bench_merge_strategies() -> list[str]:
    """Paper schemes as LM training merge strategies: pod-axis collective
    bytes per step from the multi-pod dry-run records (populate with
    ``python -m repro.launch.dryrun --arch granite_8b --shape train_4k
    --multi-pod --merge <m>``)."""
    import json
    import os
    rows = []
    path = "benchmarks/results/dryrun.json"
    if not os.path.exists(path):
        return ["merge_strategies,0,missing benchmarks/results/dryrun.json"]
    with open(path) as f:
        data = json.load(f)
    recs = [r for r in data
            if r.get("mesh") == "2x16x16" and r.get("status") == "ok"
            and r.get("merge", "none") != "none"]
    if not recs:
        return ["merge_strategies,0,no multi-pod merge records yet"]
    for rec in recs:
        div = rec.get("per_step_divisor", 1)
        per_step = rec["collectives"]["total_bytes"] / div
        rows.append(
            f"merge_{rec['arch']}_{rec['merge']},"
            f"{rec['compile_s'] * 1e6:.0f},"
            f"coll_bytes_per_step={per_step:.3e}")
    return rows


def bench_training_throughput() -> list[str]:
    """Wall-clock CPU throughput of the end-to-end train step (tiny model) —
    exercises the full substrate (data, model, optimizer)."""
    from repro.configs import registry
    from repro.data.pipeline import DataConfig, lm_batch
    from repro.optim import optimizers
    from repro.training import steps as steps_lib
    cfg = registry.get_smoke_config("granite_8b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    opt = optimizers.adamw(1e-3)
    step = jax.jit(steps_lib.make_train_step(cfg, opt))
    state = steps_lib.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    batch = lm_batch(dcfg, 0)
    state, _ = step(state, batch)  # compile
    us = _time_call(lambda: step(state, batch)[0]["step"])
    toks = dcfg.seq_len * dcfg.global_batch
    return [f"train_step_smoke,{us:.0f},tokens_per_s={toks / us * 1e6:.0f}"]


def bench_decode_throughput() -> list[str]:
    from repro.configs import registry
    from repro.training import steps as steps_lib
    from repro.models.api import get_api
    cfg = registry.get_smoke_config("granite_8b")
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32)}
    cache = api.init_cache(params, batch, 128)
    step = jax.jit(steps_lib.make_serve_step(cfg))
    tok = jnp.zeros((8, 1), jnp.int32)
    _, cache2 = step(params, cache, tok)  # compile
    us = _time_call(lambda: step(params, cache, tok)[0])
    return [f"decode_step_smoke,{us:.0f},tokens_per_s={8 / us * 1e6:.0f}"]


def bench_engine(*, quick: bool = False,
                 out_path: str = "BENCH_engine.json") -> list[str]:
    """SimExecutor vs MeshExecutor wall-clock per processed point, M = 1..8.

    Each executor runs the delta scheme end to end (compile excluded via a
    warm-up run); "per point" divides by the M*n points the run consumes, so
    the number is the engine's cost of one unit of the paper's work.  Writes
    the full trajectory record to ``BENCH_engine.json``.

    A second leg runs each scheme at M=8 on the mesh executor with kernel
    fusion on vs off (``MeshExecutor(fused=...)``) — same data, same seeds,
    the only difference is one-dispatch window/delta kernels plus the
    overlapped publish drain.  Both walls are measured on the same box, so
    the fused/unfused ratio is machine-free and ``check_regression`` gates
    it (sync legs must not be slower fused) along with bitwise curve
    equality."""
    from repro.data import synthetic
    from repro.engine import InstantNetwork, get_executor

    n, d, kappa, tau = (400 if quick else 1000), 8, 16, 10
    key = jax.random.PRNGKey(0)
    kd, kw = jax.random.split(key)
    rows, records = [], []
    for m in (1, 2, 4, 8):
        data = synthetic.replicate_stream(kd, m, n=n, d=d)
        eval_data = data[:, :200]
        w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
        for name in ("sim", "mesh"):
            ex = get_executor(name, network=InstantNetwork())
            run = lambda: jax.block_until_ready(  # noqa: E731
                ex.run("delta", w0, data, eval_data, tau=tau).w_shared)
            run()  # compile
            samples = []
            for _ in range(3):  # best-of-3: single runs are too noisy to gate
                t0 = time.perf_counter()
                res = ex.run("delta", w0, data, eval_data, tau=tau)
                jax.block_until_ready(res.w_shared)
                samples.append(time.perf_counter() - t0)
            wall_s = min(samples)
            points = m * (n // tau) * tau
            us_per_point = wall_s / points * 1e6
            rows.append(f"engine_{name}_M{m},{wall_s * 1e6:.0f},"
                        f"us_per_point={us_per_point:.3f}"
                        f" final_C={float(res.distortion[-1]):.5f}")
            records.append({
                "executor": name, "scheme": "delta", "m": m, "n": n,
                "d": d, "kappa": kappa, "tau": tau,
                "wall_s": wall_s, "us_per_point": us_per_point,
                "wall_samples": samples,
                "wall_ticks": np.asarray(res.wall_ticks).tolist(),
                "distortion": np.asarray(res.distortion,
                                         np.float64).tolist(),
            })

    # -- fused vs unfused, per scheme, M=8 (data/w0 left from the loop).
    # async_delta's per-tick program is identical at these shapes (the
    # blocked route isn't taken), so only the sync legs carry a wall gate;
    # every leg pins bitwise curve equality — fusion trades dispatches,
    # never math.
    m = 8
    for scheme in ("delta", "average", "async_delta"):
        walls, curves = {}, {}
        for fused in (True, False):
            ex = get_executor("mesh", network=InstantNetwork(), fused=fused)
            jax.block_until_ready(
                ex.run(scheme, w0, data, eval_data, tau=tau).w_shared)
            samples = []
            for _ in range(3):
                t0 = time.perf_counter()
                res = ex.run(scheme, w0, data, eval_data, tau=tau)
                jax.block_until_ready(res.w_shared)
                samples.append(time.perf_counter() - t0)
            walls[fused] = samples
            curves[fused] = np.asarray(res.distortion)
        ratio = min(walls[True]) / max(min(walls[False]), 1e-12)
        bitmatch = bool(np.array_equal(curves[True], curves[False]))
        rows.append(f"engine_fusion_{scheme},{min(walls[True]) * 1e6:.0f},"
                    f"fused_over_unfused={ratio:.3f}"
                    f" curve_bitmatch={bitmatch}")
        records.append({
            "kind": "fusion", "executor": f"fusion:{scheme}",
            "scheme": scheme, "m": m, "n": n, "d": d, "kappa": kappa,
            "tau": tau, "sync": scheme != "async_delta",
            "wall_fused_s": min(walls[True]),
            "wall_unfused_s": min(walls[False]),
            "fused_over_unfused": ratio,
            "wall_samples_fused": walls[True],
            "wall_samples_unfused": walls[False],
            "curve_bitmatch": bitmatch,
        })
    with open(out_path, "w") as f:
        json.dump({"suite": "engine", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"engine_trajectories,0,wrote {out_path} "
                f"({len(records)} records)")
    return rows


def bench_elastic(*, quick: bool = False,
                  out_path: str = "BENCH_elastic.json") -> list[str]:
    """What does a resize event cost?  An 8->4->8 elastic run vs the fixed-M
    mesh run on the same sample budget: per-event pause (checkpoint + remesh
    + reshard, measured seconds), amortized per-window overhead, and the
    final-distortion gap.  Writes the full record to ``BENCH_elastic.json``."""
    import tempfile

    from repro.checkpoint.checkpointing import Checkpointer
    from repro.data import synthetic
    from repro.engine import (ElasticMeshExecutor, InstantNetwork,
                              MeshExecutor, ResizeSchedule)

    m0, n, d, kappa, tau = 8, (400 if quick else 1000), 8, 16, 10
    m0 = min(m0, len(jax.devices()))
    key = jax.random.PRNGKey(0)
    kd, kw = jax.random.split(key)
    data = synthetic.replicate_stream(kd, m0, n=n, d=d)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    n_windows = n // tau
    schedule = ResizeSchedule([(n_windows // 2, max(1, m0 // 2)),
                               (n_windows, m0)])

    fixed = MeshExecutor(network=InstantNetwork())
    run_fixed = lambda: jax.block_until_ready(  # noqa: E731
        fixed.run("delta", w0, data, eval_data, tau=tau).w_shared)
    run_fixed()  # compile
    t0 = time.perf_counter()
    res_fixed = fixed.run("delta", w0, data, eval_data, tau=tau)
    jax.block_until_ready(res_fixed.w_shared)
    wall_fixed = time.perf_counter() - t0

    rows, records = [], []
    with tempfile.TemporaryDirectory() as td:
        for label, ck in (("nockpt", None), ("ckpt", Checkpointer(td))):
            ex = ElasticMeshExecutor(schedule, network=InstantNetwork(),
                                     checkpointer=ck)
            run_el = lambda: jax.block_until_ready(  # noqa: E731
                ex.run("delta", w0, data, eval_data, tau=tau).w_shared)
            run_el()  # compile (also warms every segment's program)
            t0 = time.perf_counter()
            res = ex.run("delta", w0, data, eval_data, tau=tau)
            jax.block_until_ready(res.w_shared)
            wall = time.perf_counter() - t0
            if ck is not None:
                ck.wait()
            resize_s = sum(e.wall_s for e in ex.resize_events)
            n_win = len(res.distortion)
            gap = (float(res.distortion[-1])
                   / float(res_fixed.distortion[-1]) - 1.0)
            rows.append(
                f"elastic_{label}_M{m0},{wall * 1e6:.0f},"
                f"resize_s={resize_s:.4f}"
                f" resize_frac={resize_s / wall:.3f}"
                f" final_C_gap={gap:+.4f}")
            for e in ex.resize_events:
                rows.append(
                    f"elastic_{label}_event_w{e.window},{e.wall_s * 1e6:.0f},"
                    f"M{e.old_m}->{e.new_m} late_points={e.late_points}")
            records.append({
                "variant": label, "m0": m0, "n": n, "d": d, "kappa": kappa,
                "tau": tau, "wall_s": wall, "wall_s_fixed": wall_fixed,
                "resize_s_total": resize_s, "n_windows": n_win,
                "final_C": float(res.distortion[-1]),
                "final_C_fixed": float(res_fixed.distortion[-1]),
                "events": [{
                    "window": e.window, "old_m": e.old_m, "new_m": e.new_m,
                    "late_points": e.late_points, "wall_s": e.wall_s,
                    "checkpointed": e.checkpoint_step is not None,
                } for e in ex.resize_events],
            })
    with open(out_path, "w") as f:
        json.dump({"suite": "elastic", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"elastic_trajectories,0,wrote {out_path} "
                f"({len(records)} records)")
    return rows


def bench_serve(*, quick: bool = False,
                out_path: str = "BENCH_serve.json") -> list[str]:
    """The serving subsystem: what do micro-batching and the sharded lookup
    buy, and does a live hot-swap hold up?

      * ``unbatched``  — the naive serving loop: one ``vq_assign`` dispatch
        per single-vector query on ONE device (the pre-serving baseline).
      * ``lookup_M*``  — batched sharded lookup, one bm=128 block per
        device: rows/s at batch = M*128.  The headline ``speedup`` record
        is batched rows/s at max M over the unbatched 1-device figure.
      * ``service``    — the full micro-batching ``QuantizeService`` under
        saturating open-loop load: q/s, p50/p99 (queue-inclusive).
      * ``hotswap``    — a live ``ElasticMeshExecutor`` publishes codebooks
        mid-load: zero failed requests + monotone served versions.

    CPU wall numbers are a correctness/ratio harness, not TPU-indicative
    (same caveat as ``bench_vq_kernel``); the gate in check_regression
    compares the machine-normalized speedup, not absolute rows/s."""
    import threading

    from repro.data import synthetic
    from repro.engine import ElasticMeshExecutor, InstantNetwork, ResizeSchedule
    from repro.serve import (CodebookStore, QuantizeService, ShardedLookup,
                             run_load)

    d, kappa, bm = 32, 64, 128
    key = jax.random.PRNGKey(0)
    kw_, kz = jax.random.split(key)
    w = jax.random.normal(kw_, (kappa, d))
    rows_out, records = [], []

    n_dev = len(jax.devices())
    counts = sorted({1, n_dev} if quick else
                    {m for m in (1, 2, 4, 8) if m <= n_dev})

    # -- unbatched baseline: one dispatch per query on one device
    look1 = ShardedLookup(n_devices=1)
    n_single = 100 if quick else 400
    zs = jax.random.normal(kz, (n_single, 1, d))
    jax.block_until_ready(look1.assign(zs[0], w))  # compile
    t0 = time.perf_counter()
    for i in range(n_single):
        jax.block_until_ready(look1.assign(zs[i], w))
    wall = time.perf_counter() - t0
    unbatched_rps = n_single / wall
    rows_out.append(f"serve_unbatched_M1,{wall / n_single * 1e6:.0f},"
                    f"rows_per_s={unbatched_rps:.0f}")
    records.append({"kind": "unbatched", "m": 1, "kappa": kappa, "d": d,
                    "rows_per_call": 1, "rows_per_s": unbatched_rps})

    # -- batched sharded lookup: one bm block per device
    batched_rps = {}
    for m in counts:
        look = ShardedLookup(n_devices=m)
        batch = m * bm
        z = jax.random.normal(kz, (batch, d))
        us = _time_call(lambda: look.assign(z, w)[0], iters=20)
        batched_rps[m] = batch / us * 1e6
        rows_out.append(f"serve_lookup_M{m},{us:.0f},"
                        f"batch={batch} rows_per_s={batched_rps[m]:.0f}"
                        f" plan={look.plan(kappa, d)}")
        records.append({"kind": "lookup", "m": m, "kappa": kappa, "d": d,
                        "rows_per_call": batch, "us_per_call": us,
                        "rows_per_s": batched_rps[m]})

    m_max = max(counts)
    speedup = batched_rps[m_max] / unbatched_rps
    rows_out.append(f"serve_speedup,0,batched_M{m_max}_over_unbatched="
                    f"{speedup:.1f}x (acceptance bar: >= 4x)")
    records.append({"kind": "speedup", "m": m_max, "kappa": kappa, "d": d,
                    "speedup": speedup})

    # -- service level: micro-batcher + futures under saturating open load
    store = CodebookStore(w)
    n_req = 100 if quick else 400
    with QuantizeService(store, ShardedLookup(n_devices=m_max),
                         max_delay_s=2e-3) as service:
        rep = run_load(service, n_requests=n_req, d=d, rows_per_request=16,
                       network=InstantNetwork(), tick_s=0.0)
    rows_out.append(f"serve_service_M{m_max},0,qps={rep.qps:.0f}"
                    f" rows_per_s={rep.rows_per_s:.0f}"
                    f" p50_ms={rep.p50_ms:.2f} p99_ms={rep.p99_ms:.2f}"
                    f" fill={service.stats.mean_fill:.0f}")
    records.append({"kind": "service", "m": m_max, "kappa": kappa, "d": d,
                    "qps": rep.qps, "rows_per_s": rep.rows_per_s,
                    "p50_ms": rep.p50_ms, "p99_ms": rep.p99_ms,
                    "failed": rep.failed,
                    "mean_fill": service.stats.mean_fill})

    # -- hot swap under load: a live elastic trainer publishes mid-stream
    m_train = min(8, n_dev)
    n_pts = 200 if quick else 400
    data = synthetic.replicate_stream(kz, m_train, n=n_pts, d=d)
    w0 = synthetic.kmeanspp_init(kw_, data.reshape(-1, d), kappa)
    store = CodebookStore(w0)
    n_win = n_pts // 10
    ex = ElasticMeshExecutor(
        ResizeSchedule([(n_win // 2, max(1, m_train // 2)), (n_win, m_train)]),
        network=InstantNetwork(), on_window=store.publisher(),
        publish_every=2)
    ex.run("delta", w0, data, data[:, :100], tau=10)  # compile warm-up
    store = CodebookStore(w0)
    ex.on_window = store.publisher()
    with QuantizeService(store, ShardedLookup(n_devices=m_max),
                         max_delay_s=1e-3) as service:
        trainer = threading.Thread(target=lambda: ex.run(
            "delta", w0, data, data[:, :100], tau=10))
        trainer.start()
        rep = run_load(service, n_requests=n_req, d=d, rows_per_request=4,
                       network=InstantNetwork(), tick_s=1.5e-3)
        trainer.join()
    rows_out.append(
        f"serve_hotswap,0,failed={rep.failed}"
        f" versions={rep.versions_min}..{rep.versions_max}"
        f" monotonic={rep.versions_monotonic}"
        f" published={store.version} staleness_max={rep.staleness_max}")
    records.append({"kind": "hotswap", "m": m_max, "kappa": kappa, "d": d,
                    "failed": rep.failed,
                    "versions_monotonic": rep.versions_monotonic,
                    "versions_served": [rep.versions_min, rep.versions_max],
                    "published": store.version,
                    "staleness_max": rep.staleness_max})

    with open(out_path, "w") as f:
        json.dump({"suite": "serve", "devices": n_dev,
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows_out.append(f"serve_records,0,wrote {out_path} "
                    f"({len(records)} records)")
    return rows_out


def bench_comm(*, quick: bool = False,
               out_path: str = "BENCH_comm.json") -> list[str]:
    """Scheme x transport through the comm layer: wall clock + MEASURED
    merge wire bytes (from the transport's CommRecord stream) per cell.

      * ``cell``            — one (scheme, transport) run: best-of-3 wall,
        per-worker merge wire/logical bytes, final distortion.
      * ``sparse_reduction``— min over displacement schemes of the dense
        (xla) wire over the sparse wire at k/kappa = 0.25.  Machine-
        independent (bytes are trace-exact); acceptance bar >= 4x.
      * ``ring_parity``     — per-scheme ring/xla wall ratios.  On CPU
        meshes the ring transport falls back to the XLA collectives, so
        parity ~1 is the contract; on TPU this measures the Pallas ring
        against the stock collective.  The gate takes the MINIMUM
        regression over the scheme legs (engine-gate precedent: noise on
        an oversubscribed host hits single legs, a real ring slowdown
        hits all of them).

    CPU wall numbers are a correctness/ratio harness, not TPU-indicative
    (same caveat as ``bench_vq_kernel``).  The sweep itself lives in
    ``repro.comm.sweep`` — one definition shared with ``launch/dryrun.py
    --comm``, so the CI gate and the dry-run report cannot drift apart."""
    from repro.comm import sweep

    # best-of-3: single runs too noisy to gate
    cells = sweep.run_comm_cells(n=(200 if quick else 400), repeats=3)
    m, kappa, d = cells[0]["m"], cells[0]["kappa"], cells[0]["d"]
    sparse_frac = next(c["sparse_frac"] for c in cells
                       if c["transport"] == "sparse")
    rows, records = [], []
    for c in cells:
        rows.append(
            f"comm_{c['scheme']}_{c['transport']},{c['wall_s'] * 1e6:.0f},"
            f"merge_wire_B={c['merge_wire_bytes']}"
            f" logical_B={c['merge_logical_bytes']}"
            f" final_C={c['final_C']:.5f}")
        records.append({"kind": "cell", **{k: c[k] for k in (
            "scheme", "transport", "m", "n", "d", "kappa", "tau",
            "sparse_frac", "wall_s", "merge_wire_bytes",
            "merge_logical_bytes", "final_C")}})

    # compression applies to displacement merges ('average' ships means,
    # dense on every transport), so the reduction is min'd over those
    reduction = sweep.sparse_reduction(cells)
    parity = sweep.ring_parity(cells)
    rows.append(f"comm_sparse_reduction,0,xla_over_sparse_wire="
                f"{reduction:.2f}x (bar: >= 4x at k/kappa = 0.25)")
    rows.append("comm_ring_parity,0,ring_over_xla_wall="
                + " ".join(f"{s}={p:.2f}x" for s, p in parity.items()))
    records.append({"kind": "sparse_reduction", "m": m, "kappa": kappa,
                    "d": d, "sparse_frac": sparse_frac,
                    "reduction": reduction})
    records.append({"kind": "ring_parity", "m": m, "parity": parity})

    with open(out_path, "w") as f:
        json.dump({"suite": "comm", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"comm_records,0,wrote {out_path} ({len(records)} records)")
    return rows


def bench_hier(*, quick: bool = False,
               out_path: str = "BENCH_hier.json") -> list[str]:
    """Flat vs hierarchical execution: every scheme through the flat
    8-worker mesh and the 2x4 two-tier topology (dense and sparse tier 1),
    wall clock + MEASURED per-tier merge wire bytes per cell.

      * ``cell``            — one (scheme, variant) run: best-of-3 wall,
        per-worker merge wire split into tier 0 (intra-host) and tier 1
        (inter-host) from the per-tier ``CommRecord``s, final distortion,
        and — for the hierarchical variants — whether the run bit-matched
        the flat reference (``bitmatch_flat``; dense tier 1 MUST, that is
        the tentpole's oracle-equivalence contract).
      * ``inter_reduction`` — min over displacement schemes of the dense
        tier-1 wire over the sparse tier-1 wire.  Machine-independent
        (bytes are trace-exact); acceptance bar >= 4x at k/kappa = 0.25.
      * ``hier_parity``     — per-scheme hier-dense/flat wall ratios (same
        box, machine divides out; the gate takes the min regression over
        schemes, the engine-gate flap-proof statistic).

    CPU wall numbers are a correctness/ratio harness, not TPU-indicative.
    The sweep lives in ``repro.comm.sweep`` — one definition shared with
    ``launch/dryrun.py --comm``'s hier table."""
    from repro.comm import sweep

    cells = sweep.run_hier_cells(n=(200 if quick else 400), repeats=3)
    hier = [c for c in cells if c["variant"] != "flat"]
    tier1_frac = next(c["tier1_frac"] for c in cells
                     if c["variant"] == "hier_sparse")
    rows, records = [], []
    for c in cells:
        extra = ("" if c["variant"] == "flat"
                 else f" bitmatch_flat={c['bitmatch_flat']}")
        rows.append(
            f"hier_{c['scheme']}_{c['variant']},{c['wall_s'] * 1e6:.0f},"
            f"intra_wire_B={c['tier0_wire_bytes']}"
            f" inter_wire_B={c['tier1_wire_bytes']}"
            f" final_C={c['final_C']:.5f}{extra}")
        records.append({"kind": "cell", **c})

    reduction = sweep.hier_inter_reduction(cells)
    parity = sweep.hier_wall_parity(cells)
    dense_bitmatch = all(c["bitmatch_flat"] for c in hier
                         if c["variant"] == "hier_dense")
    rows.append(f"hier_inter_reduction,0,dense_over_sparse_tier1_wire="
                f"{reduction:.2f}x (bar: >= 4x at k/kappa = 0.25)")
    rows.append(f"hier_dense_bitmatch,0,all_schemes={dense_bitmatch}")
    rows.append("hier_wall_parity,0,hier_dense_over_flat_wall="
                + " ".join(f"{s}={p:.2f}x" for s, p in parity.items()))
    records.append({"kind": "inter_reduction",
                    "m": cells[0]["m"], "hosts": hier[0]["hosts"],
                    "kappa": cells[0]["kappa"], "d": cells[0]["d"],
                    "tier1_frac": tier1_frac, "reduction": reduction,
                    "dense_bitmatch": dense_bitmatch})
    records.append({"kind": "hier_parity", "m": cells[0]["m"],
                    "parity": parity})

    with open(out_path, "w") as f:
        json.dump({"suite": "hier", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"hier_records,0,wrote {out_path} ({len(records)} records)")
    return rows


def bench_obs(*, quick: bool = False,
              out_path: str = "BENCH_obs.json") -> list[str]:
    """What does LIVE instrumentation cost?  Every scheme through the
    8-worker mesh twice — bare vs a live ``Tracer`` + ``MetricsRegistry``
    (enabled but unexported, the always-on configuration) — plus one
    traced 2-host hierarchical run pushed through the trace-invariant
    checker.

      * ``overhead`` — per scheme: N interleaved off/on pairs on
        identical seeded runs (A/B alternation so machine drift lands on
        both sides).  Two noise-robust estimators are computed — the
        best-of-N ratio min(on)/min(off) and the median of the per-pair
        on/off ratios — and the recorded overhead is the SMALLER: host
        noise is one-sided (it only ever adds time) and hits the two
        estimators through different failure modes (a single quiet
        sample repairs the min; drift cancellation repairs the median),
        while a genuine instrumentation cost inflates both.  Raw
        per-iteration samples are recorded so the gate can see the
        noise floor.  Acceptance bar: <= 1.03x (instrumentation < 3%).
      * ``trace`` — a 2-host hierarchical delta run with the tracer on:
        the exported Chrome events must pass ``repro.obs.check_trace``
        with tier-0 AND tier-1 merge spans and the per-window
        ``codebook_divergence`` counter present (the ``launch.train
        --hosts 2 --trace`` acceptance criterion, run in-process).

    The overhead ratio is same-box (machine divides out); absolute CPU
    walls are a harness, not TPU-indicative (``bench_vq_kernel`` caveat).
    """
    from repro import comm
    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor
    from repro.obs import MetricsRegistry, Tracer, check_trace
    from repro.topology import Topology

    # n large enough that per-window compute amortizes the fixed
    # per-window emission cost (span count scales with windows, not
    # points); quick mode halves tau, which scales wall time without
    # moving the emission/compute ratio
    m, n, d, kappa, tau = 8, 4000, 8, 16, (50 if quick else 100)
    m = min(m, len(jax.devices()))
    repeats = 5 if quick else 9
    key = jax.random.PRNGKey(0)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)

    rows, records = [], []
    for scheme in ("average", "delta", "async_delta"):
        bare = MeshExecutor(network=InstantNetwork())
        live = MeshExecutor(network=InstantNetwork(), tracer=Tracer(),
                            metrics=MetricsRegistry())
        for ex in (bare, live):  # the observe flag keys a distinct program
            jax.block_until_ready(
                ex.run(scheme, w0, data, eval_data, tau=tau,
                       key=ka).w_shared)
        samples: dict[str, list[float]] = {"off": [], "on": []}
        for _ in range(repeats):
            for label, ex in (("off", bare), ("on", live)):
                t0 = time.perf_counter()
                res = ex.run(scheme, w0, data, eval_data, tau=tau, key=ka)
                jax.block_until_ready(res.w_shared)
                samples[label].append(time.perf_counter() - t0)
        min_ratio = min(samples["on"]) / min(samples["off"])
        pair_ratios = sorted(on / off for on, off
                             in zip(samples["on"], samples["off"]))
        median_pair = pair_ratios[len(pair_ratios) // 2]
        overhead = min(min_ratio, median_pair)
        n_spans = len(live.tracer.spans())
        rows.append(f"obs_overhead_{scheme},"
                    f"{min(samples['on']) * 1e6:.0f},"
                    f"on_over_off={overhead:.3f}x (bar <= 1.03x)"
                    f" min_ratio={min_ratio:.3f} median_pair="
                    f"{median_pair:.3f} spans={n_spans}")
        records.append({
            "kind": "overhead", "scheme": scheme, "m": m, "n": n, "d": d,
            "kappa": kappa, "tau": tau, "repeats": repeats,
            "wall_s_off": min(samples["off"]),
            "wall_s_on": min(samples["on"]),
            "wall_samples_off": samples["off"],
            "wall_samples_on": samples["on"],
            "overhead": overhead, "min_ratio": min_ratio,
            "median_pair": median_pair, "spans": n_spans})

    # -- traced 2-host hierarchical run -> invariant checker
    hosts = min(2, m)
    topo = Topology.from_spec(m, hosts=hosts)
    tracer, registry = Tracer(), MetricsRegistry()
    ex = MeshExecutor(topology=topo, network=InstantNetwork(),
                      transport=comm.HierarchicalTransport(
                          tier0="xla", tier1="xla",
                          host_axis=topo.host_axis,
                          worker_axis=topo.worker_axis),
                      tracer=tracer, metrics=registry)
    jax.block_until_ready(
        ex.run("delta", w0, data, eval_data, tau=tau, key=ka).w_shared)
    events = tracer.chrome_events()
    errors = check_trace(
        events, expect_merge_tiers={"0", "1"},
        expect_counters=["codebook_divergence", "distortion"])
    trace_ok = not errors
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    rows.append(f"obs_trace_hier,0,ok={trace_ok} spans={n_spans} hosts="
                f"{hosts}" + ("" if trace_ok
                              else " errors=" + "; ".join(errors[:3])))
    records.append({
        "kind": "trace", "m": m, "hosts": hosts, "n": n, "d": d,
        "kappa": kappa, "tau": tau, "trace_ok": trace_ok,
        "n_spans": n_spans, "errors": errors})

    with open(out_path, "w") as f:
        json.dump({"suite": "obs", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"obs_records,0,wrote {out_path} ({len(records)} records)")
    return rows


def bench_chaos(*, quick: bool = False, out_path: str = "BENCH_chaos.json",
                seed: int = 7) -> list[str]:
    """Survive the cloud the paper ran on: a seeded kill/slow/partition
    schedule (2 worker deaths -> unscheduled elastic resizes, 1 straggler +
    1 host-group partition -> quorum-merge late folds) against the
    fault-free fixed-M oracle on the SAME sample budget.

      * ``chaos``  — the faulted run: final distortion over the oracle's
        (``distortion_ratio``, the acceptance bound), quorum-merge wire
        bytes (masked collective, trace-exact), recovery wall cost (the
        summed kill-resize pauses), and the full event schedule (the
        seeded-determinism pin: same seed => byte-identical events on
        every device count).
      * ``trace``  — the tracer ran live during the chaos run; the
        exported events must pass ``check_trace`` with the ``chaos_*``
        spans and the late-worker counter present.

    CPU wall numbers are a harness, not TPU-indicative; the gate pins the
    machine-independent quantities (events, wire bytes, distortion ratio).
    """
    from repro.data import synthetic
    from repro.engine import (ChaosNetwork, ChaosSchedule,
                              ElasticMeshExecutor, InstantNetwork,
                              MeshExecutor, ResizeSchedule)
    from repro.obs import MetricsRegistry, Tracer, check_trace

    n, d, kappa, tau = (400 if quick else 800), 8, 16, 10
    m = min(8, len(jax.devices()))
    hosts, quorum_frac = 2, 0.6
    kills = min(2, m - 1)
    schedule = ChaosSchedule.generate(
        seed, windows=n // tau, m=m, kills=kills, slows=1, partitions=1,
        hosts=hosts)
    key = jax.random.PRNGKey(0)
    kd, kw = jax.random.split(key)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)

    # fault-free oracle: the fixed-M delta run on the same sample budget
    oracle = MeshExecutor(network=InstantNetwork())
    run_o = lambda: jax.block_until_ready(  # noqa: E731
        oracle.run("delta", w0, data, eval_data, tau=tau).w_shared)
    run_o()  # compile
    res_o = oracle.run("delta", w0, data, eval_data, tau=tau)
    jax.block_until_ready(res_o.w_shared)

    tracer, registry = Tracer(), MetricsRegistry()
    net = ChaosNetwork(InstantNetwork(), schedule)
    ex = ElasticMeshExecutor(ResizeSchedule([]), network=net, chaos=schedule,
                             merge="quorum", quorum_frac=quorum_frac,
                             tracer=tracer, metrics=registry)
    jax.block_until_ready(
        ex.run("delta", w0, data, eval_data, tau=tau).w_shared)  # compile
    tracer, registry = Tracer(), MetricsRegistry()
    ex.tracer = tracer
    ex.metrics = registry
    for mex in ex._mesh_ex.values():
        mex.tracer, mex.metrics = tracer, registry
    t0 = time.perf_counter()
    res = ex.run("delta", w0, data, eval_data, tau=tau)
    jax.block_until_ready(res.w_shared)
    wall_s = time.perf_counter() - t0
    recovery_s = sum(e.wall_s for e in ex.resize_events
                     if e.cause == "chaos_kill")
    merge_b = ex.last_comm["by_tag"].get("merge", {"wire_bytes": 0,
                                                   "logical_bytes": 0})
    final_c = float(res.distortion[-1])
    final_o = float(res_o.distortion[-1])
    ratio = final_c / final_o

    events = tracer.chrome_events()
    expect = [f"chaos_{e.kind}" for e in schedule]
    errors = check_trace(events, expect_spans=sorted(set(expect)))
    trace_ok = not errors
    trace_path = os.path.splitext(out_path)[0] + ".trace.json"
    tracer.export_chrome(trace_path)

    rows = [
        f"chaos_seed{seed}_M{m},{wall_s * 1e6:.0f},"
        f"distortion_ratio={ratio:.4f} final_C={final_c:.5f}"
        f" oracle_C={final_o:.5f} kills={kills}"
        f" recovery_s={recovery_s:.4f}",
        f"chaos_merge_wire,0,wire_B={merge_b['wire_bytes']}"
        f" logical_B={merge_b['logical_bytes']}",
        f"chaos_schedule,0,{schedule.describe()}",
        f"chaos_trace,0,ok={trace_ok} -> {trace_path}"
        + ("" if trace_ok else " errors=" + "; ".join(errors[:3])),
    ]
    records = [{
        "kind": "chaos",
        "seed": seed, "m": m, "n": n, "d": d, "kappa": kappa, "tau": tau,
        "hosts": hosts, "quorum_frac": quorum_frac,
        "events": [e.as_dict() for e in schedule],
        "final_C": final_c, "final_C_oracle": final_o,
        "distortion_ratio": ratio,
        "merge_wire_bytes": merge_b["wire_bytes"],
        "merge_logical_bytes": merge_b["logical_bytes"],
        "wall_s": wall_s, "recovery_wall_s": recovery_s,
        "resizes": [{"window": e.window, "old_m": e.old_m,
                     "new_m": e.new_m, "cause": e.cause,
                     "late_points": e.late_points,
                     "wall_s": e.wall_s} for e in ex.resize_events],
        "trace_ok": trace_ok, "trace_errors": errors,
    }]
    with open(out_path, "w") as f:
        json.dump({"suite": "chaos", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"chaos_records,0,wrote {out_path} ({len(records)} records)")
    return rows


def bench_profile(*, quick: bool = False,
                  out_path: str = "BENCH_profile.json") -> list[str]:
    """Where does the wall go?  Every scheme through the 8-worker mesh with
    a live ``Profiler``: measured wall decomposed per window against the
    three-term roofline (analytic VQ compute/HBM + collective bytes from
    the compiled program's HLO) plus the host residual.

      * ``attribution`` — per scheme: the best (min-wall) warm run's
        attribution record.  Acceptance: the terms (residual included) sum
        to the measured window wall within 15% — the residual is clamped
        at zero, so the check fails exactly when the modeled terms
        OVERSHOOT measured wall, i.e. when an analytic count or a trip
        count is wrong.  ``collective_bytes_per_window`` is parsed from
        the compiled HLO with trip-count correction, so it is machine-
        independent and pinned EXACTLY by the gate; it is also
        cross-checked here against the transport's own ``CommLog``
        logical-byte accounting of the same program.

    Efficiency gauges are TPU-v5e-relative; on the CPU CI harness they
    are tiny (the host term dominates) — the compute-efficiency floor
    gate only pins that the analytic terms are nonzero and attributed.
    """
    from repro.data import synthetic
    from repro.engine import InstantNetwork, MeshExecutor
    from repro.obs import MetricsRegistry, Profiler

    m, n, d, kappa, tau = 8, (2000 if quick else 4000), 8, 16, 50
    m = min(m, len(jax.devices()))
    repeats = 3 if quick else 5
    key = jax.random.PRNGKey(0)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, : min(200, n)]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)

    rows, records = [], []
    for scheme in ("average", "delta", "async_delta"):
        registry = MetricsRegistry()
        prof = Profiler(metrics=registry)
        ex = MeshExecutor(network=InstantNetwork(), profiler=prof,
                          metrics=registry)
        jax.block_until_ready(
            ex.run(scheme, w0, data, eval_data, tau=tau,
                   key=ka).w_shared)           # compile (AOT + HLO parse)
        for _ in range(repeats):
            jax.block_until_ready(
                ex.run(scheme, w0, data, eval_data, tau=tau,
                       key=ka).w_shared)
        warm = [a for a in prof.attributions if not a["compiled_in_run"]]
        best = min(warm, key=lambda a: a["wall_s"])
        # CommLog ground truth for the same program: every all-reduce the
        # HLO carries per window is a merge- or eval-tagged logical payload
        by_tag = ex.last_comm["by_tag"]
        log_pw = sum(t["logical_bytes"] for t in by_tag.values()) \
            / best["n_windows"]
        eff = best["efficiency"]
        rows.append(
            f"profile_{scheme},{best['wall_s'] * 1e6:.0f},"
            f"consistency={best['consistency']:.4f} (bar <= 0.15)"
            f" coll_B_per_window={best['collective_bytes_per_window']:.1f}"
            f" commlog_B={log_pw:.1f}"
            f" host%={eff['host'] * 100:.1f}")
        records.append({
            "kind": "attribution", "scheme": scheme,
            "transport": ex.transport.name, "m": m, "n": n, "d": d,
            "kappa": kappa, "tau": tau, "repeats": repeats,
            "wall_s": best["wall_s"],
            "commlog_logical_bytes_per_window": log_pw,
            "attribution": best})

    with open(out_path, "w") as f:
        json.dump({"suite": "profile", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"profile_records,0,wrote {out_path} "
                f"({len(records)} records)")
    return rows


def bench_adapt(*, quick: bool = False,
                out_path: str = "BENCH_adapt.json") -> list[str]:
    """Adaptive communication: divergence-triggered merges + quantized
    wire vs the fixed-tau frontier, on one workload.

      * ``cell``       — one (merge, quant) run from the shared
        ``sweep.run_adapt_cells`` grid ({fixed, dynamic} x {dense, bf16,
        int8}): best-of-3 wall, measured merge + probe wire bytes, how
        many of the windows actually triggered, final distortion.
      * ``fixed_leg``  — plain delta-merge legs across tau in (5, 10, 20):
        the fixed-tau frontier the dynamic merge is gated against.
      * ``adapt_summary`` — the acceptance predicates in one record: the
        thresh=0/quant-off run bit-matches the plain delta merge
        (``bitmatch``), and the dynamic-dense and dynamic-int8 cells land
        within rtol 1e-2 of the BEST fixed-tau leg's final distortion at
        strictly fewer total wire bytes.

    Wire bytes and trigger counts are trace-exact and seeded, so the gate
    pins them EXACTLY; only wall rides ratios."""
    from repro.comm import sweep

    n = 160 if quick else 240
    cells = sweep.run_adapt_cells(n=n, repeats=3)
    legs = sweep.run_fixed_tau_legs(n=n)
    bitmatch = sweep.adapt_bitmatch(n=n)
    best = sweep.best_fixed_leg(legs)

    rows, records = [], []
    for c in cells:
        rows.append(
            f"adapt_{c['merge']}_{c['quant']},{c['wall_s'] * 1e6:.0f},"
            f"wire_B={c['total_wire_bytes']}"
            f" trig={c['n_triggered']}/{c['n_windows']}"
            f" final_C={c['final_C']:.5f}")
        records.append({"kind": "cell", **{k: c[k] for k in (
            "merge", "quant", "m", "n", "d", "kappa", "tau", "thresh",
            "max_stale", "wall_s", "merge_wire_bytes", "probe_wire_bytes",
            "total_wire_bytes", "n_windows", "n_triggered", "final_C")}})
    for leg in legs:
        rows.append(f"adapt_fixed_tau{leg['tau']},0,"
                    f"wire_B={leg['total_wire_bytes']}"
                    f" final_C={leg['final_C']:.5f}")
        records.append({"kind": "fixed_leg", **leg})

    dyn = {c["quant"]: c for c in cells if c["merge"] == "dynamic"}
    summary = {
        "kind": "adapt_summary", "bitmatch": bitmatch,
        "best_tau": best["tau"], "best_final_C": best["final_C"],
        "best_wire_bytes": best["total_wire_bytes"],
        "dyn_dense_final_C": dyn["dense"]["final_C"],
        "dyn_dense_wire_bytes": dyn["dense"]["total_wire_bytes"],
        "dyn_int8_final_C": dyn["int8"]["final_C"],
        "dyn_int8_wire_bytes": dyn["int8"]["total_wire_bytes"],
        "dynamic_wire_ok": sweep.adapt_dynamic_wire_ok(cells),
    }
    records.append(summary)
    rows.append(
        f"adapt_summary,0,bitmatch={bitmatch}"
        f" best_tau={best['tau']} best_C={best['final_C']:.5f}"
        f" dyn_C={summary['dyn_dense_final_C']:.5f}"
        f" dyn_wire={summary['dyn_dense_wire_bytes']}"
        f"/{summary['best_wire_bytes']}B")

    with open(out_path, "w") as f:
        json.dump({"suite": "adapt", "devices": len(jax.devices()),
                   "backend": jax.default_backend(),
                   "results": records}, f, indent=1)
    rows.append(f"adapt_records,0,wrote {out_path} ({len(records)} records)")
    return rows


BENCHES = {
    "fig1": bench_fig1,
    "fig2": bench_fig2,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "vq_kernel": bench_vq_kernel,
    "merge": bench_merge_strategies,
    "throughput": bench_training_throughput,
    "decode": bench_decode_throughput,
    "engine": bench_engine,
    "elastic": bench_elastic,
    "serve": bench_serve,
    "comm": bench_comm,
    "hier": bench_hier,
    "obs": bench_obs,
    "chaos": bench_chaos,
    "profile": bench_profile,
    "adapt": bench_adapt,
}

# named groups runnable as `--suite NAME`
SUITES = {
    "engine": ["engine"],
    "elastic": ["elastic"],
    "serve": ["serve"],
    "comm": ["comm"],
    "hier": ["hier"],
    "obs": ["obs"],
    "chaos": ["chaos"],
    "profile": ["profile"],
    "adapt": ["adapt"],
    "paper": ["fig1", "fig2", "fig3", "fig4"],
    "lm": ["throughput", "decode"],
}

# benches that take (quick, out_path) and write a JSON record
_JSON_BENCHES = {"engine": "BENCH_engine.json",
                 "elastic": "BENCH_elastic.json",
                 "serve": "BENCH_serve.json",
                 "comm": "BENCH_comm.json",
                 "hier": "BENCH_hier.json",
                 "obs": "BENCH_obs.json",
                 "chaos": "BENCH_chaos.json",
                 "profile": "BENCH_profile.json",
                 "adapt": "BENCH_adapt.json"}


def suite_out_path(out: str, name: str, *, multi: bool) -> str:
    """Output path for one JSON suite under ``--out``.

    With one JSON suite selected, ``--out`` is used verbatim.  With several,
    each suite gets a derived sibling path — ``--out FRESH.json`` writes
    ``FRESH.engine.json``, ``FRESH.elastic.json``, ... — instead of the old
    behaviour of warning and ignoring ``--out`` entirely."""
    if not out:
        return _JSON_BENCHES[name]
    if not multi:
        return out
    base, ext = os.path.splitext(out)
    return f"{base}.{name}{ext or '.json'}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(BENCHES))
    ap.add_argument("--suite", choices=sorted(SUITES))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--chaos-seed", type=int, default=7,
                    help="chaos suite: seed the kill/slow/partition "
                         "schedule is drawn from (the cron sweep matrixes "
                         "over this)")
    ap.add_argument("--out", default="",
                    help="JSON output path for the engine/elastic/serve "
                         "suites (default: the committed BENCH_<name>.json "
                         "baseline path; CI writes a fresh file and diffs "
                         "against the baseline with "
                         "benchmarks.check_regression).  When several JSON "
                         "suites are selected, each gets a derived sibling "
                         "path: --out F.json -> F.engine.json, ...")
    args = ap.parse_args()
    if args.only:
        names = [args.only]
    elif args.suite:
        names = SUITES[args.suite]
    else:
        names = list(BENCHES)
    if args.quick:
        names = [n for n in names if n not in ("fig4",)]
    json_names = [n for n in names if n in _JSON_BENCHES]
    multi = len(json_names) > 1
    if args.out and multi:
        outs = {n: suite_out_path(args.out, n, multi=True)
                for n in json_names}
        print(f"note: --out covers {len(json_names)} JSON suites; writing "
              + ", ".join(f"{n} -> {p}" for n, p in outs.items()))
    print("name,us_per_call,derived")
    for name in names:
        kwargs = {}
        if name in _JSON_BENCHES:
            kwargs = {"quick": args.quick,
                      "out_path": suite_out_path(args.out, name,
                                                 multi=multi)}
            if name == "chaos":
                kwargs["seed"] = args.chaos_seed
        try:
            for row in BENCHES[name](**kwargs):
                print(row)
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
