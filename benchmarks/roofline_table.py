"""Render the roofline table (EXPERIMENTS.md §Roofline) from dryrun.json.

    PYTHONPATH=src python -m benchmarks.roofline_table [--json PATH]
"""

from __future__ import annotations

import argparse
import json


def load(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| skipped: {r['reason'][:40]} |")
    if r["status"] == "error":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| ERROR |")
    t = r["roofline"]
    dom = t["dominant"]
    peak = r["memory"]["peak_bytes"] / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {t['t_compute']:.4f} | {t['t_memory']:.4f} "
        f"| {t['t_collective']:.4f} | **{dom}** "
        f"| useful={t['useful_ratio']:.2f} mfu≤{t['mfu_bound']:.2f} "
        f"peak={peak:.2f}GiB |")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="benchmarks/results/dryrun.json")
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    args = ap.parse_args()
    rows = load(args.json)
    rows = [r for r in rows if r.get("merge", "none") == "none"]
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("| arch | shape | mesh | t_compute (s) | t_memory (s) "
          "| t_collective (s) | dominant | notes |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))

    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
        print(f"\ndominant-term counts: {doms} over {len(ok)} ok cells")


if __name__ == "__main__":
    main()
