"""Reproductions of the paper's figures 1-4 (performance curves vs wall time).

Each function runs the corresponding scheme for M in {1, 2, 10} (Fig. 4:
up to 32) on the synthetic mixture with tau=10 — the paper's setup — and
returns/prints the distortion curves at matched wall ticks.  The paper's
claims are asserted quantitatively by tests/test_schemes.py; these harness
functions emit the CSV behind EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np

import jax

from repro.core import async_vq, schemes
from repro.data import synthetic

TAU = 10
N = 4000
D = 8
KAPPA = 16
KEY = jax.random.PRNGKey(2012)


def _setup(m):
    kd, kw = jax.random.split(KEY, 2)
    data = synthetic.replicate_stream(kd, m, n=N, d=D)
    # the criterion (eq. 2) is the distortion over the dataset itself
    eval_data = data[:, :1000]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)
    return data, eval_data, w0


def _curve(res, ticks):
    wt = np.asarray(res.wall_ticks)
    dist = np.asarray(res.distortion)
    idx = np.clip(np.searchsorted(wt, ticks), 0, len(dist) - 1)
    return dist[idx]


def fig1_averaging(ms=(1, 2, 10), ticks=(200, 1000, 2000, 4000)) -> dict:
    """Section 2 / Fig. 1: averaging scheme — no speed-up from extra workers."""
    out = {}
    for m in ms:
        data, eval_data, w0 = _setup(m)
        if m == 1:
            res = schemes.scheme_sequential(w0, data[0], eval_data, tau=TAU)
        else:
            res = schemes.scheme_average(w0, data, eval_data, tau=TAU)
        out[m] = _curve(res, list(ticks))
    return {"ticks": list(ticks), "curves": out}


def fig2_delta(ms=(1, 2, 10), ticks=(200, 1000, 2000, 4000)) -> dict:
    """Section 3 / Fig. 2: delta-merge scheme — ~M-fold speed-up."""
    out = {}
    for m in ms:
        data, eval_data, w0 = _setup(m)
        res = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
        out[m] = _curve(res, list(ticks))
    return {"ticks": list(ticks), "curves": out}


def fig3_async(ms=(1, 2, 10), ticks=(200, 1000, 2000, 4000),
               p_delay=0.5) -> dict:
    """Section 4 / Fig. 3: asynchronous scheme with geometric delays."""
    out = {}
    for m in ms:
        data, eval_data, w0 = _setup(m)
        res = async_vq.scheme_async(w0, data, eval_data,
                                    jax.random.fold_in(KEY, m),
                                    tau=TAU, p_delay=p_delay)
        out[m] = _curve(res, list(ticks))
    return {"ticks": list(ticks), "curves": out}


def fig4_scaleup(ms=(1, 2, 4, 8, 16, 32), target=None) -> dict:
    """Fig. 4 analogue: wall ticks to reach a distortion threshold vs M
    (the Azure 32-VM scale-up, on the simulated architecture)."""
    # threshold: what M=1 reaches at the END of its run
    data, eval_data, w0 = _setup(1)
    seq = schemes.scheme_sequential(w0, data[0], eval_data, tau=TAU)
    thresh = target or float(seq.distortion[-1])
    out = {}
    for m in ms:
        data, eval_data, w0 = _setup(m)
        res = async_vq.scheme_async(w0, data, eval_data,
                                    jax.random.fold_in(KEY, 100 + m),
                                    tau=TAU, p_delay=0.5)
        dist = np.asarray(res.distortion)
        wt = np.asarray(res.wall_ticks)
        hit = np.argmax(dist <= thresh) if np.any(dist <= thresh) else -1
        out[m] = int(wt[hit]) if hit >= 0 else -1
    return {"threshold": thresh, "ticks_to_threshold": out}
