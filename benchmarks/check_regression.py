"""CI benchmark regression gate for the engine and serve suites.

The suite is auto-detected from the baseline JSON's ``suite`` field.

**engine**: diffs a fresh ``benchmarks.run --suite engine --quick`` output
against the committed ``BENCH_engine.json`` baseline and FAILS (exit 1)
when:

  * the mesh-vs-sim wall-clock ratio regresses by more than
    ``--max-ratio-regression`` on every M leg (default 1.25, i.e. >25%
    slower relative to the sim executor on the same machine — absolute wall
    times are not comparable across machines, the ratio is); or
  * any distortion curve diverges from the baseline beyond ``--curve-rtol``
    (the runs are seeded, so the curves are a numerical fingerprint of the
    engine — a drift means the schemes no longer compute what they did).

The mesh/sim ratio normalizes the machine out of the comparison as far as
one number can: both executors ran the same work on the same box.  It is
still mildly hardware-shaped (core count vs the 8 forced devices), so if
the gate reads persistently high or low on a new runner class with no code
change, regenerate the committed baseline THERE (`python -m benchmarks.run
--suite engine --quick`) rather than widening the threshold — the printed
per-side medians make the two cases easy to tell apart.

**serve**: diffs a fresh ``--suite serve --quick`` output against the
committed ``BENCH_serve.json`` and FAILS when:

  * the micro-batching speedup (batched sharded-lookup rows/s over the
    unbatched single-dispatch figure, both measured on the same box — the
    serve analogue of the engine's machine-normalizing mesh/sim ratio)
    regresses by more than ``--max-ratio-regression``; or
  * the speedup drops below ``--min-speedup`` (default 4x, the serving
    acceptance bar); or
  * the hot-swap leg failed any request or served non-monotonic codebook
    versions (functional, machine-independent).

Exit codes: 0 pass, 1 regression, 2 usage/config mismatch (e.g. the fresh
run used a different n/tau/d than the baseline — the comparison would be
meaningless, so that is an error, not a pass).

    python -m benchmarks.check_regression \
        --baseline BENCH_engine.json --fresh BENCH_engine.fresh.json
    python -m benchmarks.check_regression \
        --baseline BENCH_serve.json --fresh BENCH_serve.fresh.json
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _index(doc: dict) -> dict[tuple[str, int], dict]:
    return {(r["executor"], r["m"]): r for r in doc.get("results", [])}


def _config_key(rec: dict) -> tuple:
    return tuple(rec.get(k) for k in ("scheme", "n", "d", "kappa", "tau"))


def check(baseline: dict, fresh: dict, *, max_ratio_regression: float = 1.25,
          curve_rtol: float = 1e-2) -> tuple[bool, list[str]]:
    """Returns (ok, messages).  Raises ValueError on config mismatch."""
    base_idx, fresh_idx = _index(baseline), _index(fresh)
    common = sorted(set(base_idx) & set(fresh_idx))
    if not common:
        raise ValueError("no (executor, M) records shared between baseline "
                         "and fresh output — nothing to compare")
    msgs: list[str] = []
    ok = True

    # -- wall clock: per-M mesh/sim ratios, gated on the MINIMUM regression
    # over M.  The ratio normalizes out the machine (both executors ran on
    # the same box); the min is the flap-proof statistic on an oversubscribed
    # CI host (8 forced devices on 2 cores jitter individual legs >2x) —
    # a genuine engine regression slows EVERY M leg, noise does not.
    ms = [m for (ex, m) in common if ex == "mesh"
          and ("sim", m) in base_idx and ("sim", m) in fresh_idx]
    if ms:
        def ratios(idx):
            return np.asarray([
                idx[("mesh", m)]["wall_s"]
                / max(idx[("sim", m)]["wall_s"], 1e-12) for m in ms])
        r_base, r_fresh = ratios(base_idx), ratios(fresh_idx)
        regress = float(np.min(r_fresh / r_base))
        line = (f"mesh/sim wall ratio over M={ms}: baseline median "
                f"{float(np.median(r_base)):.2f}x, fresh "
                f"{float(np.median(r_fresh)):.2f}x "
                f"(min per-M regression {regress:.2f}x)")
        if regress > max_ratio_regression:
            ok = False
            msgs.append(f"FAIL {line} > {max_ratio_regression:.2f}x allowed")
        else:
            msgs.append(f"ok   {line}")

    # -- distortion curves: numerical fingerprint of the engine
    for key in common:
        b, f = base_idx[key], fresh_idx[key]
        if _config_key(b) != _config_key(f):
            raise ValueError(
                f"{key}: baseline config {_config_key(b)} != fresh "
                f"{_config_key(f)} — regenerate the baseline "
                f"(benchmarks.run --suite engine --quick) instead of "
                f"comparing different runs")
        cb = np.asarray(b["distortion"], np.float64)
        cf = np.asarray(f["distortion"], np.float64)
        if cb.shape != cf.shape:
            raise ValueError(
                f"{key}: curve length {cf.shape} != baseline {cb.shape} "
                f"— config mismatch")
        err = float(np.max(np.abs(cf - cb) / (np.abs(cb) + 1e-12)))
        if err > curve_rtol:
            ok = False
            msgs.append(f"FAIL {key}: distortion curve diverged "
                        f"(max rel err {err:.2e} > {curve_rtol:.0e})")
        else:
            msgs.append(f"ok   {key}: curve max rel err {err:.2e}")
    return ok, msgs


def _serve_rec(doc: dict, kind: str) -> dict | None:
    recs = [r for r in doc.get("results", []) if r.get("kind") == kind]
    return recs[-1] if recs else None


def check_serve(baseline: dict, fresh: dict, *,
                max_ratio_regression: float = 1.25,
                min_speedup: float = 4.0) -> tuple[bool, list[str]]:
    """Serve-suite gate; same contract as ``check``."""
    msgs: list[str] = []
    ok = True
    b_sp, f_sp = _serve_rec(baseline, "speedup"), _serve_rec(fresh, "speedup")
    if b_sp is None or f_sp is None:
        raise ValueError("serve suite needs a 'speedup' record in both "
                         "baseline and fresh output — regenerate with "
                         "benchmarks.run --suite serve")
    for k in ("m", "kappa", "d"):
        if b_sp.get(k) != f_sp.get(k):
            raise ValueError(
                f"speedup config mismatch on {k}: baseline {b_sp.get(k)} != "
                f"fresh {f_sp.get(k)} — regenerate the baseline instead of "
                f"comparing different runs")
    # the speedup is unbatched-vs-batched on ONE box, so (like the engine's
    # mesh/sim wall ratio) the machine divides out of the comparison
    regress = b_sp["speedup"] / max(f_sp["speedup"], 1e-12)
    line = (f"micro-batch speedup: baseline {b_sp['speedup']:.1f}x, "
            f"fresh {f_sp['speedup']:.1f}x (regression {regress:.2f}x)")
    if regress > max_ratio_regression:
        ok = False
        msgs.append(f"FAIL {line} > {max_ratio_regression:.2f}x allowed")
    elif f_sp["speedup"] < min_speedup:
        ok = False
        msgs.append(f"FAIL {line}; fresh speedup below the "
                    f"{min_speedup:.0f}x serving bar")
    else:
        msgs.append(f"ok   {line}")

    hot = _serve_rec(fresh, "hotswap")
    if hot is None:
        ok = False
        msgs.append("FAIL fresh serve run has no hotswap record")
    elif hot.get("failed", 1) or not hot.get("versions_monotonic", False):
        ok = False
        msgs.append(f"FAIL hot-swap under load: failed={hot.get('failed')} "
                    f"monotonic={hot.get('versions_monotonic')}")
    else:
        msgs.append(
            f"ok   hot-swap under load: 0 failed, served versions "
            f"{hot['versions_served'][0]}..{hot['versions_served'][1]} "
            f"monotonic (staleness_max={hot.get('staleness_max')})")
    return ok, msgs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_engine.json")
    ap.add_argument("--fresh", default="BENCH_engine.fresh.json")
    ap.add_argument("--max-ratio-regression", type=float, default=1.25,
                    help="allowed mesh/sim wall-ratio (engine) or batching-"
                         "speedup (serve) regression (1.25 = +25%%)")
    ap.add_argument("--curve-rtol", type=float, default=1e-2)
    ap.add_argument("--min-speedup", type=float, default=4.0,
                    help="serve suite: absolute floor for the batched-over-"
                         "unbatched lookup speedup")
    args = ap.parse_args(argv)
    try:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
        with open(args.fresh) as fh:
            fresh = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        # JSONDecodeError: a truncated fresh file (bench killed mid-write)
        # is a usage error, not a crash
        print(f"error: {e}", file=sys.stderr)
        return 2
    suites = (baseline.get("suite", "engine"), fresh.get("suite", "engine"))
    if suites[0] != suites[1]:
        print(f"error: baseline suite {suites[0]!r} != fresh {suites[1]!r}",
              file=sys.stderr)
        return 2
    try:
        if suites[0] == "serve":
            ok, msgs = check_serve(
                baseline, fresh,
                max_ratio_regression=args.max_ratio_regression,
                min_speedup=args.min_speedup)
        else:
            ok, msgs = check(baseline, fresh,
                             max_ratio_regression=args.max_ratio_regression,
                             curve_rtol=args.curve_rtol)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for m in msgs:
        print(m)
    print("benchmark regression gate:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
