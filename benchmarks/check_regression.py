"""CI benchmark regression gate for the engine and serve suites.

The suite is auto-detected from the baseline JSON's ``suite`` field.

**engine**: diffs a fresh ``benchmarks.run --suite engine --quick`` output
against the committed ``BENCH_engine.json`` baseline and FAILS (exit 1)
when:

  * the mesh-vs-sim wall-clock ratio regresses by more than
    ``--max-ratio-regression`` on every M leg (default 1.25, i.e. >25%
    slower relative to the sim executor on the same machine — absolute wall
    times are not comparable across machines, the ratio is); or
  * any distortion curve diverges from the baseline beyond ``--curve-rtol``
    (the runs are seeded, so the curves are a numerical fingerprint of the
    engine — a drift means the schemes no longer compute what they did); or
  * the fused-vs-unfused leg regresses: the kernel-fusion wall ratio
    (``MeshExecutor(fused=True)`` over ``fused=False``, same box so the
    machine divides out) exceeds 1.0 on every sync scheme (min over legs,
    the same flap-proof statistic as the mesh/sim gate), or any scheme's
    fused distortion curve is no longer BITWISE equal to the unfused one
    (fusion trades dispatches, never math).

The mesh/sim ratio normalizes the machine out of the comparison as far as
one number can: both executors ran the same work on the same box.  It is
still mildly hardware-shaped (core count vs the 8 forced devices), so if
the gate reads persistently high or low on a new runner class with no code
change, regenerate the committed baseline THERE (`python -m benchmarks.run
--suite engine --quick`) rather than widening the threshold — the printed
per-side medians make the two cases easy to tell apart.

**serve**: diffs a fresh ``--suite serve --quick`` output against the
committed ``BENCH_serve.json`` and FAILS when:

  * the micro-batching speedup (batched sharded-lookup rows/s over the
    unbatched single-dispatch figure, both measured on the same box — the
    serve analogue of the engine's machine-normalizing mesh/sim ratio)
    regresses by more than ``--max-ratio-regression``; or
  * the speedup drops below ``--min-speedup`` (default 4x, the serving
    acceptance bar); or
  * the hot-swap leg failed any request or served non-monotonic codebook
    versions (functional, machine-independent).

**hier**: diffs a fresh ``--suite hier --quick`` output against the
committed ``BENCH_hier.json`` and FAILS when:

  * any cell's measured per-tier merge wire bytes (intra-host tier 0,
    inter-host tier 1) differ from the baseline (trace-exact, like comm);
  * a hierarchical-dense cell no longer bit-matches the flat reference
    (``bitmatch_flat`` — the tentpole's oracle-equivalence contract); or
  * the inter-host sparse-vs-dense tier-1 wire reduction drops below
    ``--min-sparse-reduction`` (default 4x at k/kappa = 0.25); or
  * the hier-dense-vs-flat wall parity (same box, machine divides out)
    regresses by more than ``--max-ratio-regression`` (min over scheme
    legs); or any final distortion diverges beyond ``--curve-rtol``.

**comm**: diffs a fresh ``--suite comm --quick`` output against the
committed ``BENCH_comm.json`` and FAILS when:

  * any cell's measured merge wire bytes differ from the baseline (the
    bytes are trace-exact shape arithmetic — drift means the accounting or
    the schemes' collective structure changed); or
  * the sparse-vs-dense wire reduction drops below ``--min-sparse-reduction``
    (default 4x, the ISSUE-4 acceptance bar at k/kappa = 0.25); or
  * the ring-vs-xla wall parity (same box, machine divides out) regresses
    by more than ``--max-ratio-regression``; or any final distortion
    diverges beyond ``--curve-rtol``.

**obs**: diffs a fresh ``--suite obs --quick`` output against the
committed ``BENCH_obs.json`` and FAILS when:

  * any scheme's live-instrumentation overhead (tracer + metrics enabled
    but unexported, over the bare executor on the same box — the machine
    divides out of the on/off ratio) exceeds ``--max-obs-overhead``
    (default 1.03, the <3%% acceptance bar; absolute, not
    baseline-relative); or
  * the traced 2-host hierarchical run no longer passes the
    ``repro.obs.check`` invariants with tier-0 AND tier-1 merge spans and
    the ``codebook_divergence`` counter present (functional,
    machine-independent).

**chaos**: diffs a fresh ``--suite chaos --quick`` output against the
committed ``BENCH_chaos.json`` and FAILS when:

  * the seeded kill/slow/partition event schedule differs from the
    baseline (the same seed MUST draw the identical events on every
    device count — the chaos suite's determinism pin);
  * the quorum-merge wire bytes differ (trace-exact, like comm/hier);
  * the faulted run's final distortion over the fault-free oracle
    exceeds ``--max-chaos-distortion`` (default 1.25 — the acceptance
    bound: surviving 2 kills + 1 straggler + 1 partition costs < 25%%
    distortion); or the final distortion diverges from the baseline
    beyond ``--curve-rtol``; or the chaos trace (``chaos_*`` spans)
    violated the ``repro.obs.check`` invariants.

  ``--absolute`` gates the FRESH output alone on the absolute bars
  (distortion bound + trace invariants) with no baseline file — the
  cron seed sweep runs seeds that have no committed baseline.

**profile**: diffs a fresh ``--suite profile --quick`` output against the
committed ``BENCH_profile.json`` and FAILS when:

  * any scheme's roofline attribution terms (compute + memory +
    collective + host residual) no longer sum to the measured per-window
    wall within ``--max-consistency`` (default 0.15 — since the host
    residual is clamped at zero, a violation means the ANALYTIC terms
    overshoot the measurement: wrong flop/byte counts or a mis-inferred
    while-loop trip count);
  * the compute-term roofline efficiency drops below
    ``--min-compute-eff`` (attribution lost the analytic compute term);
  * the trip-count-corrected HLO collective bytes per window drift from
    the baseline (machine-independent shape arithmetic, pinned exactly);
  * the HLO bytes disagree with the transport's own CommLog logical-byte
    accounting of the same program (two independent derivations of the
    same traffic must agree).

  On any profile failure the per-term attribution deltas vs the baseline
  are printed; when any OTHER suite's gate fails and a
  ``BENCH_profile.fresh.json`` sits beside the fresh file, the same
  deltas are printed as a diagnostic — the gate says which roofline term
  the regression lives in, not just that wall moved.

Every run (pass or fail) ends with a gate table listing each gate's
measured value, its bar, and its margin — so CI logs always show how
close every suite sits to its thresholds, not only when one trips.

All suites additionally WARN (never fail) when the baseline's recorded
per-iteration ``wall_samples`` spread exceeds the regression threshold:
a ratio FAIL against such a baseline is as likely noise as regression,
so the fix is regenerating the baseline on a quieter box, not widening
the gate.

Exit codes: 0 pass, 1 regression, 2 usage/config mismatch (e.g. the fresh
run used a different n/tau/d than the baseline — the comparison would be
meaningless, so that is an error, not a pass), 3 baseline or fresh file
missing/unreadable (a SETUP failure, distinct from a perf regression so
CI can route it to the right owner).

    python -m benchmarks.check_regression \
        --baseline BENCH_engine.json --fresh BENCH_engine.fresh.json
    python -m benchmarks.check_regression \
        --baseline BENCH_serve.json --fresh BENCH_serve.fresh.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np


def _gate(gates: list | None, name: str, value: float, bar: float,
          cmp: str = "<=") -> None:
    """Record one gate's (value, bar) pair for the always-printed summary
    table — the margin to every bar should be visible in CI logs on green
    runs too, not only when something trips."""
    if gates is not None:
        gates.append({"name": name, "value": float(value), "bar": float(bar),
                      "cmp": cmp})


def _gate_ok(g: dict) -> bool:
    if g["cmp"] == "<=":
        return g["value"] <= g["bar"]
    if g["cmp"] == ">=":
        return g["value"] >= g["bar"]
    return g["value"] == g["bar"]


def gate_table(gates: list[dict]) -> str:
    """Aligned gate | value | bar | status summary."""
    if not gates:
        return ""
    name_w = max(len(g["name"]) for g in gates)
    lines = [f"{'gate':<{name_w}}  {'value':>12}  {'bar':>12}  status",
             "-" * (name_w + 38)]
    for g in gates:
        lines.append(
            f"{g['name']:<{name_w}}  {g['value']:>12.6g}  "
            f"{g['cmp']:>2} {g['bar']:>9.6g}  "
            f"{'ok' if _gate_ok(g) else 'FAIL'}")
    return "\n".join(lines)


def _index(doc: dict) -> dict[tuple[str, int], dict]:
    # kind-less records are the sim/mesh trajectory legs; 'fusion' records
    # carry no trajectory and ride their own gate in ``check``
    return {(r["executor"], r["m"]): r for r in doc.get("results", [])
            if r.get("kind") is None}


def _config_key(rec: dict) -> tuple:
    return tuple(rec.get(k) for k in ("scheme", "n", "d", "kappa", "tau"))


def check(baseline: dict, fresh: dict, *, max_ratio_regression: float = 1.25,
          curve_rtol: float = 1e-2,
          gates: list | None = None) -> tuple[bool, list[str]]:
    """Returns (ok, messages).  Raises ValueError on config mismatch."""
    base_idx, fresh_idx = _index(baseline), _index(fresh)
    common = sorted(set(base_idx) & set(fresh_idx))
    if not common:
        raise ValueError("no (executor, M) records shared between baseline "
                         "and fresh output — nothing to compare")
    msgs: list[str] = []
    ok = True

    # -- wall clock: per-M mesh/sim ratios, gated on the MINIMUM regression
    # over M.  The ratio normalizes out the machine (both executors ran on
    # the same box); the min is the flap-proof statistic on an oversubscribed
    # CI host (8 forced devices on 2 cores jitter individual legs >2x) —
    # a genuine engine regression slows EVERY M leg, noise does not.
    ms = [m for (ex, m) in common if ex == "mesh"
          and ("sim", m) in base_idx and ("sim", m) in fresh_idx]
    if ms:
        def ratios(idx):
            return np.asarray([
                idx[("mesh", m)]["wall_s"]
                / max(idx[("sim", m)]["wall_s"], 1e-12) for m in ms])
        r_base, r_fresh = ratios(base_idx), ratios(fresh_idx)
        regress = float(np.min(r_fresh / r_base))
        _gate(gates, "engine mesh/sim min wall regression", regress,
              max_ratio_regression)
        line = (f"mesh/sim wall ratio over M={ms}: baseline median "
                f"{float(np.median(r_base)):.2f}x, fresh "
                f"{float(np.median(r_fresh)):.2f}x "
                f"(min per-M regression {regress:.2f}x)")
        if regress > max_ratio_regression:
            ok = False
            msgs.append(f"FAIL {line} > {max_ratio_regression:.2f}x allowed")
        else:
            msgs.append(f"ok   {line}")

    # -- distortion curves: numerical fingerprint of the engine
    max_err = 0.0
    for key in common:
        b, f = base_idx[key], fresh_idx[key]
        if _config_key(b) != _config_key(f):
            raise ValueError(
                f"{key}: baseline config {_config_key(b)} != fresh "
                f"{_config_key(f)} — regenerate the baseline "
                f"(benchmarks.run --suite engine --quick) instead of "
                f"comparing different runs")
        cb = np.asarray(b["distortion"], np.float64)
        cf = np.asarray(f["distortion"], np.float64)
        if cb.shape != cf.shape:
            raise ValueError(
                f"{key}: curve length {cf.shape} != baseline {cb.shape} "
                f"— config mismatch")
        err = float(np.max(np.abs(cf - cb) / (np.abs(cb) + 1e-12)))
        max_err = max(max_err, err)
        if err > curve_rtol:
            ok = False
            msgs.append(f"FAIL {key}: distortion curve diverged "
                        f"(max rel err {err:.2e} > {curve_rtol:.0e})")
        else:
            msgs.append(f"ok   {key}: curve max rel err {err:.2e}")
    _gate(gates, "engine distortion curve max rel err", max_err, curve_rtol)

    # -- kernel fusion: fused vs unfused mesh runs on the SAME box, so the
    # ratio is machine-free and gated ABSOLUTELY (fused must not be slower).
    # Min over the sync legs is the flap-proof statistic: a genuine fusion
    # regression slows every leg, noise does not.  Bitwise curve equality
    # is functional and gated per leg — fusion trades dispatches, not math.
    b_fu = {r["scheme"]: r for r in baseline.get("results", [])
            if r.get("kind") == "fusion"}
    f_fu = {r["scheme"]: r for r in fresh.get("results", [])
            if r.get("kind") == "fusion"}
    if b_fu and not f_fu:
        raise ValueError("fresh engine run has no fusion records but the "
                         "baseline does — the suite lost coverage "
                         "(regenerate with benchmarks.run --suite engine)")
    if f_fu:
        sync = sorted(s for s, r in f_fu.items() if r.get("sync"))
        if sync:
            best = min(f_fu[s]["fused_over_unfused"] for s in sync)
            _gate(gates, "engine fused/unfused wall (min sync leg)",
                  best, 1.0)
            per = ", ".join(f"{s} {f_fu[s]['fused_over_unfused']:.2f}x"
                            for s in sync)
            line = f"fused/unfused wall over sync legs: {per} (min {best:.2f}x)"
            if best > 1.0:
                ok = False
                msgs.append(f"FAIL {line} > 1.00x — fusion no longer pays")
            else:
                msgs.append(f"ok   {line}")
        mismatched = sorted(s for s, r in f_fu.items()
                            if not r.get("curve_bitmatch"))
        _gate(gates, "engine fusion curve bit-mismatch legs",
              len(mismatched), 0)
        if mismatched:
            ok = False
            msgs.append(f"FAIL fused curves diverged bitwise from unfused "
                        f"on {mismatched} — fusion changed the math")
        else:
            msgs.append(f"ok   fused curves bitwise equal to unfused on "
                        f"all {len(f_fu)} scheme legs")
    return ok, msgs


def _serve_rec(doc: dict, kind: str) -> dict | None:
    recs = [r for r in doc.get("results", []) if r.get("kind") == kind]
    return recs[-1] if recs else None


def check_serve(baseline: dict, fresh: dict, *,
                max_ratio_regression: float = 1.25,
                min_speedup: float = 4.0,
                gates: list | None = None) -> tuple[bool, list[str]]:
    """Serve-suite gate; same contract as ``check``."""
    msgs: list[str] = []
    ok = True
    b_sp, f_sp = _serve_rec(baseline, "speedup"), _serve_rec(fresh, "speedup")
    if b_sp is None or f_sp is None:
        raise ValueError("serve suite needs a 'speedup' record in both "
                         "baseline and fresh output — regenerate with "
                         "benchmarks.run --suite serve")
    for k in ("m", "kappa", "d"):
        if b_sp.get(k) != f_sp.get(k):
            raise ValueError(
                f"speedup config mismatch on {k}: baseline {b_sp.get(k)} != "
                f"fresh {f_sp.get(k)} — regenerate the baseline instead of "
                f"comparing different runs")
    # the speedup is unbatched-vs-batched on ONE box, so (like the engine's
    # mesh/sim wall ratio) the machine divides out of the comparison
    regress = b_sp["speedup"] / max(f_sp["speedup"], 1e-12)
    _gate(gates, "serve speedup regression", regress, max_ratio_regression)
    _gate(gates, "serve batched speedup", f_sp["speedup"], min_speedup, ">=")
    line = (f"micro-batch speedup: baseline {b_sp['speedup']:.1f}x, "
            f"fresh {f_sp['speedup']:.1f}x (regression {regress:.2f}x)")
    if regress > max_ratio_regression:
        ok = False
        msgs.append(f"FAIL {line} > {max_ratio_regression:.2f}x allowed")
    elif f_sp["speedup"] < min_speedup:
        ok = False
        msgs.append(f"FAIL {line}; fresh speedup below the "
                    f"{min_speedup:.0f}x serving bar")
    else:
        msgs.append(f"ok   {line}")

    hot = _serve_rec(fresh, "hotswap")
    _gate(gates, "serve hot-swap failed requests",
          hot.get("failed", 1) if hot else 1, 0)
    if hot is None:
        ok = False
        msgs.append("FAIL fresh serve run has no hotswap record")
    elif hot.get("failed", 1) or not hot.get("versions_monotonic", False):
        ok = False
        msgs.append(f"FAIL hot-swap under load: failed={hot.get('failed')} "
                    f"monotonic={hot.get('versions_monotonic')}")
    else:
        msgs.append(
            f"ok   hot-swap under load: 0 failed, served versions "
            f"{hot['versions_served'][0]}..{hot['versions_served'][1]} "
            f"monotonic (staleness_max={hot.get('staleness_max')})")
    return ok, msgs


def _comm_cells(doc: dict) -> dict[tuple[str, str], dict]:
    return {(r["scheme"], r["transport"]): r
            for r in doc.get("results", []) if r.get("kind") == "cell"}


def check_comm(baseline: dict, fresh: dict, *,
               max_ratio_regression: float = 1.25,
               min_sparse_reduction: float = 4.0,
               curve_rtol: float = 1e-2,
               gates: list | None = None) -> tuple[bool, list[str]]:
    """Comm-suite gate; same contract as ``check``.

    Wire bytes are trace-exact shape arithmetic, so they must match the
    baseline EXACTLY — any drift means the accounting (or the schemes'
    collective structure) changed, which is the thing this suite pins.
    Wall gates ride ratios measured on one box (machine divides out):
    ring-vs-xla parity and its regression vs the baseline ratio.
    """
    msgs: list[str] = []
    ok = True
    b_cells, f_cells = _comm_cells(baseline), _comm_cells(fresh)
    missing = sorted(set(b_cells) - set(f_cells))
    if missing:
        # a vanished cell is lost coverage, not a pass: every baseline
        # (scheme, transport) pin must still be produced by the fresh run
        raise ValueError(
            f"fresh comm run is missing baseline cells {missing} — the "
            f"sweep lost coverage (regenerate the baseline only if the "
            f"cell was removed on purpose)")
    common = sorted(set(b_cells) & set(f_cells))
    if not common:
        raise ValueError("no (scheme, transport) cells shared between "
                         "baseline and fresh comm output — regenerate with "
                         "benchmarks.run --suite comm")
    drifted = 0
    max_err = 0.0
    for key in common:
        b, f = b_cells[key], f_cells[key]
        cfg = ("m", "n", "d", "kappa", "tau", "sparse_frac")
        if tuple(b.get(k) for k in cfg) != tuple(f.get(k) for k in cfg):
            raise ValueError(
                f"{key}: baseline config != fresh — regenerate the "
                f"baseline (benchmarks.run --suite comm) instead of "
                f"comparing different runs")
        if b["merge_wire_bytes"] != f["merge_wire_bytes"]:
            ok = False
            drifted += 1
            msgs.append(
                f"FAIL {key}: measured merge wire bytes drifted "
                f"{b['merge_wire_bytes']} -> {f['merge_wire_bytes']} "
                f"(accounting or collective structure changed)")
        else:
            msgs.append(f"ok   {key}: merge wire "
                        f"{f['merge_wire_bytes']} B (exact)")
        err = abs(f["final_C"] - b["final_C"]) / (abs(b["final_C"]) + 1e-12)
        max_err = max(max_err, err)
        if err > curve_rtol:
            ok = False
            msgs.append(f"FAIL {key}: final distortion diverged "
                        f"(rel err {err:.2e} > {curve_rtol:.0e})")
    _gate(gates, "comm wire-byte cells drifted", drifted, 0)
    _gate(gates, "comm final distortion max rel err", max_err, curve_rtol)

    red_ok, red_msgs = _check_reduction_record(
        baseline, fresh, kind="sparse_reduction", suite="comm",
        label="sparse-vs-dense wire reduction", floor=min_sparse_reduction,
        gates=gates)
    par_ok, par_msgs = _check_parity_record(
        baseline, fresh, kind="ring_parity", label="ring/xla wall parity",
        max_ratio_regression=max_ratio_regression, gates=gates)
    return ok and red_ok and par_ok, msgs + red_msgs + par_msgs


def _check_reduction_record(baseline: dict, fresh: dict, *, kind: str,
                            suite: str, label: str, floor: float,
                            gates: list | None = None
                            ) -> tuple[bool, list[str]]:
    """Shared floor gate on a wire-reduction record (comm + hier suites)."""
    b_red = _serve_rec(baseline, kind)
    f_red = _serve_rec(fresh, kind)
    if f_red is None or b_red is None:
        return False, [f"FAIL {suite} suite needs a {kind!r} record in "
                       f"both baseline and fresh output"]
    _gate(gates, label, f_red["reduction"], floor, ">=")
    if f_red["reduction"] < floor:
        return False, [f"FAIL {label} {f_red['reduction']:.2f}x below the "
                       f"{floor:.0f}x bar"]
    return True, [f"ok   {label} {f_red['reduction']:.2f}x "
                  f"(bar {floor:.0f}x)"]


def _check_parity_record(baseline: dict, fresh: dict, *, kind: str,
                         label: str, max_ratio_regression: float,
                         gates: list | None = None
                         ) -> tuple[bool, list[str]]:
    """Shared wall-parity gate: MIN regression over the scheme legs (the
    engine gate's flap-proof statistic — noise on an oversubscribed host
    jitters single legs, a genuine slowdown hits all of them)."""
    b_par = _serve_rec(baseline, kind)
    f_par = _serve_rec(fresh, kind)
    if f_par is None or b_par is None:
        return False, [f"FAIL suite needs a {kind!r} record in both "
                       f"baseline and fresh output"]
    schemes = sorted(set(b_par["parity"]) & set(f_par["parity"]))
    if not schemes:
        raise ValueError(f"{kind} records share no scheme legs — "
                         f"regenerate the baseline")
    regress = min(f_par["parity"][s] / max(b_par["parity"][s], 1e-12)
                  for s in schemes)
    _gate(gates, f"{label} min regression", regress, max_ratio_regression)
    med_b = float(np.median([b_par["parity"][s] for s in schemes]))
    med_f = float(np.median([f_par["parity"][s] for s in schemes]))
    line = (f"{label} over {schemes}: baseline median {med_b:.2f}x, "
            f"fresh {med_f:.2f}x (min per-scheme regression {regress:.2f}x)")
    if regress > max_ratio_regression:
        return False, [f"FAIL {line} > {max_ratio_regression:.2f}x allowed"]
    return True, [f"ok   {line}"]


def _hier_cells(doc: dict) -> dict[tuple[str, str], dict]:
    return {(r["scheme"], r["variant"]): r
            for r in doc.get("results", []) if r.get("kind") == "cell"}


def check_hier(baseline: dict, fresh: dict, *,
               max_ratio_regression: float = 1.25,
               min_sparse_reduction: float = 4.0,
               curve_rtol: float = 1e-2,
               gates: list | None = None) -> tuple[bool, list[str]]:
    """Hier-suite gate; same contract as ``check``.

    Per-tier wire bytes are trace-exact shape arithmetic, so they must
    match the baseline EXACTLY; the dense-tier-1 bit-match flag is the
    tentpole's flat-oracle equivalence and must stay True on every scheme.
    """
    msgs: list[str] = []
    ok = True
    b_cells, f_cells = _hier_cells(baseline), _hier_cells(fresh)
    missing = sorted(set(b_cells) - set(f_cells))
    if missing:
        raise ValueError(
            f"fresh hier run is missing baseline cells {missing} — the "
            f"sweep lost coverage (regenerate the baseline only if the "
            f"cell was removed on purpose)")
    common = sorted(set(b_cells) & set(f_cells))
    if not common:
        raise ValueError("no (scheme, variant) cells shared between "
                         "baseline and fresh hier output — regenerate with "
                         "benchmarks.run --suite hier")
    drifted = 0
    max_err = 0.0
    for key in common:
        b, f = b_cells[key], f_cells[key]
        cfg = ("m", "hosts", "workers_per_host", "n", "d", "kappa", "tau",
               "tier1_frac")
        if tuple(b.get(k) for k in cfg) != tuple(f.get(k) for k in cfg):
            raise ValueError(
                f"{key}: baseline config != fresh — regenerate the "
                f"baseline (benchmarks.run --suite hier) instead of "
                f"comparing different runs")
        # total merge bytes too, not just the tiered split — the flat
        # cells have no tiers, and their accounting is pinned HERE
        drift = [(t, b.get(t, 0), f.get(t, 0))
                 for t in ("merge_wire_bytes", "tier0_wire_bytes",
                           "tier1_wire_bytes")
                 if b.get(t, 0) != f.get(t, 0)]
        if drift:
            ok = False
            drifted += len(drift)
            for t, bb, ff in drift:
                msgs.append(
                    f"FAIL {key}: measured {t} drifted {bb} -> {ff} "
                    f"(accounting or collective structure changed)")
        else:
            msgs.append(
                f"ok   {key}: merge {f.get('merge_wire_bytes', 0)} B "
                f"(intra {f.get('tier0_wire_bytes', 0)} B / "
                f"inter {f.get('tier1_wire_bytes', 0)} B, exact)")
        if key[1] == "hier_dense" and not f.get("bitmatch_flat", False):
            ok = False
            msgs.append(f"FAIL {key}: dense tier-1 run no longer "
                        f"bit-matches the flat mesh oracle")
        err = abs(f["final_C"] - b["final_C"]) / (abs(b["final_C"]) + 1e-12)
        max_err = max(max_err, err)
        if err > curve_rtol:
            ok = False
            msgs.append(f"FAIL {key}: final distortion diverged "
                        f"(rel err {err:.2e} > {curve_rtol:.0e})")
    _gate(gates, "hier wire-byte fields drifted", drifted, 0)
    _gate(gates, "hier final distortion max rel err", max_err, curve_rtol)

    red_ok, red_msgs = _check_reduction_record(
        baseline, fresh, kind="inter_reduction", suite="hier",
        label="inter-host sparse-vs-dense tier-1 wire reduction",
        floor=min_sparse_reduction, gates=gates)
    par_ok, par_msgs = _check_parity_record(
        baseline, fresh, kind="hier_parity", label="hier/flat wall parity",
        max_ratio_regression=max_ratio_regression, gates=gates)
    return ok and red_ok and par_ok, msgs + red_msgs + par_msgs


def _adapt_cells(doc: dict) -> dict[tuple[str, str], dict]:
    return {(r["merge"], r["quant"]): r
            for r in doc.get("results", []) if r.get("kind") == "cell"}


def check_adapt(baseline: dict, fresh: dict, *,
                curve_rtol: float = 1e-2,
                gates: list | None = None) -> tuple[bool, list[str]]:
    """Adapt-suite gate; same contract as ``check``.

    Wire bytes AND trigger counts are trace-exact on a seeded workload, so
    every cell and fixed-tau leg must match the baseline EXACTLY — drift
    means the trigger rule, the probe accounting, or a codec's wire
    formula changed.  On top of the baseline pins, the fresh summary
    record must clear the ISSUE's absolute bars: the thresh=0/quant-off
    run bit-matches the plain delta merge, and the dynamic-dense and
    dynamic-int8 cells land within ``curve_rtol`` of the best fixed-tau
    leg's final distortion at STRICTLY fewer total wire bytes.
    """
    msgs: list[str] = []
    ok = True
    b_cells, f_cells = _adapt_cells(baseline), _adapt_cells(fresh)
    missing = sorted(set(b_cells) - set(f_cells))
    if missing:
        raise ValueError(
            f"fresh adapt run is missing baseline cells {missing} — the "
            f"sweep lost coverage (regenerate the baseline only if the "
            f"cell was removed on purpose)")
    common = sorted(set(b_cells) & set(f_cells))
    if not common:
        raise ValueError("no (merge, quant) cells shared between baseline "
                         "and fresh adapt output — regenerate with "
                         "benchmarks.run --suite adapt")
    drifted = 0
    max_err = 0.0
    for key in common:
        b, f = b_cells[key], f_cells[key]
        cfg = ("m", "n", "d", "kappa", "tau", "thresh", "max_stale")
        if tuple(b.get(k) for k in cfg) != tuple(f.get(k) for k in cfg):
            raise ValueError(
                f"{key}: baseline config != fresh — regenerate the "
                f"baseline (benchmarks.run --suite adapt) instead of "
                f"comparing different runs")
        pins = ("total_wire_bytes", "merge_wire_bytes", "probe_wire_bytes",
                "n_triggered")
        bad = [p for p in pins if b[p] != f[p]]
        if bad:
            ok = False
            drifted += 1
            msgs.append(
                f"FAIL {key}: " + "; ".join(
                    f"{p} drifted {b[p]} -> {f[p]}" for p in bad))
        else:
            msgs.append(
                f"ok   {key}: wire {f['total_wire_bytes']} B, "
                f"trig {f['n_triggered']}/{f['n_windows']} (exact)")
        err = abs(f["final_C"] - b["final_C"]) / (abs(b["final_C"]) + 1e-12)
        max_err = max(max_err, err)
        if err > curve_rtol:
            ok = False
            msgs.append(f"FAIL {key}: final distortion diverged "
                        f"(rel err {err:.2e} > {curve_rtol:.0e})")
    _gate(gates, "adapt wire/trigger cells drifted", drifted, 0)
    _gate(gates, "adapt final distortion max rel err", max_err, curve_rtol)

    b_legs = {r["tau"]: r for r in baseline.get("results", [])
              if r.get("kind") == "fixed_leg"}
    f_legs = {r["tau"]: r for r in fresh.get("results", [])
              if r.get("kind") == "fixed_leg"}
    leg_drift = 0
    for tau in sorted(set(b_legs) & set(f_legs)):
        if b_legs[tau]["total_wire_bytes"] != f_legs[tau]["total_wire_bytes"]:
            ok = False
            leg_drift += 1
            msgs.append(f"FAIL fixed tau={tau}: wire drifted "
                        f"{b_legs[tau]['total_wire_bytes']} -> "
                        f"{f_legs[tau]['total_wire_bytes']}")
    _gate(gates, "adapt fixed-tau leg wire drifted", leg_drift, 0)

    s = _serve_rec(fresh, "adapt_summary")
    if s is None or _serve_rec(baseline, "adapt_summary") is None:
        return False, msgs + ["FAIL adapt suite needs an 'adapt_summary' "
                              "record in both baseline and fresh output"]
    _gate(gates, "adapt thresh=0 bitmatch", float(s["bitmatch"]), 1.0, "==")
    if not s["bitmatch"]:
        ok = False
        msgs.append("FAIL thresh=0 dynamic merge did not bit-match the "
                    "plain delta merge")
    else:
        msgs.append("ok   thresh=0 + quant-off dynamic merge bit-matches "
                    "the plain delta merge")
    _gate(gates, "adapt dynamic<=fixed wire per quant",
          float(s["dynamic_wire_ok"]), 1.0, "==")
    if not s["dynamic_wire_ok"]:
        ok = False
        msgs.append("FAIL a dynamic cell moved more total wire than its "
                    "fixed counterpart (the probe isn't paying for itself)")
    best_c, best_w = s["best_final_C"], s["best_wire_bytes"]
    for leg in ("dense", "int8"):
        c, w = s[f"dyn_{leg}_final_C"], s[f"dyn_{leg}_wire_bytes"]
        ratio = c / (best_c + 1e-12)
        _gate(gates, f"adapt dyn-{leg} C over best fixed", ratio,
              1.0 + curve_rtol)
        _gate(gates, f"adapt dyn-{leg} wire under best fixed", w,
              best_w - 1)
        if ratio > 1.0 + curve_rtol or w >= best_w:
            ok = False
            msgs.append(
                f"FAIL dynamic-{leg}: C={c:.5f} wire={w} vs best fixed "
                f"tau={s['best_tau']} C={best_c:.5f} wire={best_w} "
                f"(need C within rtol {curve_rtol:.0e} at strictly "
                f"fewer bytes)")
        else:
            msgs.append(
                f"ok   dynamic-{leg}: C {ratio:.4f}x of best fixed "
                f"(tau={s['best_tau']}) at {w}/{best_w} wire bytes")
    return ok, msgs


def check_obs(baseline: dict, fresh: dict, *, max_overhead: float = 1.03,
              gates: list | None = None) -> tuple[bool, list[str]]:
    """Obs-suite gate; same contract as ``check``.

    The overhead bar is ABSOLUTE (the acceptance criterion: live
    instrumentation costs < 3% wall), measured fresh on one box — the
    machine divides out of the on/off ratio, so the baseline pins the
    config and records the noise floor rather than anchoring a ratio.
    The trace leg is functional and machine-independent: the fresh
    traced hierarchical run must pass the invariant checker.
    """
    msgs: list[str] = []
    ok = True
    b_over = {r["scheme"]: r for r in baseline.get("results", [])
              if r.get("kind") == "overhead"}
    f_over = {r["scheme"]: r for r in fresh.get("results", [])
              if r.get("kind") == "overhead"}
    if not b_over or not f_over:
        raise ValueError("obs suite needs 'overhead' records in both "
                         "baseline and fresh output — regenerate with "
                         "benchmarks.run --suite obs")
    missing = sorted(set(b_over) - set(f_over))
    if missing:
        raise ValueError(f"fresh obs run is missing baseline overhead "
                         f"legs {missing} — the suite lost coverage")
    for scheme in sorted(f_over):
        f = f_over[scheme]
        b = b_over.get(scheme)
        if b is not None:
            cfg = ("m", "n", "d", "kappa", "tau")
            if tuple(b.get(k) for k in cfg) != tuple(f.get(k) for k in cfg):
                raise ValueError(
                    f"obs overhead [{scheme}]: baseline config != fresh — "
                    f"regenerate the baseline (benchmarks.run --suite obs) "
                    f"instead of comparing different runs")
        _gate(gates, f"obs overhead [{scheme}]", f["overhead"], max_overhead)
        line = (f"obs overhead [{scheme}]: instrumented/bare wall "
                f"{f['overhead']:.3f}x (bar <= {max_overhead:.2f}x)")
        if f["overhead"] > max_overhead:
            ok = False
            msgs.append(f"FAIL {line}")
        else:
            msgs.append(f"ok   {line}")

    tr = _serve_rec(fresh, "trace")
    _gate(gates, "obs trace invariants ok",
          1 if (tr and tr.get("trace_ok", False)) else 0, 1, ">=")
    if tr is None:
        ok = False
        msgs.append("FAIL fresh obs run has no 'trace' record")
    elif not tr.get("trace_ok", False):
        ok = False
        msgs.append("FAIL traced hierarchical run violated trace "
                    "invariants: "
                    + "; ".join(tr.get("errors", ["(no detail)"])[:3]))
    else:
        msgs.append(f"ok   traced {tr.get('hosts')}-host run: "
                    f"{tr.get('n_spans')} spans, tier-0/1 merge spans + "
                    f"divergence counter present")
    return ok, msgs


def check_chaos(baseline: dict | None, fresh: dict, *,
                max_chaos_distortion: float = 1.25,
                curve_rtol: float = 1e-2,
                gates: list | None = None) -> tuple[bool, list[str]]:
    """Chaos-suite gate; same contract as ``check``.

    ``baseline=None`` is the ``--absolute`` mode used by the cron seed
    sweep: only the absolute bars apply (distortion-ratio ceiling over
    the fault-free oracle + trace invariants) since sweep seeds have no
    committed baseline to diff against.
    """
    msgs: list[str] = []
    ok = True

    f = _serve_rec(fresh, "chaos")
    if f is None:
        raise ValueError("chaos suite needs a 'chaos' record in the fresh "
                         "output — regenerate with "
                         "benchmarks.run --suite chaos")

    if baseline is not None:
        b = _serve_rec(baseline, "chaos")
        if b is None:
            raise ValueError("chaos baseline has no 'chaos' record — "
                             "regenerate with benchmarks.run --suite chaos")
        cfg = ("seed", "m", "n", "d", "kappa", "tau", "hosts", "quorum_frac")
        b_cfg = tuple(b.get(k) for k in cfg)
        f_cfg = tuple(f.get(k) for k in cfg)
        if b_cfg != f_cfg:
            raise ValueError(
                f"chaos config mismatch baseline={b_cfg} fresh={f_cfg} — "
                f"regenerate the baseline (benchmarks.run --suite chaos) "
                f"instead of comparing different runs")
        # determinism pin: the same seed must draw the identical
        # kill/slow/partition schedule on every device count
        if b.get("events") != f.get("events"):
            ok = False
            msgs.append("FAIL chaos schedule drifted from baseline — same "
                        "seed must draw identical events "
                        f"(baseline {b.get('events')} != "
                        f"fresh {f.get('events')})")
        else:
            msgs.append(f"ok   seeded schedule: {len(f.get('events', []))} "
                        f"events, identical to baseline")
        wire = (b.get("merge_wire_bytes"), f.get("merge_wire_bytes"))
        if wire[0] != wire[1]:
            ok = False
            msgs.append(f"FAIL quorum-merge wire bytes drifted "
                        f"{wire[0]} -> {wire[1]} B (masked-collective "
                        f"accounting or structure changed)")
        else:
            msgs.append(f"ok   quorum merge wire {wire[1]} B (exact)")
        err = (abs(f["final_C"] - b["final_C"])
               / (abs(b["final_C"]) + 1e-12))
        if err > curve_rtol:
            ok = False
            msgs.append(f"FAIL chaos final distortion diverged from "
                        f"baseline: rel err {err:.2e} > {curve_rtol:.0e}")
        else:
            msgs.append(f"ok   chaos final distortion rel err {err:.2e}")

    _gate(gates, "chaos distortion ratio vs oracle", f["distortion_ratio"],
          max_chaos_distortion)
    _gate(gates, "chaos trace invariants ok",
          1 if f.get("trace_ok", False) else 0, 1, ">=")
    line = (f"distortion ratio vs fault-free oracle "
            f"{f['distortion_ratio']:.4f} "
            f"(bound {max_chaos_distortion:.2f}, "
            f"{len(f.get('resizes', []))} unscheduled resizes survived)")
    if f["distortion_ratio"] > max_chaos_distortion:
        ok = False
        msgs.append(f"FAIL {line}")
    else:
        msgs.append(f"ok   {line}")

    if not f.get("trace_ok", False):
        ok = False
        msgs.append("FAIL chaos trace violated invariants: "
                    + "; ".join(f.get("trace_errors", ["(no detail)"])[:3]))
    else:
        msgs.append("ok   chaos trace: chaos_* spans present, "
                    "invariants hold")
    return ok, msgs


def attribution_deltas(baseline: dict, fresh: dict) -> list[str]:
    """Per-scheme roofline-term movement between two profile docs.

    This is what turns a wall regression from "slower" into "slower
    BECAUSE": printed on every profile-gate run and, by ``main``, as a
    diagnostic whenever ANY suite's gate fails and a fresh profile doc is
    available next to the committed one."""
    b_idx = {r["scheme"]: r for r in baseline.get("results", [])
             if r.get("kind") == "attribution"}
    f_idx = {r["scheme"]: r for r in fresh.get("results", [])
             if r.get("kind") == "attribution"}
    out: list[str] = []
    for scheme in sorted(set(b_idx) & set(f_idx)):
        ba, fa = b_idx[scheme]["attribution"], f_idx[scheme]["attribution"]
        moved = []
        for term in ("compute", "memory", "collective", "host"):
            bt, ft = ba.get(f"t_{term}_s", 0.0), fa.get(f"t_{term}_s", 0.0)
            if bt > 0:
                moved.append(f"{term} {bt * 1e6:.2f}->{ft * 1e6:.2f}us "
                             f"({ft / bt:.2f}x)")
            elif ft > 0:
                moved.append(f"{term} 0->{ft * 1e6:.2f}us (new)")
        wall = (f"window wall {ba['window_wall_s'] * 1e6:.1f}->"
                f"{fa['window_wall_s'] * 1e6:.1f}us")
        out.append(f"attribution [{scheme}]: {wall}; " + ", ".join(moved))
    return out


def check_profile(baseline: dict, fresh: dict, *,
                  max_consistency: float = 0.15,
                  min_compute_eff: float = 1e-9,
                  gates: list | None = None) -> tuple[bool, list[str]]:
    """Profile-suite gate; same contract as ``check``.

    * attribution-sum consistency: the roofline terms (host residual
      included) must sum to the measured window wall within
      ``max_consistency`` (0.15 — the acceptance criterion).  The
      residual is clamped at zero, so a violation means the ANALYTIC
      terms overshoot measured wall: a wrong flop/byte count or a
      mis-inferred while-loop trip count;
    * compute-term efficiency floor: the analytic compute term must be
      present and positive (TPU-peak-relative, so tiny on the CPU
      harness — the floor pins attribution happened, not CPU speed);
    * ``collective_bytes_per_window`` is trip-count-corrected HLO shape
      arithmetic — machine-independent, pinned EXACTLY against the
      baseline, and cross-checked against the transport's own CommLog
      logical-byte accounting of the same program.

    On any failure the per-term attribution deltas vs the baseline are
    appended, so the log says WHICH roofline term moved, not just that
    wall did.
    """
    msgs: list[str] = []
    ok = True
    b_idx = {r["scheme"]: r for r in baseline.get("results", [])
             if r.get("kind") == "attribution"}
    f_idx = {r["scheme"]: r for r in fresh.get("results", [])
             if r.get("kind") == "attribution"}
    if not b_idx or not f_idx:
        raise ValueError("profile suite needs 'attribution' records in both "
                         "baseline and fresh output — regenerate with "
                         "benchmarks.run --suite profile")
    missing = sorted(set(b_idx) - set(f_idx))
    if missing:
        raise ValueError(f"fresh profile run is missing baseline schemes "
                         f"{missing} — the suite lost coverage")
    worst_cons = 0.0
    min_eff = float("inf")
    for scheme in sorted(f_idx):
        f = f_idx[scheme]
        b = b_idx.get(scheme)
        fa = f["attribution"]
        if b is not None:
            cfg = ("m", "n", "d", "kappa", "tau", "transport")
            if tuple(b.get(k) for k in cfg) != tuple(f.get(k) for k in cfg):
                raise ValueError(
                    f"profile [{scheme}]: baseline config != fresh — "
                    f"regenerate the baseline (benchmarks.run --suite "
                    f"profile) instead of comparing different runs")
        cons = fa["consistency"]
        worst_cons = max(worst_cons, cons)
        line = (f"profile [{scheme}]: attribution sum vs measured window "
                f"wall off by {cons:.4f} (bar <= {max_consistency:.2f})")
        if cons > max_consistency:
            ok = False
            msgs.append(f"FAIL {line} — modeled terms overshoot measured "
                        f"wall (bad analytic count or trip count)")
        else:
            msgs.append(f"ok   {line}")
        eff = fa["efficiency"].get("compute", 0.0)
        min_eff = min(min_eff, eff)
        if eff < min_compute_eff:
            ok = False
            msgs.append(f"FAIL profile [{scheme}]: compute-term efficiency "
                        f"{eff:.3e} below the {min_compute_eff:.0e} floor "
                        f"(attribution lost the analytic compute term)")
        if b is not None:
            bw = b["attribution"]["collective_bytes_per_window"]
            fw = fa["collective_bytes_per_window"]
            if bw != fw:
                ok = False
                msgs.append(
                    f"FAIL profile [{scheme}]: HLO collective bytes/window "
                    f"drifted {bw} -> {fw} (collective structure or trip-"
                    f"count inference changed)")
            else:
                msgs.append(f"ok   profile [{scheme}]: collective "
                            f"{fw:.0f} B/window (HLO, exact)")
        log_pw = f.get("commlog_logical_bytes_per_window")
        if log_pw:
            rel = abs(fa["collective_bytes_per_window"] - log_pw) / log_pw
            if rel > 1e-6:
                ok = False
                msgs.append(
                    f"FAIL profile [{scheme}]: HLO bytes/window "
                    f"{fa['collective_bytes_per_window']:.1f} != CommLog "
                    f"{log_pw:.1f} (rel {rel:.2e}) — the parsed program "
                    f"disagrees with the transport's own accounting")
            else:
                msgs.append(f"ok   profile [{scheme}]: HLO == CommLog "
                            f"logical bytes ({log_pw:.1f} B/window)")
    _gate(gates, "profile attribution consistency (worst)", worst_cons,
          max_consistency)
    _gate(gates, "profile compute efficiency (min)", min_eff,
          min_compute_eff, ">=")
    if not ok:
        msgs += attribution_deltas(baseline, fresh)
    return ok, msgs


def _sample_tag(rec: dict) -> str:
    """Short human tag for a BENCH record carrying raw samples."""
    for keys in (("executor", "m"), ("kind", "scheme"),
                 ("scheme", "transport"), ("scheme", "variant"),
                 ("variant",), ("kind",)):
        if all(rec.get(k) is not None for k in keys):
            return "/".join(str(rec[k]) for k in keys)
    return "record"


def variance_warnings(doc: dict, *, threshold: float,
                      label: str = "baseline") -> list[str]:
    """WARN when recorded per-iteration wall samples spread wider than the
    regression threshold — a ratio FAIL against such a baseline is as
    likely noise as regression (regenerate the baseline on a quieter box
    rather than widening the gate).  Never fails the run."""
    warns: list[str] = []
    for rec in doc.get("results", []):
        for fld in ("wall_samples", "wall_samples_off", "wall_samples_on",
                    "wall_samples_fused", "wall_samples_unfused"):
            s = rec.get(fld)
            if not isinstance(s, list) or len(s) < 2 or min(s) <= 0:
                continue
            spread = max(s) / min(s) - 1.0
            if spread > threshold:
                warns.append(
                    f"warn {label} {_sample_tag(rec)}: {fld} spread "
                    f"{spread:.0%} exceeds the {threshold:.0%} regression "
                    f"threshold — wall-ratio gates on this record are "
                    f"noise-limited")
    return warns


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_engine.json")
    ap.add_argument("--fresh", default="BENCH_engine.fresh.json")
    ap.add_argument("--max-ratio-regression", type=float, default=1.25,
                    help="allowed mesh/sim wall-ratio (engine) or batching-"
                         "speedup (serve) regression (1.25 = +25%%)")
    ap.add_argument("--curve-rtol", type=float, default=1e-2)
    ap.add_argument("--min-speedup", type=float, default=4.0,
                    help="serve suite: absolute floor for the batched-over-"
                         "unbatched lookup speedup")
    ap.add_argument("--min-sparse-reduction", type=float, default=4.0,
                    help="comm suite: floor for the sparse-vs-dense merge "
                         "wire-byte reduction (4x at k/kappa = 0.25)")
    ap.add_argument("--max-obs-overhead", type=float, default=1.03,
                    help="obs suite: absolute ceiling for the live-"
                         "instrumentation wall overhead (1.03 = the <3%% "
                         "acceptance bar)")
    ap.add_argument("--max-chaos-distortion", type=float, default=1.25,
                    help="chaos suite: absolute ceiling for the faulted "
                         "run's final distortion over the fault-free "
                         "oracle (1.25 = within 25%%)")
    ap.add_argument("--max-consistency", type=float, default=0.15,
                    help="profile suite: ceiling for |attributed - "
                         "measured| / measured on the per-window wall "
                         "(0.15 = the 15%% acceptance bar)")
    ap.add_argument("--min-compute-eff", type=float, default=1e-9,
                    help="profile suite: floor for the compute-term "
                         "roofline efficiency (TPU-peak-relative, so "
                         "tiny on the CPU CI harness; the floor proves "
                         "attribution ran, it does not rate hardware)")
    ap.add_argument("--absolute", action="store_true",
                    help="chaos suite: gate the fresh output on the "
                         "absolute bars alone, no baseline file (the "
                         "cron seed sweep runs seeds with no committed "
                         "baseline)")
    args = ap.parse_args(argv)
    try:
        with open(args.fresh) as fh:
            fresh = json.load(fh)
        baseline = None
        if not args.absolute:
            with open(args.baseline) as fh:
                baseline = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        # exit 3, distinct from config-mismatch (2) and regression (1):
        # a missing/truncated benchmark file is a SETUP failure and CI
        # must not report it as either a perf regression or a pass
        print(f"error: missing or unreadable benchmark file: {e}",
              file=sys.stderr)
        return 3
    if args.absolute:
        suite = fresh.get("suite", "engine")
        if suite != "chaos":
            print(f"error: --absolute only applies to the chaos suite, "
                  f"fresh is {suite!r}", file=sys.stderr)
            return 2
        suites = ("chaos", "chaos")
    else:
        suites = (baseline.get("suite", "engine"),
                  fresh.get("suite", "engine"))
        if suites[0] != suites[1]:
            print(f"error: baseline suite {suites[0]!r} != fresh "
                  f"{suites[1]!r}", file=sys.stderr)
            return 2
    gates: list[dict] = []
    try:
        if suites[0] == "serve":
            ok, msgs = check_serve(
                baseline, fresh,
                max_ratio_regression=args.max_ratio_regression,
                min_speedup=args.min_speedup, gates=gates)
        elif suites[0] == "comm":
            ok, msgs = check_comm(
                baseline, fresh,
                max_ratio_regression=args.max_ratio_regression,
                min_sparse_reduction=args.min_sparse_reduction,
                curve_rtol=args.curve_rtol, gates=gates)
        elif suites[0] == "hier":
            ok, msgs = check_hier(
                baseline, fresh,
                max_ratio_regression=args.max_ratio_regression,
                min_sparse_reduction=args.min_sparse_reduction,
                curve_rtol=args.curve_rtol, gates=gates)
        elif suites[0] == "obs":
            ok, msgs = check_obs(baseline, fresh,
                                 max_overhead=args.max_obs_overhead,
                                 gates=gates)
        elif suites[0] == "chaos":
            ok, msgs = check_chaos(
                baseline, fresh,
                max_chaos_distortion=args.max_chaos_distortion,
                curve_rtol=args.curve_rtol, gates=gates)
        elif suites[0] == "profile":
            ok, msgs = check_profile(
                baseline, fresh,
                max_consistency=args.max_consistency,
                min_compute_eff=args.min_compute_eff, gates=gates)
        elif suites[0] == "adapt":
            ok, msgs = check_adapt(baseline, fresh,
                                   curve_rtol=args.curve_rtol, gates=gates)
        else:
            ok, msgs = check(baseline, fresh,
                             max_ratio_regression=args.max_ratio_regression,
                             curve_rtol=args.curve_rtol, gates=gates)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    thresh = (args.max_obs_overhead - 1.0 if suites[0] == "obs"
              else args.max_ratio_regression - 1.0)
    if baseline is not None:
        msgs += variance_warnings(baseline, threshold=thresh)
    if not ok and suites[0] != "profile":
        # any suite's wall gate failing: attribute the regression if a
        # fresh profile run sits next to the committed baseline — say
        # WHICH roofline term moved, not just that wall did
        msgs += _profile_attribution_diag(args.fresh)
    for m in msgs:
        print(m)
    if gates:
        print()
        print(gate_table(gates))
    print("benchmark regression gate:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def _profile_attribution_diag(fresh_path: str) -> list[str]:
    """Best-effort roofline attribution of a non-profile gate failure.

    Looks for ``BENCH_profile.json`` (committed) and
    ``BENCH_profile.fresh.json`` beside the failing suite's fresh file;
    silent if either is absent — this is a diagnostic, never a gate."""
    d = os.path.dirname(os.path.abspath(fresh_path))
    try:
        with open(os.path.join(d, "BENCH_profile.json")) as fh:
            base = json.load(fh)
        with open(os.path.join(d, "BENCH_profile.fresh.json")) as fh:
            fresh = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return []
    deltas = attribution_deltas(base, fresh)
    if deltas:
        deltas.insert(0, "roofline attribution of the regression "
                         "(BENCH_profile.fresh.json vs committed):")
    return deltas


if __name__ == "__main__":
    raise SystemExit(main())
