"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness; plus a decode
step against the cache."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models.api import get_api
from repro.optim import optimizers
from repro.training import steps as steps_lib

KEY = jax.random.PRNGKey(0)
B, T = 2, 16


def _batch(cfg):
    batch = {
        "tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.img_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch_id", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = registry.get_smoke_config(arch_id)
    api = get_api(cfg)
    params = api.init(KEY)
    batch = _batch(cfg)

    logits = api.forward(params, batch)
    exp_t = T + (cfg.img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_t, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = optimizers.adamw(1e-3)
    step = jax.jit(steps_lib.make_train_step(cfg, opt))
    state = steps_lib.init_train_state(cfg, opt, KEY)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state["step"]) == 1
    # one more step: loss must stay finite and params must have moved
    state2, metrics2 = step(state, batch)
    assert bool(jnp.isfinite(metrics2["loss"]))


@pytest.mark.parametrize("arch_id", registry.ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = registry.get_smoke_config(arch_id)
    api = get_api(cfg)
    params = api.init(KEY)
    batch = _batch(cfg)
    cache = api.init_cache(params, batch, 32)
    step = jax.jit(steps_lib.make_serve_step(cfg))
    logits, cache = step(params, cache, batch["tokens"][:, :1])
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    logits2, cache = step(params, cache, batch["tokens"][:, 1:2])
    assert int(cache["cur_len"]) == 2


@pytest.mark.parametrize("arch_id", registry.ARCH_IDS)
def test_full_config_param_count_sane(arch_id):
    """The FULL configs are never materialized on CPU — but their analytic
    parameter counts must be in the advertised ballpark."""
    cfg = registry.get_config(arch_id)
    n = cfg.n_params()
    expected = {
        "granite_34b": 34e9, "granite_8b": 8e9, "starcoder2_7b": 7e9,
        "command_r_35b": 35e9, "whisper_tiny": 39e6,
        # assigned dims (48L x 64e x d_ff 1408) give 28B total / ~4B active;
        # the hf label "16b" reflects a different layer/expert split
        "moonshot_v1_16b_a3b": 28e9, "olmoe_1b_7b": 7e9,
        "mamba2_2p7b": 2.7e9, "internvl2_76b": 76e9, "hymba_1p5b": 1.5e9,
    }[arch_id]
    assert 0.55 * expected < n < 1.55 * expected, (
        f"{arch_id}: analytic {n / 1e9:.2f}B vs expected "
        f"{expected / 1e9:.2f}B")


def test_loss_decreases_on_learnable_data():
    """End-to-end trainability: tiny dense model on the Markov pipeline."""
    from repro.data.pipeline import DataConfig, lm_batch
    cfg = registry.get_smoke_config("granite_8b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    opt = optimizers.adamw(3e-3)
    step = jax.jit(steps_lib.make_train_step(cfg, opt))
    state = steps_lib.init_train_state(cfg, opt, KEY)
    first = last = None
    for i in range(30):
        state, metrics = step(state, lm_batch(dcfg, i))
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.2, (first, last)
