"""Unit + property tests for the paper's core: H, VQ iterations, criterion."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image ships without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import vq
from repro.data import synthetic

KEY = jax.random.PRNGKey(0)


def test_H_single_touches_only_winner():
    z = jnp.array([0.0, 0.0])
    w = jnp.array([[1.0, 0.0], [5.0, 5.0], [-3.0, 0.1]])
    h = vq.H(z, w)
    assert h.shape == w.shape
    # winner is prototype 0 (distance 1)
    np.testing.assert_allclose(np.asarray(h[0]), [1.0, 0.0])
    np.testing.assert_allclose(np.asarray(h[1:]), 0.0)


def test_H_batch_equals_sum_of_H():
    z = jax.random.normal(KEY, (32, 6))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (9, 6))
    hb = vq.H_batch(z, w)
    hs = sum(vq.H(z[i], w) for i in range(32))
    np.testing.assert_allclose(np.asarray(hb), np.asarray(hs), atol=1e-4)


def test_vq_step_matches_eq1():
    """w(t+1) differs from w(t) only on the winning prototype, by
    eps*(w_l - z)."""
    z = jax.random.normal(KEY, (5,))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (7, 5))
    state = vq.VQState(w=w, t=jnp.asarray(3, jnp.int32))
    new = vq.vq_step(state, z)
    l = int(vq.nearest(z[None], w)[0])
    eps = float(vq.default_steps(jnp.asarray(4)))
    np.testing.assert_allclose(
        np.asarray(new.w[l]), np.asarray(w[l] - eps * (w[l] - z)), rtol=1e-5)
    mask = jnp.arange(7) != l
    np.testing.assert_array_equal(np.asarray(new.w[mask]),
                                  np.asarray(w[mask]))


def test_vq_run_reduces_distortion():
    data = synthetic.mixture_data(KEY, n=2000, d=4, n_centers=5)
    w0 = synthetic.kmeanspp_init(jax.random.fold_in(KEY, 3), data, 8)
    before = float(vq.distortion(data, w0))
    final = vq.vq_run(w0, data)
    after = float(vq.distortion(data, final.w))
    assert after < before


def test_window_displacement_identity():
    """w_final == w0 - delta (eq. 7 bookkeeping)."""
    data = synthetic.mixture_data(KEY, n=50, d=3)
    w0 = data[:4]
    delta, w_final = vq.window_displacement(
        w0, data, jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(np.asarray(w0 - delta), np.asarray(w_final),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 24), st.integers(1, 8), st.integers(2, 40))
def test_distortion_nonnegative_and_zero_on_prototypes(kappa, d, n):
    key = jax.random.PRNGKey(kappa * 131 + d * 7 + n)
    w = jax.random.normal(key, (kappa, d))
    # points exactly on prototypes -> zero distortion
    idx = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, kappa)
    z = w[idx]
    assert float(vq.distortion(z, w)) == pytest.approx(0.0, abs=1e-5)
    z2 = jax.random.normal(jax.random.fold_in(key, 2), (n, d))
    assert float(vq.distortion(z2, w)) >= 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(2, 12))
def test_steps_monotone_decreasing(a, b):
    t1 = jnp.asarray(a, jnp.int32)
    t2 = jnp.asarray(a + b, jnp.int32)
    assert float(vq.default_steps(t2)) < float(vq.default_steps(t1))
