"""Communication-layer tests (ISSUE 4): every MergeStrategy x Transport
combination against the XlaTransport oracle, wire-byte accounting, the
VMEM-routed mesh inner loop, the comm regression gate, and the CLI.

Runs on both CI legs: the M=1 cells exercise degenerate (no-wire) meshes,
the M=8 cells the real collective paths (``@pytest.mark.devices``).
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro import comm  # noqa: E402
from repro.comm import (CommLog, CommRecord, SparseTransport,  # noqa: E402
                        XlaTransport, get_transport, ring_wire_bytes)
from repro.core import schemes  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.engine import (InstantNetwork, MeshExecutor,  # noqa: E402
                          get_network)
from repro.engine import merge as merge_lib  # noqa: E402

KEY = jax.random.PRNGKey(42)
TAU = 10
D, KAPPA = 8, 16
# k/kappa = 0.25: k = kappa/4 entries kept of the kappa*d displacement —
# the acceptance point where sparse wire must be >= 4x under dense
FRAC_Q = (KAPPA // 4) / (KAPPA * D)


def _setup(m, n=400):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=D)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)
    return data, eval_data, w0


def _run(scheme, m, transport, **kw):
    data, eval_data, w0 = _setup(m)
    ex = MeshExecutor(network=InstantNetwork(),
                      transport=transport, **kw)
    res = ex.run(scheme, w0, data, eval_data, tau=TAU,
                 key=jax.random.fold_in(KEY, 9))
    return res, ex


# ---------------------------------------------------------------------------
# factory + API surface
# ---------------------------------------------------------------------------

def test_get_transport_factory():
    assert get_transport("xla").name == "xla"
    assert get_transport("ring").name == "ring"
    sp = get_transport("sparse", frac=0.5)
    assert sp.name == "sparse" and sp.stateful
    # instances pass through (executors accept either spelling)
    assert get_transport(sp) is sp
    with pytest.raises(ValueError, match="unknown transport"):
        get_transport("pigeon")
    with pytest.raises(ValueError, match="frac"):
        get_transport("sparse", frac=0.0)
    with pytest.raises(ValueError, match="unknown reduce op"):
        XlaTransport().all_reduce(jnp.zeros(3), "workers", op="max")


def test_get_merge_factory_and_transport_plumbing():
    assert merge_lib.get_merge("delta_sparse").name == "delta_sparse"
    assert isinstance(merge_lib.get_merge("delta_sparse").transport,
                      SparseTransport)
    t = get_transport("ring")
    assert merge_lib.get_merge("delta", transport=t).transport is t
    with pytest.raises(ValueError, match="unknown merge"):
        merge_lib.get_merge("gossip")


def test_comm_log_summarize():
    log = CommLog()
    log.append(CommRecord(op="sum", transport="xla", axis="w",
                          participants=4, logical_bytes=100, wire_bytes=150,
                          calls=10))
    log.append(CommRecord(op="mean", transport="xla", axis="w",
                          participants=4, logical_bytes=4, wire_bytes=6,
                          calls=10, tag="eval"))
    s = CommLog.summarize(log.records)
    assert s["wire_bytes"] == 1560 and s["logical_bytes"] == 1040
    assert s["by_tag"]["merge"]["wire_bytes"] == 1500
    assert s["by_tag"]["eval"]["wire_bytes"] == 60
    mark = log.mark()
    assert log.since(mark) == []


def test_comm_log_bounded_with_absolute_marks():
    """The log drops oldest records past max_records; marks are absolute,
    so since() stays correct across trims (no unbounded growth in a
    long-lived serve/train-publish loop)."""
    rec = CommRecord(op="sum", transport="xla", axis="w", participants=2,
                     logical_bytes=8, wire_bytes=8)
    log = CommLog(max_records=4)
    for _ in range(10):
        log.append(rec)
    assert len(log.records) == 4 and log.mark() == 10
    m = log.mark()
    log.extend([rec, rec])
    assert len(log.records) == 4                 # still bounded
    assert len(log.since(m)) == 2                # the two new ones
    assert log.since(0) == log.records           # old window: what's left
    with pytest.raises(ValueError, match="max_records"):
        CommLog(max_records=0)


def test_ring_wire_convention():
    assert ring_wire_bytes(1024, 1) == 0       # one participant: no wire
    assert ring_wire_bytes(1024, 8) == 2 * 7 * 1024 // 8


# ---------------------------------------------------------------------------
# equivalence suite: MergeStrategy x Transport vs the XlaTransport oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
@pytest.mark.parametrize("scheme", ["average", "delta", "async_delta"])
def test_ring_matches_xla_exactly(scheme, m):
    """Dense transports are numerics-identical: same schemes, same bytes,
    same bits (on CPU the ring rides its XLA fallback — the contract the
    TPU Pallas path is tested against)."""
    base, _ = _run(scheme, m, "xla")
    ring, ex = _run(scheme, m, "ring")
    np.testing.assert_array_equal(np.asarray(base.distortion),
                                  np.asarray(ring.distortion))
    np.testing.assert_array_equal(np.asarray(base.w_shared),
                                  np.asarray(ring.w_shared))
    merge = ex.last_comm["by_tag"]["merge"]
    if m == 1:
        assert merge["wire_bytes"] == 0
    else:
        assert merge["wire_bytes"] > 0


@pytest.mark.parametrize(
    "m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
@pytest.mark.parametrize("scheme", ["average", "delta", "async_delta"])
def test_sparse_full_density_matches_xla(scheme, m):
    """frac=1.0 keeps everything: the gathered-scatter-add sum must agree
    with the dense all-reduce (only floating-sum order can differ)."""
    base, _ = _run(scheme, m, "xla")
    sparse, _ = _run(scheme, m, get_transport("sparse", frac=1.0))
    np.testing.assert_allclose(np.asarray(base.distortion),
                               np.asarray(sparse.distortion),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize(
    "m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
@pytest.mark.parametrize("scheme", ["average", "delta", "async_delta"])
def test_sparse_low_density_distortion_bound(scheme, m):
    """At k/kappa = 0.25 the error-feedback compressed merges must stay
    within 25% of the dense final distortion and still converge."""
    base, _ = _run(scheme, m, "xla")
    sparse, _ = _run(scheme, m, get_transport("sparse", frac=FRAC_Q))
    curve = np.asarray(sparse.distortion)
    assert np.all(np.isfinite(curve))
    assert curve[-1] < curve[0]                      # it converges
    gap = curve[-1] / float(base.distortion[-1]) - 1.0
    assert abs(gap) < 0.25, f"sparse final C off dense by {gap:+.3f}"


@pytest.mark.devices(8)
def test_sparse_average_rides_dense():
    """Means are not compressed (absolute values don't concentrate): the
    average scheme over SparseTransport is bit-identical to dense."""
    base, _ = _run("average", 8, "xla")
    sparse, ex = _run("average", 8, get_transport("sparse", frac=FRAC_Q))
    np.testing.assert_array_equal(np.asarray(base.distortion),
                                  np.asarray(sparse.distortion))
    # and its merge wire is the dense figure, not the top-k one
    n_windows = 400 // TAU
    dense_per_window = ring_wire_bytes(4 * KAPPA * D, 8)
    assert (ex.last_comm["by_tag"]["merge"]["wire_bytes"]
            == n_windows * dense_per_window)


def test_mesh_default_transport_is_oracle_exact():
    """The refactor is invisible at the default: mesh delta on XlaTransport
    still equals the scheme_delta oracle."""
    data, eval_data, w0 = _setup(1)
    oracle = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
    res, _ = _run("delta", 1, None)
    np.testing.assert_allclose(np.asarray(res.distortion),
                               np.asarray(oracle.distortion),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# wire-byte accounting (measured, replayed on cache hits)
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_wire_bytes_closed_form_and_replay():
    m, n = 8, 400
    n_windows = n // TAU
    res, ex = _run("delta", m, "xla")
    merge = ex.last_comm["by_tag"]["merge"]
    logical = 4 * KAPPA * D
    assert merge["logical_bytes"] == n_windows * logical
    assert merge["wire_bytes"] == n_windows * ring_wire_bytes(logical, m)
    assert merge["calls"] == n_windows
    # eval traffic is tagged separately and tiny
    assert ex.last_comm["by_tag"]["eval"]["logical_bytes"] == n_windows * 4
    # a second run hits the compile cache; the replayed records must give
    # the same per-run summary (accounting survives caching)
    first = ex.last_comm
    data, eval_data, w0 = _setup(m)
    ex.run("delta", w0, data, eval_data, tau=TAU)
    assert ex.last_comm == first


@pytest.mark.devices(8)
def test_sparse_wire_reduction_at_quarter_kappa():
    """The ISSUE-4 acceptance inequality, measured: sparse merge wire >= 4x
    below dense at k/kappa = 0.25."""
    _, dense = _run("delta", 8, "xla")
    _, sparse = _run("delta", 8, get_transport("sparse", frac=FRAC_Q))
    dw = dense.last_comm["by_tag"]["merge"]["wire_bytes"]
    sw = sparse.last_comm["by_tag"]["merge"]["wire_bytes"]
    assert dw / sw >= 4.0, f"sparse reduction {dw / sw:.2f}x < 4x"


def test_single_worker_moves_no_wire():
    _, ex = _run("delta", 1, "xla")
    assert ex.last_comm["by_tag"]["merge"]["wire_bytes"] == 0
    assert ex.last_comm["by_tag"]["merge"]["logical_bytes"] > 0


@pytest.mark.devices(4)
def test_bandwidth_network_charges_measured_bytes():
    """FixedLatencyNetwork(bytes_per_tick=...) stretches the wall clock by
    the transport's MEASURED per-window wire bytes."""
    data, eval_data, w0 = _setup(4)
    free = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, eval_data, tau=TAU)
    per_window = ring_wire_bytes(4 * KAPPA * D, 4)
    net = get_network("fixed", latency_ticks=0, bytes_per_tick=per_window)
    taxed_ex = MeshExecutor(network=net)
    taxed = taxed_ex.run("delta", w0, data, eval_data, tau=TAU)
    # same merges, same curve values; each window pays exactly 1 extra tick
    np.testing.assert_allclose(np.asarray(free.distortion),
                               np.asarray(taxed.distortion), rtol=1e-6)
    assert int(taxed.wall_ticks[0]) == TAU + 1
    assert net.transfer_ticks(0) == 0
    assert get_network("fixed", latency_ticks=0).transfer_ticks(1 << 20) == 0
    with pytest.raises(ValueError, match="bytes_per_tick"):
        get_network("fixed", bytes_per_tick=-1)


@pytest.mark.devices(8)
def test_elastic_late_delta_rides_comm_accounting():
    from repro.engine import ElasticMeshExecutor, ResizeSchedule
    data, eval_data, w0 = _setup(8)
    ex = ElasticMeshExecutor(ResizeSchedule([(2, 4)]),
                             network=InstantNetwork())
    ex.run("delta", w0, data, eval_data, tau=TAU)
    late = ex.last_comm["by_tag"].get("late_delta")
    assert late is not None and late["wire_bytes"] == 4 * KAPPA * D
    assert ex.last_comm["by_tag"]["merge"]["wire_bytes"] > 0


# ---------------------------------------------------------------------------
# VMEM-routed mesh inner loop (ROADMAP: larger-than-VMEM codebooks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m", [1, pytest.param(4, marks=pytest.mark.devices(4))])
def test_mesh_routed_blocked_parity_kappa_gt_bk(m):
    """kappa > bk with a tiny VMEM budget forces the blocked-assign +
    segment-sum fallback inside the mesh inner loop; the run must be
    bit-compatible with the fused-kernel path (batch-of-one: no
    accumulation-order freedom)."""
    kappa = 192                       # > bk=128: codebook streams in tiles
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=200, d=D)
    eval_data = data[:, :100]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), kappa)
    fused = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, eval_data, tau=TAU)
    from repro.kernels import ops
    assert not ops.delta_fits_vmem(kappa, D, budget_bytes=1024)
    routed = MeshExecutor(network=InstantNetwork(),
                          vmem_budget_bytes=1024).run(
        "delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_allclose(np.asarray(fused.distortion),
                               np.asarray(routed.distortion),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused.w_shared),
                               np.asarray(routed.w_shared),
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# regression-gate units (benchmarks/check_regression.py, comm suite)
# ---------------------------------------------------------------------------

def _comm_doc(wire_delta=17920, reduction=4.0, parity=1.0):
    cell = {"kind": "cell", "scheme": "delta", "transport": "xla",
            "m": 8, "n": 200, "d": 8, "kappa": 16, "tau": 10,
            "sparse_frac": None, "wall_s": 0.01,
            "merge_wire_bytes": wire_delta, "merge_logical_bytes": 10240,
            "final_C": 0.02}
    return {"suite": "comm", "results": [
        cell,
        {"kind": "sparse_reduction", "m": 8, "kappa": 16, "d": 8,
         "sparse_frac": 0.03125, "reduction": reduction},
        {"kind": "ring_parity", "m": 8,
         "parity": parity if isinstance(parity, dict) else
         {"average": parity, "delta": parity, "async_delta": parity}},
    ]}


def test_comm_gate_passes_identical():
    from benchmarks.check_regression import check_comm
    ok, msgs = check_comm(_comm_doc(), _comm_doc())
    assert ok, msgs


def test_comm_gate_fails_on_wire_drift():
    from benchmarks.check_regression import check_comm
    ok, msgs = check_comm(_comm_doc(), _comm_doc(wire_delta=17921))
    assert not ok and any("wire bytes drifted" in m for m in msgs)


def test_comm_gate_fails_below_sparse_floor():
    from benchmarks.check_regression import check_comm
    ok, msgs = check_comm(_comm_doc(), _comm_doc(reduction=3.2))
    assert not ok and any("below the 4x bar" in m for m in msgs)


def test_comm_gate_fails_on_ring_parity_regression():
    from benchmarks.check_regression import check_comm
    # a genuine ring slowdown hits EVERY scheme leg -> min regression 2x
    ok, msgs = check_comm(_comm_doc(parity=1.0), _comm_doc(parity=2.0))
    assert not ok and any("parity" in m for m in msgs)


def test_comm_gate_tolerates_single_leg_parity_noise():
    from benchmarks.check_regression import check_comm
    # one jittery leg on an oversubscribed host is NOT a regression
    fresh = _comm_doc(parity={"average": 2.0, "delta": 1.0,
                              "async_delta": 1.0})
    ok, msgs = check_comm(_comm_doc(parity=1.0), fresh)
    assert ok, msgs


def test_comm_gate_rejects_config_mismatch():
    from benchmarks.check_regression import check_comm
    fresh = _comm_doc()
    fresh["results"][0]["n"] = 400
    with pytest.raises(ValueError, match="regenerate the baseline"):
        check_comm(_comm_doc(), fresh)


# ---------------------------------------------------------------------------
# CLI (launch/train.py --transport)
# ---------------------------------------------------------------------------

def test_train_cli_transport_smoke(capsys):
    from repro.launch import train
    rc = train.main(["--mode", "vq", "--executor", "mesh", "--workers", "1",
                     "--points", "100", "--scheme", "delta",
                     "--transport", "sparse", "--compress-frac", "0.25"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "transport=sparse" in out and "comm[sparse]" in out


def test_train_cli_transport_needs_mesh(capsys):
    from repro.launch import train
    rc = train.main(["--mode", "vq", "--executor", "sim",
                     "--transport", "ring"])
    assert rc == 2
    assert "--executor mesh" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# stateful-transport composition (review-fix regressions)
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_published_sparse_carries_residual_across_chunks():
    """The publish path must thread the error-feedback residual across its
    host-level chunks — same numerics as the unpublished run."""
    data, eval_data, w0 = _setup(8)
    plain, _ = _run("delta", 8, get_transport("sparse", frac=FRAC_Q))
    published = MeshExecutor(
        network=InstantNetwork(),
        transport=get_transport("sparse", frac=FRAC_Q),
        on_window=lambda *_: None, publish_every=3).run(
        "delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(plain.distortion),
                                  np.asarray(published.distortion))
    np.testing.assert_array_equal(np.asarray(plain.w_shared),
                                  np.asarray(published.w_shared))


def test_sparse_delta_merge_rejects_conflicting_frac():
    t = get_transport("sparse", frac=0.5)
    with pytest.raises(ValueError, match="conflicts"):
        merge_lib.SparseDeltaMerge(t, frac=0.25)
    # no frac, or a matching one, is fine
    assert merge_lib.SparseDeltaMerge(t).transport is t
    assert merge_lib.SparseDeltaMerge(t, frac=0.5).transport is t


@pytest.mark.devices(2)
def test_window_step_rejects_delta_over_stateful_transport():
    from repro.configs import registry
    from repro.optim import optimizers
    from repro.training import steps as steps_lib
    cfg = registry.get_smoke_config("granite_8b")
    mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
    with pytest.raises(ValueError, match="DELTA_SPARSE instead"):
        steps_lib.make_window_step(
            cfg, optimizers.sgd(0.05), mesh, tau=2,
            merge=steps_lib.Merge.DELTA, transport="sparse")


@pytest.mark.devices(2)
def test_window_step_async_delta_over_sparse_transport():
    """ASYNC_DELTA x SparseTransport: init_window_state seeds the joint
    {own, comm} carry and a window runs finite (the crash the review
    found)."""
    from repro.configs import registry
    from repro.models import common as model_common
    from repro.optim import optimizers
    from repro.training import steps as steps_lib
    model_common.set_run_options(mesh=None)
    cfg = registry.get_smoke_config("granite_8b")
    mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
    opt = optimizers.sgd(0.05)
    tsp = get_transport("sparse", frac=0.25)
    step = steps_lib.make_window_step(
        cfg, opt, mesh, tau=2, merge=steps_lib.Merge.ASYNC_DELTA,
        merge_axis="pod", transport=tsp)
    state = steps_lib.init_window_state(
        cfg, opt, KEY, steps_lib.Merge.ASYNC_DELTA, transport=tsp)
    assert set(state["delta_prev"]) == {"own", "comm"}
    toks = jax.random.randint(KEY, (2, 4, 8), 0, cfg.vocab)
    with mesh:
        out, metrics = jax.jit(step)(state, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(metrics["loss"]))
    assert set(out["delta_prev"]) == {"own", "comm"}


# ---------------------------------------------------------------------------
# the LM window step rides the same implementations (spot check)
# ---------------------------------------------------------------------------

def test_window_step_sparse_strategy_is_shared():
    """DELTA_SPARSE's residual init comes from the shared SparseDeltaMerge,
    and the strategy's leaf math is comm.sparse.sparse_allsum (one
    implementation for the LM window step and the VQ engine)."""
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    strat = merge_lib.SparseDeltaMerge(frac=0.5)
    state = strat.init_state(params)
    assert set(state) == {"w", "b"}
    assert all(leaf.dtype == jnp.float32
               for leaf in jax.tree.leaves(state))
    assert comm.sparse_allsum is not None
