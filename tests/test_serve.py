"""Serving subsystem (ISSUE 3): versioned store, sharded lookup, the
micro-batching service, the engine publish hook, and the serve bench gate.

Acceptance bars under test: served assignments bit-match the ``kernels/ref``
oracle for a pinned codebook version; a hot-swap under concurrent load never
serves a torn codebook and versions only move forward; the micro-batcher
flushes partial batches on deadline.  Multi-device lookup plans carry
``@pytest.mark.devices(n)`` so the 1-device CI leg skips them.
"""

import pathlib
import sys
import threading
import time

from repro.xla_flags import force_host_devices

force_host_devices(8)

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.data import synthetic  # noqa: E402
from repro.engine import (ElasticMeshExecutor, GeometricDelayNetwork,  # noqa: E402
                          InstantNetwork, MeshExecutor, ResizeSchedule)
from repro.kernels import ref  # noqa: E402
from repro.launch import serve as serve_cli  # noqa: E402
from repro.serve import (CodebookStore, QuantizeService,  # noqa: E402
                         ShardedLookup, arrival_gaps_s, run_load)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks import check_regression  # noqa: E402

KEY = jax.random.PRNGKey(7)
D, KAPPA = 16, 48


def _codebook(kappa=KAPPA, d=D, fold=0):
    return np.asarray(jax.random.normal(jax.random.fold_in(KEY, fold),
                                        (kappa, d)), np.float32)


def _queries(n, d=D, fold=100):
    return np.asarray(jax.random.normal(jax.random.fold_in(KEY, fold),
                                        (n, d)), np.float32)


# ---------------------------------------------------------------------------
# CodebookStore
# ---------------------------------------------------------------------------

def test_store_versions_strictly_monotonic():
    store = CodebookStore()
    assert store.version == 0 and len(store) == 0
    with pytest.raises(LookupError):
        store.latest()
    w = _codebook()
    s1 = store.publish(w, step=10)
    s2 = store.publish(2 * w, step=20)
    assert (s1.version, s2.version) == (1, 2)
    assert store.latest() is s2
    assert store.get(1) is s1 and store.get(99) is None
    # snapshots are immutable: the published array cannot be poked
    with pytest.raises(ValueError):
        s1.w[0, 0] = 123.0
    # publisher() plugs straight into on_window
    store.publisher()(7, 3 * w)
    assert store.version == 3 and store.latest().step == 7


def test_store_history_bounded_and_wait_for():
    store = CodebookStore(_codebook(), keep=3)
    for i in range(6):
        store.publish(_codebook(fold=i))
    assert store.version == 7 and len(store) == 3
    assert store.get(1) is None and store.get(7) is not None
    assert store.wait_for(7, timeout=0.01)
    assert not store.wait_for(99, timeout=0.01)
    with pytest.raises(ValueError):
        CodebookStore(keep=0)
    with pytest.raises(ValueError):
        store.publish(np.zeros(3))  # not (kappa, d)


def test_store_concurrent_publish_no_torn_reads():
    """Readers racing a publisher must always see (version, w) pairs that
    belong together — w filled with its own version number makes a torn
    snapshot directly visible."""
    store = CodebookStore(np.full((4, 4), 1.0, np.float32))
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            snap = store.latest()
            if not np.all(snap.w == float(snap.version)):
                torn.append(snap.version)

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for v in range(2, 200):
        store.publish(np.full((4, 4), float(v), np.float32))
    stop.set()
    for t in threads:
        t.join()
    assert not torn


# ---------------------------------------------------------------------------
# ShardedLookup
# ---------------------------------------------------------------------------

def test_lookup_direct_bitmatches_oracle():
    look = ShardedLookup(n_devices=1)
    z, w = _queries(37), _codebook()
    a, m = look.assign(z, w)
    ar, mr = ref.vq_assign_ref(z, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-5)
    assert look.plan(KAPPA, D) == "direct"


@pytest.mark.devices(2)
@pytest.mark.parametrize("mode", ["shard_batch", "shard_kappa"])
def test_lookup_sharded_bitmatches_oracle(mode):
    look = ShardedLookup(n_devices=2, mode=mode)
    z, w = _queries(64), _codebook()
    a, m = look.assign(z, w)
    ar, mr = ref.vq_assign_ref(z, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-5)


@pytest.mark.devices(8)
def test_lookup_shard_kappa_ragged_padding():
    """kappa not divisible by the shard count: sentinel pad rows never win."""
    look = ShardedLookup(n_devices=8, mode="shard_kappa")
    z, w = _queries(40), _codebook(kappa=13)  # 13 rows over 8 shards
    a, m = look.assign(z, w)
    ar, mr = ref.vq_assign_ref(z, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-5)


@pytest.mark.devices(2)
def test_lookup_auto_routes_by_vmem_budget():
    tiny = ShardedLookup(n_devices=2, budget_bytes=256)
    big = ShardedLookup(n_devices=2)
    assert tiny.plan(KAPPA, D) == "shard_kappa"
    assert big.plan(KAPPA, D) == "shard_batch"


def test_lookup_validation():
    with pytest.raises(ValueError, match="unknown lookup mode"):
        ShardedLookup(mode="psum")
    with pytest.raises(ValueError, match="n_devices"):
        ShardedLookup(n_devices=len(jax.devices()) + 1)
    if len(jax.devices()) >= 2:
        look = ShardedLookup(n_devices=2, mode="shard_batch")
        with pytest.raises(ValueError, match="multiple"):
            look.assign(_queries(33), _codebook())  # 33 % 2 != 0
    with pytest.raises(ValueError, match="matching d"):
        ShardedLookup(n_devices=1).assign(_queries(8, d=4), _codebook())


# ---------------------------------------------------------------------------
# QuantizeService
# ---------------------------------------------------------------------------

def test_service_bitmatches_oracle_for_pinned_version():
    w = _codebook()
    store = CodebookStore(w)
    with QuantizeService(store, ShardedLookup(), max_delay_s=1e-3) as svc:
        z_single = _queries(1)[0]           # (d,) single-vector form
        z_bulk = _queries(29, fold=5)
        r1 = svc.quantize(z_single)
        r2 = svc.quantize(z_bulk)
    ar, mr = ref.vq_assign_ref(z_single[None], w)
    np.testing.assert_array_equal(r1.assign, np.asarray(ar))
    np.testing.assert_allclose(r1.mindist, np.asarray(mr), rtol=1e-5)
    ar, _ = ref.vq_assign_ref(z_bulk, w)
    np.testing.assert_array_equal(r2.assign, np.asarray(ar))
    assert r1.version == r2.version == 1
    assert r1.batch_rows >= 1 and r2.batch_rows >= 29


def test_service_deadline_flushes_partial_batch():
    store = CodebookStore(_codebook())
    svc = QuantizeService(store, ShardedLookup(n_devices=1),
                          max_batch=10_000, max_delay_s=0.05)
    with svc:
        t0 = time.monotonic()
        futs = [svc.submit(_queries(1)[0]) for _ in range(3)]
        resps = [f.result(timeout=10) for f in futs]
        waited = time.monotonic() - t0
    # far from full, so only the deadline can have flushed it
    assert svc.stats.deadline_flushes >= 1 and svc.stats.full_flushes == 0
    assert waited >= 0.04
    assert all(r.version == 1 for r in resps)
    assert svc.stats.requests == 3 and svc.stats.rows == 3


def test_service_full_batch_flushes_before_deadline():
    store = CodebookStore(_codebook())
    svc = QuantizeService(store, ShardedLookup(n_devices=1),
                          max_batch=64, max_delay_s=30.0)
    with svc:
        t0 = time.monotonic()
        futs = [svc.submit(_queries(16, fold=i)) for i in range(4)]
        for f in futs:
            f.result(timeout=10)
        waited = time.monotonic() - t0
    # 64 pending rows filled max_batch: no 30s deadline wait
    assert waited < 5.0
    assert svc.stats.full_flushes >= 1
    assert svc.stats.mean_fill >= 16


def test_service_pads_to_mxu_alignment():
    store = CodebookStore(_codebook())
    svc = QuantizeService(store, ShardedLookup(n_devices=1),
                          max_delay_s=1e-3, batch_align=128)
    with svc:
        svc.quantize(_queries(3, fold=9))
    assert svc.stats.padded_rows == 125  # 3 -> one aligned 128 block


def test_service_empty_store_fails_request_not_service():
    store = CodebookStore()
    with QuantizeService(store, ShardedLookup(n_devices=1),
                         max_delay_s=1e-3) as svc:
        with pytest.raises(LookupError):
            svc.quantize(_queries(1)[0])
        # the flush loop survives the fault; a publish heals the service
        store.publish(_codebook())
        assert svc.quantize(_queries(1)[0]).version == 1
    assert svc.stats.failed == 1


def test_service_submit_validation_and_lifecycle():
    store = CodebookStore(_codebook())
    svc = QuantizeService(store, ShardedLookup(n_devices=1))
    with pytest.raises(RuntimeError, match="not running"):
        svc.submit(_queries(1)[0])
    with svc:
        with pytest.raises(ValueError, match="rows, d"):
            svc.submit(np.zeros((2, 3, 4)))
        with pytest.raises(RuntimeError, match="already running"):
            svc.start()
    with pytest.raises(ValueError, match="max_delay_s"):
        QuantizeService(store, ShardedLookup(n_devices=1), max_delay_s=-1)


def test_service_survives_cancelled_future():
    """cancel() on a queued request must not kill the flush thread or the
    requests coalesced into the same batch."""
    store = CodebookStore(_codebook())
    with QuantizeService(store, ShardedLookup(n_devices=1),
                         max_batch=10_000, max_delay_s=0.05) as svc:
        doomed = svc.submit(_queries(1)[0])
        assert doomed.cancel()
        live = svc.submit(_queries(2, fold=3))
        resp = live.result(timeout=10)
        assert resp.version == 1
        # the service still works after the cancelled flush
        assert svc.quantize(_queries(1, fold=4)[0]).version == 1


def test_store_publish_does_not_freeze_callers_array():
    w = _codebook().copy()
    store = CodebookStore()
    store.publish(w)
    w[0, 0] = 42.0  # caller keeps a writable array...
    assert store.latest().w[0, 0] != 42.0  # ...and the snapshot a copy


def test_service_hot_swap_under_concurrent_load():
    """The acceptance bar: concurrent publishes never tear a response —
    every answer bit-matches the oracle on the exact version it reports —
    and versions served only move forward."""
    n_versions, n_clients, n_reqs = 30, 4, 25
    store = CodebookStore(_codebook(fold=1), keep=n_versions + 1)
    results: dict[int, list] = {i: [] for i in range(n_clients)}
    errors: list[Exception] = []

    with QuantizeService(store, ShardedLookup(), max_delay_s=5e-4) as svc:
        stop = threading.Event()

        def publisher():
            for v in range(2, n_versions + 2):
                store.publish(_codebook(fold=v))
                time.sleep(1e-3)
            stop.set()

        def client(i):
            try:
                for j in range(n_reqs):
                    z = _queries(3, fold=1000 + i * n_reqs + j)
                    results[i].append((z, svc.quantize(z)))
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = ([threading.Thread(target=publisher)]
                   + [threading.Thread(target=client, args=(i,))
                      for i in range(n_clients)])
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert not errors
    served_versions = set()
    for i in range(n_clients):
        versions = [r.version for _, r in results[i]]
        # in-order clients see non-decreasing versions (store is monotone
        # and flushes happen in submission order)
        assert versions == sorted(versions)
        served_versions.update(versions)
        for z, r in results[i]:
            snap = store.get(r.version)
            assert snap is not None, "served a version the store never had"
            ar, _ = ref.vq_assign_ref(z, snap.w)
            np.testing.assert_array_equal(r.assign, np.asarray(ar))
    assert len(served_versions) > 1, "load never overlapped a hot swap"


# ---------------------------------------------------------------------------
# engine publish hook (on_window)
# ---------------------------------------------------------------------------

def _setup(m, n=300, d=8, kappa=16):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    return data, data[:, :100], synthetic.kmeanspp_init(
        kw, data.reshape(-1, d), kappa)


@pytest.mark.parametrize("publish_every", [1, 7])
def test_mesh_on_window_identical_numerics(publish_every):
    data, ev, w0 = _setup(1)
    plain = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, ev, tau=10)
    pubs = []
    ex = MeshExecutor(network=InstantNetwork(),
                      on_window=lambda wi, w: pubs.append((wi, np.asarray(w))),
                      publish_every=publish_every)
    res = ex.run("delta", w0, data, ev, tau=10)
    np.testing.assert_allclose(np.asarray(res.distortion),
                               np.asarray(plain.distortion), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(res.wall_ticks),
                                  np.asarray(plain.wall_ticks))
    n_windows = data.shape[1] // 10
    windows = [wi for wi, _ in pubs]
    assert windows[-1] == n_windows and windows == sorted(set(windows))
    np.testing.assert_allclose(pubs[-1][1], np.asarray(res.w_shared),
                               rtol=1e-6)
    with pytest.raises(ValueError, match="publish_every"):
        MeshExecutor(publish_every=0)


@pytest.mark.devices(4)
def test_elastic_on_window_global_windows_across_resizes():
    data, ev, w0 = _setup(4)
    store = CodebookStore()
    sched = ResizeSchedule([(10, 2), (20, 4)])
    ex = ElasticMeshExecutor(sched, network=InstantNetwork(),
                             on_window=store.publisher(), publish_every=4)
    res = ex.run("delta", w0, data, ev, tau=10)
    steps = [store.get(v).step for v in range(1, store.version + 1)]
    assert steps == sorted(steps), "window tags must be global + monotone"
    assert len(ex.resize_events) == 2
    baseline = ElasticMeshExecutor(sched, network=InstantNetwork()).run(
        "delta", w0, data, ev, tau=10)
    np.testing.assert_allclose(np.asarray(res.distortion),
                               np.asarray(baseline.distortion), rtol=1e-6)
    np.testing.assert_allclose(store.latest().w, np.asarray(res.w_shared),
                               rtol=1e-6)
    # clearing the hook must actually clear it on the cached per-M
    # executors: a re-run may not keep publishing into the old store
    ex.on_window = None
    v_before = store.version
    ex.run("delta", w0, data, ev, tau=10)
    assert store.version == v_before


# ---------------------------------------------------------------------------
# loadgen
# ---------------------------------------------------------------------------

def test_loadgen_geometric_arrivals_and_report():
    gaps = arrival_gaps_s(GeometricDelayNetwork(0.5), 500, tick_s=1e-3,
                          key=KEY)
    assert gaps.shape == (500,) and np.all(gaps >= 1e-3)  # round >= tau=1
    assert gaps.max() > 1e-3  # geometric extras actually drawn

    store = CodebookStore(_codebook())
    with QuantizeService(store, ShardedLookup(), max_delay_s=1e-3) as svc:
        rep = run_load(svc, n_requests=50, d=D, rows_per_request=2,
                       network=GeometricDelayNetwork(0.5), tick_s=1e-4,
                       key=KEY)
    assert rep.failed == 0 and rep.requests == 50 and rep.rows == 100
    assert rep.qps > 0 and rep.p50_ms <= rep.p99_ms
    assert rep.versions_min == rep.versions_max == 1
    assert rep.versions_monotonic and rep.staleness_max == 0
    assert "50 req" in rep.summary()


# ---------------------------------------------------------------------------
# serve benchmark gate (mirrors the engine-gate unit tests)
# ---------------------------------------------------------------------------

def _serve_doc(speedup=100.0, failed=0, monotonic=True):
    return {"suite": "serve", "results": [
        {"kind": "speedup", "m": 8, "kappa": 64, "d": 32, "speedup": speedup},
        {"kind": "hotswap", "failed": failed,
         "versions_monotonic": monotonic, "versions_served": [1, 5],
         "staleness_max": 1},
    ]}


def test_serve_gate_pass_and_regression():
    ok, msgs = check_regression.check_serve(_serve_doc(100), _serve_doc(90))
    assert ok, msgs
    ok, msgs = check_regression.check_serve(_serve_doc(100), _serve_doc(50))
    assert not ok and any("FAIL" in m for m in msgs)


def test_serve_gate_absolute_floor_and_hotswap():
    ok, _ = check_regression.check_serve(_serve_doc(4.0), _serve_doc(3.5))
    assert not ok  # below the 4x serving bar even if relative drop is small
    ok, msgs = check_regression.check_serve(_serve_doc(), _serve_doc(failed=2))
    assert not ok and any("hot-swap" in m for m in msgs)
    ok, _ = check_regression.check_serve(_serve_doc(),
                                         _serve_doc(monotonic=False))
    assert not ok


def test_serve_gate_config_mismatch_and_dispatch():
    bad = _serve_doc()
    bad["results"][0]["kappa"] = 999
    with pytest.raises(ValueError, match="config mismatch"):
        check_regression.check_serve(_serve_doc(), bad)
    with pytest.raises(ValueError, match="speedup"):
        check_regression.check_serve({"suite": "serve", "results": []},
                                     _serve_doc())
    # main() dispatches on the suite field and rejects mixed suites
    import json
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        base, fresh = f"{td}/b.json", f"{td}/f.json"
        with open(base, "w") as f:
            json.dump(_serve_doc(), f)
        with open(fresh, "w") as f:
            json.dump(_serve_doc(speedup=95), f)
        assert check_regression.main(["--baseline", base,
                                      "--fresh", fresh]) == 0
        with open(fresh, "w") as f:
            json.dump({"suite": "engine", "results": []}, f)
        assert check_regression.main(["--baseline", base,
                                      "--fresh", fresh]) == 2


# ---------------------------------------------------------------------------
# CLI + bench plumbing
# ---------------------------------------------------------------------------

def test_serve_cli_vq_smoke(capsys):
    rc = serve_cli.main(["--mode", "vq", "--smoke", "--requests", "40",
                         "--dim", "8", "--kappa", "8", "--tick-ms", "0"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 failed" in out and "plan=" in out


def test_serve_cli_train_publish_smoke(capsys):
    rc = serve_cli.main(["--mode", "vq", "--smoke", "--requests", "30",
                         "--dim", "8", "--kappa", "8", "--train-publish",
                         "--points", "100", "--tick-ms", "0.2"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "trainer published" in out


def test_suite_out_path_derivation():
    from benchmarks.run import suite_out_path
    assert suite_out_path("", "engine", multi=True) == "BENCH_engine.json"
    assert suite_out_path("F.json", "engine", multi=False) == "F.json"
    assert suite_out_path("F.json", "engine", multi=True) == "F.engine.json"
    assert suite_out_path("F.json", "serve", multi=True) == "F.serve.json"
    assert suite_out_path("FRESH", "elastic",
                          multi=True) == "FRESH.elastic.json"
