"""Distribution tests on a small host mesh (8 CPU devices from conftest).

Covers: param-spec divisibility policy, merge-strategy semantics (the paper's
schemes applied to LM training), and elastic resharding.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.models import common as model_common
from repro.models.api import get_api
from repro.optim import optimizers
from repro.training import steps as steps_lib

KEY = jax.random.PRNGKey(0)


def _mesh(pod=2, data=2, model=2):
    return jax.make_mesh((pod, data, model), ("pod", "data", "model"))


def _batchify(cfg, b, t, tau=None):
    shape = (tau, b, t) if tau else (b, t)
    toks = jax.random.randint(KEY, shape, 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.devices(8)
def test_param_specs_divisibility_policy():
    """Heads sharded only when divisible; MLP always; norms replicated."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = registry.get_smoke_config("granite_8b")  # 8 heads % 4 == 0
    specs = sharding.param_specs(cfg, mesh, use_fsdp=False)
    assert specs["blocks"]["wq"] == P(None, None, "model")
    assert specs["blocks"]["attn_norm"] == P(None, None)
    assert specs["blocks"]["w_gate"][2] == "model"

    cfg2 = registry.get_smoke_config("starcoder2_7b")  # 6 heads % 4 != 0
    specs2 = sharding.param_specs(cfg2, mesh, use_fsdp=False)
    assert specs2["blocks"]["wq"] == P(None, None, None)
    assert specs2["blocks"]["w_gate"] == P(None, None, "model")


@pytest.mark.devices(8)
def test_param_specs_fsdp_adds_data_axis():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = registry.get_smoke_config("granite_8b")
    specs = sharding.param_specs(cfg, mesh, use_fsdp=True)
    # wq (L, D, H*Dh): TP on dim 2, FSDP on dim 1 (D=128 % 2 == 0)
    assert specs["blocks"]["wq"] == P(None, "data", "model")


@pytest.mark.devices(8)
@pytest.mark.parametrize("merge", [steps_lib.Merge.ALLREDUCE,
                                   steps_lib.Merge.AVERAGE,
                                   steps_lib.Merge.DELTA,
                                   steps_lib.Merge.ASYNC_DELTA])
def test_window_step_runs_and_is_finite(merge):
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    model_common.set_run_options(mesh=None)
    cfg = registry.get_smoke_config("granite_8b")
    opt = optimizers.sgd(0.1)
    tau, b, t = 3, 4, 16
    step = steps_lib.make_window_step(cfg, opt, mesh, tau=tau, merge=merge,
                                      merge_axis="pod")
    state = steps_lib.init_window_state(cfg, opt, KEY, merge)
    batches = _batchify(cfg, b, t, tau=tau)
    with mesh:
        new_state, metrics = jax.jit(step)(state, batches)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == tau
    moved = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.devices(2)
def test_delta_merge_matches_sequential_when_single_worker():
    """With identical per-pod batches, DELTA with M pods applies M times the
    displacement (paper eq. 8: sum, not mean) — while AVERAGE reproduces the
    single-worker result exactly.  Checked on the real LM train step."""
    mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
    model_common.set_run_options(mesh=None)
    cfg = registry.get_smoke_config("granite_8b")
    opt = optimizers.sgd(0.05)
    tau, b, t = 2, 4, 8

    batches = _batchify(cfg, b, t, tau=tau)
    # identical batch on both pods: (tau, 2*b, t) by tiling on batch dim
    tiled = jax.tree.map(lambda x: jnp.concatenate([x, x], axis=1), batches)

    state0 = steps_lib.init_window_state(cfg, opt, KEY, steps_lib.Merge.AVERAGE)

    avg_step = steps_lib.make_window_step(
        cfg, opt, mesh, tau=tau, merge=steps_lib.Merge.AVERAGE,
        merge_axis="pod")
    dlt_step = steps_lib.make_window_step(
        cfg, opt, mesh, tau=tau, merge=steps_lib.Merge.DELTA,
        merge_axis="pod")
    with mesh:
        avg_state, _ = jax.jit(avg_step)(state0, tiled)
        dlt_state, _ = jax.jit(dlt_step)(state0, tiled)

    # single-worker reference: tau plain steps on one copy of the batch
    plain = steps_lib.make_train_step(cfg, opt, clip=1.0)
    ref = {k: state0[k] for k in ("params", "opt_state", "step")}
    for i in range(tau):
        ref, _ = jax.jit(plain)(
            ref, jax.tree.map(lambda x: x[i], batches))

    a_err = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
        avg_state["params"], ref["params"])
    assert max(jax.tree.leaves(a_err)) < 2e-5  # average == sequential

    # delta applies 2x the displacement: w_d - w0 == 2 (w_ref - w0)
    def _check(d, r, w0):
        np.testing.assert_allclose(
            np.asarray(d, np.float32) - np.asarray(w0, np.float32),
            2.0 * (np.asarray(r, np.float32) - np.asarray(w0, np.float32)),
            atol=5e-5)
    jax.tree.map(_check, dlt_state["params"], ref["params"],
                 state0["params"])


@pytest.mark.devices(8)
def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Checkpoint written under one mesh restores onto a different one."""
    from repro.checkpoint.checkpointing import Checkpointer
    cfg = registry.get_smoke_config("olmoe_1b_7b")
    opt = optimizers.adamw(1e-3)
    state = steps_lib.init_train_state(cfg, opt, KEY)

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    specs = sharding.param_specs(cfg, mesh_a, use_fsdp=False)
    state_specs = {"params": specs,
                   "opt_state": sharding.opt_specs_like(specs,
                                                        state["opt_state"]),
                   "step": P()}
    state_a = jax.device_put(state, sharding.named(mesh_a, state_specs))
    ck = Checkpointer(str(tmp_path))
    ck.save(7, state_a)

    mesh_b = jax.make_mesh((2, 2), ("data", "model"))
    specs_b = sharding.param_specs(cfg, mesh_b, use_fsdp=False)
    state_specs_b = {"params": specs_b,
                     "opt_state": sharding.opt_specs_like(
                         specs_b, state["opt_state"]),
                     "step": P()}
    restored = ck.restore(7, jax.tree.map(jnp.zeros_like, state),
                          shardings=sharding.named(mesh_b, state_specs_b))
    host_a = jax.tree.map(lambda x: np.asarray(x, np.float32),
                          jax.device_get(state_a["params"]))
    host_b = jax.tree.map(lambda x: np.asarray(x, np.float32),
                          jax.device_get(restored["params"]))
    jax.tree.map(np.testing.assert_array_equal, host_a, host_b)


@pytest.mark.devices(2)
def test_delta_sparse_full_density_equals_delta():
    """DELTA_SPARSE with frac=1.0 must reproduce DELTA exactly (the
    compression path is lossless when everything is kept)."""
    mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
    model_common.set_run_options(mesh=None)
    cfg = registry.get_smoke_config("granite_8b")
    opt = optimizers.sgd(0.05)
    tau, b, t = 2, 4, 8
    batches = _batchify(cfg, b, t, tau=tau)
    tiled = jax.tree.map(lambda x: jnp.concatenate([x, x], axis=1), batches)

    dlt = steps_lib.make_window_step(
        cfg, opt, mesh, tau=tau, merge=steps_lib.Merge.DELTA,
        merge_axis="pod")
    sps = steps_lib.make_window_step(
        cfg, opt, mesh, tau=tau, merge=steps_lib.Merge.DELTA_SPARSE,
        merge_axis="pod", compress_frac=1.0)
    s0d = steps_lib.init_window_state(cfg, opt, KEY, steps_lib.Merge.DELTA)
    s0s = steps_lib.init_window_state(cfg, opt, KEY,
                                      steps_lib.Merge.DELTA_SPARSE)
    with mesh:
        out_d, _ = jax.jit(dlt)(s0d, tiled)
        out_s, _ = jax.jit(sps)(s0s, tiled)
    err = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        out_d["params"], out_s["params"])
    assert max(jax.tree.leaves(err)) < 1e-5
    # residual must be ~zero at full density
    rmax = max(float(jnp.max(jnp.abs(r)))
               for r in jax.tree.leaves(out_s["residual"]))
    assert rmax < 1e-6


@pytest.mark.devices(2)
def test_delta_sparse_low_density_finite_and_bounded():
    mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
    model_common.set_run_options(mesh=None)
    cfg = registry.get_smoke_config("granite_8b")
    opt = optimizers.sgd(0.05)
    tau, b, t = 2, 4, 8
    batches = _batchify(cfg, b, t, tau=tau)
    tiled = jax.tree.map(lambda x: jnp.concatenate([x, x], axis=1), batches)
    step = steps_lib.make_window_step(
        cfg, opt, mesh, tau=tau, merge=steps_lib.Merge.DELTA_SPARSE,
        merge_axis="pod", compress_frac=0.05)
    s0 = steps_lib.init_window_state(cfg, opt, KEY,
                                     steps_lib.Merge.DELTA_SPARSE)
    with mesh:
        out, metrics = jax.jit(step)(s0, tiled)
    assert bool(jnp.isfinite(metrics["loss"]))
    # error feedback holds the skipped mass
    rmax = max(float(jnp.max(jnp.abs(r)))
               for r in jax.tree.leaves(out["residual"]))
    assert rmax > 0


def test_plan_remesh_prefers_tp():
    from repro.distributed import elastic
    # 512 -> 496 survivors: keep TP=16, shrink data to 31
    p = elastic.plan_remesh(496, prev_data=32, prev_model=16)
    assert p.model == 16 and p.data == 31 and p.tp_preserved
    # catastrophic: 12 survivors < TP=16 -> fall back to pow2 TP
    p2 = elastic.plan_remesh(12, prev_data=2, prev_model=16)
    assert not p2.tp_preserved and p2.model * p2.data <= 12


def test_merge_late_delta_staleness():
    import jax.numpy as jnp
    from repro.distributed import elastic
    w = {"p": jnp.ones((4,))}
    d = {"p": jnp.full((4,), 0.5)}
    on_time = elastic.merge_late_delta(w, d, delay_windows=0)
    late = elastic.merge_late_delta(w, d, delay_windows=3)
    np.testing.assert_allclose(np.asarray(on_time["p"]), 0.5)
    assert float(late["p"][0]) > 0.5  # damped: less of the delta applied


def test_dvq_window_matches_scheme_delta():
    """The SPMD window step (core/dvq.py) reproduces the simulated S2
    scheme (core/schemes.py) exactly for one window."""
    import jax.numpy as jnp
    from repro.core import dvq, schemes
    from repro.data import synthetic
    key = jax.random.PRNGKey(1)
    m, tau, d, kappa = 4, 10, 6, 8
    data = synthetic.replicate_stream(key, m, n=tau, d=d)
    w0 = synthetic.kmeanspp_init(jax.random.fold_in(key, 1),
                                 data.reshape(-1, d), kappa)
    ref = schemes.scheme_delta(w0, data, data, tau=tau)
    step = dvq.make_window_vq_step(tau=tau)
    w_new, t = jax.jit(step)(w0, jnp.zeros((), jnp.int32), data)
    np.testing.assert_allclose(np.asarray(w_new), np.asarray(ref.w_shared),
                               rtol=1e-5, atol=1e-6)
    assert int(t) == tau


@pytest.mark.devices(8)
def test_dvq_minibatch_reduces_distortion_on_mesh():
    import jax.numpy as jnp
    from repro.core import dvq, vq
    from repro.data import synthetic
    key = jax.random.PRNGKey(2)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n_steps, batch, d, kappa = 20, 256, 16, 32
    stream = synthetic.mixture_data(key, n=n_steps * batch, d=d)
    data = stream.reshape(n_steps, batch, d)
    w0 = synthetic.kmeanspp_init(jax.random.fold_in(key, 3), stream, kappa)
    w_sh, z_sh = dvq.vq_shardings(mesh, kappa=kappa, d=d, batch=batch)
    with mesh:
        w0_dev = jax.device_put(w0, w_sh)
        w_final, trace = dvq.run_minibatch_vq(w0_dev, data, steps=n_steps)
    assert float(trace[-1]) < float(trace[0])
    before = float(vq.distortion(stream, w0))
    after = float(vq.distortion(stream, jax.device_get(w_final)))
    assert after < before


@pytest.mark.devices(8)
def test_pipeline_parallel_matches_reference():
    """GPipe over 'pod': pipelined loss == plain loss; grads flow."""
    from repro.training import pipeline
    cfg = registry.get_smoke_config("granite_8b")  # 2 layers -> 2 stages
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    api = get_api(cfg)
    params = api.init(KEY)
    B, T = 8, 16
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    ref = float(api.loss_fn(params, batch))
    pp_loss = pipeline.make_pp_loss_fn(cfg, mesh, n_micro=4)
    with mesh:
        got = float(jax.jit(pp_loss)(params, batch))
        g = jax.jit(jax.grad(pp_loss))(params, batch)
    np.testing.assert_allclose(got, ref, rtol=2e-3)
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
