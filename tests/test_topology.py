"""Topology & hierarchical-merge tests (ISSUE 5).

The acceptance bars, verbatim:

  * a 2x4 hierarchical run with dense tier 1 matches the flat 8-worker
    mesh oracle BIT-FOR-BIT;
  * with sparse tier 1 at the k/kappa = 0.25 point, the measured
    inter-host wire bytes (per-tier ``CommRecord``s) come in >= 4x below
    dense while the final distortion stays within the PR-4 sparse bound;
  * ``hosts=1`` collapses bit-identically to the flat path on BOTH CI
    device legs; elastic host-group resize (2x4 -> 1x4 -> 2x4) ends
    within rtol 1e-2 of the fixed oracle;
  * no module outside ``src/repro/topology/`` constructs a mesh directly.
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)

import pathlib  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro import comm  # noqa: E402
from repro.comm import HierarchicalTransport, get_transport  # noqa: E402
from repro.comm.sweep import acceptance_sparse_frac  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.engine import (ElasticMeshExecutor, InstantNetwork,  # noqa: E402
                          MeshExecutor, ResizeSchedule, get_network)
from repro.topology import (Topology, make_host_mesh,  # noqa: E402
                            make_worker_mesh)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

KEY = jax.random.PRNGKey(42)
TAU = 10
D, KAPPA = 8, 16
FRAC_Q = acceptance_sparse_frac(KAPPA, D)  # k/kappa = 0.25


def _setup(m, n=400):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=D)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)
    return data, eval_data, w0


def _hier_transport(topo, tier1="sparse", frac=FRAC_Q):
    return HierarchicalTransport(
        tier0="xla", tier1=tier1,
        tier1_frac=frac if tier1 == "sparse" else None,
        host_axis=topo.host_axis, worker_axis=topo.worker_axis)


# ---------------------------------------------------------------------------
# topology invariants
# ---------------------------------------------------------------------------

def test_topology_partitions_devices_exactly_once():
    n = len(jax.devices())
    topo = Topology.from_spec(n, hosts=None)
    flat = list(topo.device_grid.reshape(-1))
    assert len({d.id for d in flat}) == n  # every device exactly once
    assert topo.total_workers == n
    # a grid that repeats a device is rejected
    dup = np.asarray([[jax.devices()[0], jax.devices()[0]]], dtype=object)
    with pytest.raises(ValueError, match="partition"):
        Topology(dup)


def test_topology_shape_and_axis_validation():
    with pytest.raises(ValueError, match="non-empty"):
        Topology.flat(1, worker_axis="")
    with pytest.raises(ValueError, match="distinct"):
        Topology.simulate(1, 1, host_axis="w", worker_axis="w")
    with pytest.raises(ValueError, match="hosts >= 1"):
        Topology.simulate(0, 2)
    with pytest.raises(ValueError, match="devices"):
        Topology.simulate(2, len(jax.devices()))
    with pytest.raises(ValueError, match="equal host groups"):
        Topology.from_spec(8, hosts=3)
    with pytest.raises(ValueError, match="hosts must be >= 1"):
        Topology.from_spec(8, hosts=-2)


@pytest.mark.devices(8)
def test_topology_shapes_and_specs():
    topo = Topology.from_spec(8, hosts=2)
    assert (topo.hosts, topo.workers_per_host, topo.total_workers) == (2, 4, 8)
    assert not topo.is_flat
    assert topo.axes == ("hosts", "workers")
    assert topo.spec == ("hosts", "workers")
    assert topo.describe() == "2x4"
    assert topo.group_of(0) == 0 and topo.group_of(7) == 1
    with pytest.raises(ValueError, match="outside"):
        topo.group_of(8)
    mesh = topo.make_mesh()
    assert mesh.devices.shape == (2, 4)
    assert mesh.axis_names == ("hosts", "workers")

    flat = Topology.from_spec(4, hosts=1)
    assert flat.is_flat and flat.spec == "workers"
    assert flat.make_mesh().devices.shape == (4,)


@pytest.mark.devices(8)
def test_topology_model_axis_mesh_forms():
    """The LM production form: each group's workers split (data, model)."""
    topo = Topology.flat(8)
    mesh = topo.make_mesh(model=2)
    assert mesh.devices.shape == (4, 2)
    assert mesh.axis_names == ("data", "model")
    pods = Topology.simulate(2, 4, host_axis="pod")
    mesh3 = pods.make_mesh(model=2)
    assert mesh3.devices.shape == (2, 2, 2)
    assert mesh3.axis_names == ("pod", "data", "model")
    with pytest.raises(ValueError, match="divide"):
        topo.make_mesh(model=3)


def test_topology_detect_single_process_is_flat():
    topo = Topology.detect()
    assert topo.is_flat
    assert topo.total_workers == len(jax.devices())


def test_make_worker_mesh_wrapper_still_validates():
    """The engine re-export keeps the historical error surface."""
    with pytest.raises(ValueError, match="non-empty"):
        make_worker_mesh(2, axis="")
    with pytest.raises(ValueError, match="devices"):
        make_worker_mesh(len(jax.devices()) + 1)
    mesh = make_host_mesh(data=2, model=1)
    assert mesh.axis_names == ("data", "model")


def test_no_mesh_construction_outside_topology():
    """CI pin: ``repro.topology`` is the only module in ``src/repro`` that
    builds a ``jax.sharding.Mesh`` (or calls ``jax.make_mesh``) — every
    other layer goes through a ``Topology``."""
    root = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    pat = re.compile(r"\bMesh\(|jax\.make_mesh\s*\(")
    offenders = []
    for path in sorted(root.rglob("*.py")):
        if "topology" in path.parts:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{path.relative_to(root)}:{lineno}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "raw mesh construction outside src/repro/topology/ — build it "
        "from a Topology instead:\n" + "\n".join(offenders))


# ---------------------------------------------------------------------------
# hierarchical transport semantics
# ---------------------------------------------------------------------------

def test_hier_transport_factory_and_validation():
    t = get_transport("hier", tier1_frac=0.25)
    assert t.name == "hier" and t.stateful and t.tier1_frac == 0.25
    dense = get_transport("hier", tier1="xla")
    assert not dense.stateful and dense.tier1_frac is None
    with pytest.raises(ValueError, match="distinct"):
        HierarchicalTransport(host_axis="w", worker_axis="w")
    with pytest.raises(ValueError, match="one place only"):
        HierarchicalTransport(tier1=get_transport("sparse", frac=0.5),
                              tier1_frac=0.25)
    with pytest.raises(ValueError, match="unknown reduce op"):
        t.all_reduce(jnp.zeros(3), ("hosts", "workers"), op="max")
    with pytest.raises(ValueError, match="reduces over"):
        t.all_reduce(jnp.zeros(3), "pods")


def test_hier_transport_state_tree():
    t = get_transport("hier", tier1_frac=FRAC_Q)
    st = t.init_state(jnp.zeros((4, 2)))
    assert set(st) == {"t0", "t1"}
    assert st["t0"] is None and st["t1"].shape == (4, 2)
    assert get_transport("hier", tier1="xla").init_state(
        jnp.zeros((4, 2))) is None


@pytest.mark.devices(8)
def test_hier_per_tier_wire_closed_form():
    """Per-tier CommRecords carry the closed-form two-tier schedule: tier 0
    the dense ring inside a 4-worker group, tier 1 across the 2 hosts —
    dense ring for xla, (hosts-1)*k*8 for sparse."""
    m, n = 8, 400
    n_windows = n // TAU
    logical = 4 * KAPPA * D
    data, eval_data, w0 = _setup(m)
    topo = Topology.from_spec(m, hosts=2)

    ex = MeshExecutor(topology=topo, network=InstantNetwork(),
                      transport=_hier_transport(topo, tier1="xla"))
    ex.run("delta", w0, data, eval_data, tau=TAU)
    tiers = ex.last_comm["by_tag"]["merge"]["by_tier"]
    assert tiers[0]["wire_bytes"] == n_windows * comm.ring_wire_bytes(
        logical, 4)
    assert tiers[1]["wire_bytes"] == n_windows * comm.ring_wire_bytes(
        logical, 2)

    exs = MeshExecutor(topology=topo, network=InstantNetwork(),
                       transport=_hier_transport(topo))
    exs.run("delta", w0, data, eval_data, tau=TAU)
    tiers_s = exs.last_comm["by_tag"]["merge"]["by_tier"]
    k = comm.topk_count(KAPPA * D, FRAC_Q)
    assert tiers_s[0]["wire_bytes"] == tiers[0]["wire_bytes"]
    assert tiers_s[1]["wire_bytes"] == n_windows * (2 - 1) * k * 8
    # the acceptance inequality, measured per-tier
    assert tiers[1]["wire_bytes"] / tiers_s[1]["wire_bytes"] >= 4.0


@pytest.mark.devices(8)
@pytest.mark.parametrize("scheme", ["average", "delta", "async_delta"])
def test_hier_dense_tier1_bitmatches_flat_oracle(scheme):
    """Acceptance: 2x4 hierarchical with dense tier 1 == flat 8-worker mesh
    BIT-FOR-BIT (the joint-axis group enumerates devices in flat order)."""
    data, eval_data, w0 = _setup(8)
    key = jax.random.fold_in(KEY, 9)
    flat = MeshExecutor(network=InstantNetwork()).run(
        scheme, w0, data, eval_data, tau=TAU, key=key)
    topo = Topology.from_spec(8, hosts=2)
    hier = MeshExecutor(topology=topo, network=InstantNetwork(),
                        transport=_hier_transport(topo, tier1="xla")).run(
        scheme, w0, data, eval_data, tau=TAU, key=key)
    np.testing.assert_array_equal(np.asarray(flat.w_shared),
                                  np.asarray(hier.w_shared))
    np.testing.assert_array_equal(np.asarray(flat.distortion),
                                  np.asarray(hier.distortion))


@pytest.mark.parametrize("tier1", ["xla", "sparse"])
def test_hosts_one_collapses_bit_identically(tier1):
    """Degenerate hosts=1 runs the flat path bit-for-bit on BOTH CI device
    legs (m = all available devices, so the 1-device leg runs m=1)."""
    m = min(8, len(jax.devices()))
    data, eval_data, w0 = _setup(m)
    flat = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, eval_data, tau=TAU)
    topo = Topology.from_spec(m, hosts=1)
    ex = MeshExecutor(topology=topo, network=InstantNetwork(),
                      transport=_hier_transport(topo, tier1=tier1))
    hier = ex.run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(flat.w_shared),
                                  np.asarray(hier.w_shared))
    np.testing.assert_array_equal(np.asarray(flat.distortion),
                                  np.asarray(hier.distortion))
    # tier-1 never ran: every merge record is tier 0, no inter-host wire
    tiers = ex.last_comm["by_tag"]["merge"].get("by_tier", {})
    assert 1 not in tiers


@pytest.mark.devices(8)
@pytest.mark.parametrize("scheme", ["delta", "async_delta"])
def test_hier_sparse_tier1_distortion_bound(scheme):
    """Acceptance: sparse tier 1 at k/kappa = 0.25 stays within the PR-4
    sparse bound (25% of dense final distortion) and still converges."""
    data, eval_data, w0 = _setup(8)
    key = jax.random.fold_in(KEY, 9)
    flat = MeshExecutor(network=InstantNetwork()).run(
        scheme, w0, data, eval_data, tau=TAU, key=key)
    topo = Topology.from_spec(8, hosts=2)
    hier = MeshExecutor(topology=topo, network=InstantNetwork(),
                        transport=_hier_transport(topo)).run(
        scheme, w0, data, eval_data, tau=TAU, key=key)
    curve = np.asarray(hier.distortion)
    assert np.all(np.isfinite(curve))
    assert curve[-1] < curve[0]
    gap = curve[-1] / float(flat.distortion[-1]) - 1.0
    assert abs(gap) < 0.25, f"hier sparse final C off flat by {gap:+.3f}"


@pytest.mark.devices(8)
def test_hier_sparse_full_density_matches_dense():
    """tier1_frac=1.0 keeps everything: only float-sum order can differ."""
    data, eval_data, w0 = _setup(8)
    topo = Topology.from_spec(8, hosts=2)
    dense = MeshExecutor(topology=topo, network=InstantNetwork(),
                         transport=_hier_transport(topo, tier1="xla")).run(
        "delta", w0, data, eval_data, tau=TAU)
    full = MeshExecutor(topology=topo, network=InstantNetwork(),
                        transport=_hier_transport(topo, frac=1.0)).run(
        "delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_allclose(np.asarray(dense.distortion),
                               np.asarray(full.distortion),
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# per-tier network charging
# ---------------------------------------------------------------------------

def test_fixed_network_charges_dcn_tier_separately():
    net = get_network("fixed", latency_ticks=0, bytes_per_tick=1000,
                      dcn_bytes_per_tick=10)
    assert net.transfer_ticks(1000) == 1                # flat: ICI rate
    assert net.transfer_ticks(1000, tier=0) == 1        # intra-host
    assert net.transfer_ticks(1000, tier=1) == 100      # slow DCN
    # without a DCN rate, tier 1 rides the common bandwidth
    flat = get_network("fixed", latency_ticks=0, bytes_per_tick=1000)
    assert flat.transfer_ticks(1000, tier=1) == 1
    with pytest.raises(ValueError, match="dcn_bytes_per_tick"):
        get_network("fixed", dcn_bytes_per_tick=-1)


@pytest.mark.devices(8)
def test_slow_dcn_stretches_hier_wall_clock():
    """Same merges, same curve values — but the sparse tier-1 wire on a
    slow DCN still costs fewer ticks than the dense tier-1 wire would:
    the paper's reason the final scheme exists, on the wall-tick axis."""
    data, eval_data, w0 = _setup(8)
    topo = Topology.from_spec(8, hosts=2)
    logical = 4 * KAPPA * D
    dcn = comm.ring_wire_bytes(logical, 2)  # dense tier-1 bytes per window
    net = get_network("fixed", latency_ticks=0, dcn_bytes_per_tick=dcn)
    free = MeshExecutor(topology=topo, network=InstantNetwork(),
                        transport=_hier_transport(topo, tier1="xla")).run(
        "delta", w0, data, eval_data, tau=TAU)
    dense = MeshExecutor(topology=topo, network=net,
                         transport=_hier_transport(topo, tier1="xla")).run(
        "delta", w0, data, eval_data, tau=TAU)
    sparse = MeshExecutor(topology=topo, network=net,
                          transport=_hier_transport(topo)).run(
        "delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_allclose(np.asarray(free.distortion),
                               np.asarray(dense.distortion), rtol=1e-6)
    assert int(dense.wall_ticks[0]) == TAU + 1   # 1 full DCN tick per window
    assert int(sparse.wall_ticks[0]) == TAU + 1  # ceil: tiny wire, 1 tick
    assert int(dense.wall_ticks[-1]) > int(free.wall_ticks[-1])


# ---------------------------------------------------------------------------
# multi-host elasticity: whole host groups
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_elastic_host_group_resize_matches_oracle():
    """Acceptance: 2x4 -> 1x4 -> 2x4 (a host group leaves and returns) ends
    within rtol 1e-2 of the fixed flat oracle on the same sample budget."""
    m, n = 8, 800
    data, eval_data, w0 = _setup(m, n=n)
    oracle = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, eval_data, tau=TAU)
    n_windows = n // TAU
    topo = Topology.from_spec(m, hosts=2)
    ex = ElasticMeshExecutor(
        ResizeSchedule([(n_windows // 3, 4), (2 * n_windows // 3, 8)]),
        network=InstantNetwork(), topology=topo,
        transport=_hier_transport(topo))
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    assert [(e.old_m, e.new_m) for e in ex.resize_events] == [(8, 4), (4, 8)]
    np.testing.assert_allclose(float(res.distortion[-1]),
                               float(oracle.distortion[-1]), rtol=1e-2)
    # the late-delta upload crossed host groups: tier-1 accounting
    late = ex.last_comm["by_tag"]["late_delta"]
    assert late["wire_bytes"] == 4 * KAPPA * D
    assert late["by_tier"][1]["wire_bytes"] == 4 * KAPPA * D


@pytest.mark.devices(8)
def test_elastic_hier_clamps_to_whole_host_groups():
    """A resize target that is not a whole number of host groups rounds
    down to one (workers_per_host stays fixed — hosts leave, not chips)."""
    data, eval_data, w0 = _setup(8)
    topo = Topology.from_spec(8, hosts=2)
    ex = ElasticMeshExecutor(ResizeSchedule([(2, 6)]),
                             network=InstantNetwork(), topology=topo,
                             transport=_hier_transport(topo))
    ex.run("delta", w0, data, eval_data, tau=TAU)
    assert [(e.old_m, e.new_m) for e in ex.resize_events] == [(8, 4)]


# ---------------------------------------------------------------------------
# regression-gate units (benchmarks/check_regression.py, hier suite)
# ---------------------------------------------------------------------------

def _hier_doc(inter_wire=640, reduction=16.0, bitmatch=True, parity=1.0,
              final_c=0.02):
    def cell(variant, tier0, tier1, **kw):
        c = {"kind": "cell", "scheme": "delta", "variant": variant,
             "hosts": 2 if variant != "flat" else 1,
             "workers_per_host": 4 if variant != "flat" else 8,
             "m": 8, "n": 200, "d": 8, "kappa": 16, "tau": 10,
             "tier1_frac": FRAC_Q if variant == "hier_sparse" else None,
             "wall_s": 0.01, "merge_wire_bytes": tier0 + tier1,
             "tier0_wire_bytes": tier0, "tier1_wire_bytes": tier1,
             "final_C": final_c}
        c.update(kw)
        return c
    return {"suite": "hier", "results": [
        cell("flat", 0, 0),
        cell("hier_dense", 15360, 10240, bitmatch_flat=bitmatch),
        cell("hier_sparse", 15360, inter_wire, bitmatch_flat=False),
        {"kind": "inter_reduction", "m": 8, "hosts": 2, "kappa": 16,
         "d": 8, "tier1_frac": FRAC_Q, "reduction": reduction,
         "dense_bitmatch": bitmatch},
        {"kind": "hier_parity", "m": 8,
         "parity": parity if isinstance(parity, dict) else
         {"average": parity, "delta": parity, "async_delta": parity}},
    ]}


def test_hier_gate_passes_identical():
    from benchmarks.check_regression import check_hier
    ok, msgs = check_hier(_hier_doc(), _hier_doc())
    assert ok, msgs


def test_hier_gate_fails_on_tier_wire_drift():
    from benchmarks.check_regression import check_hier
    ok, msgs = check_hier(_hier_doc(), _hier_doc(inter_wire=1280))
    assert not ok and any("tier1_wire_bytes drifted" in m for m in msgs)


def test_hier_gate_fails_below_inter_floor():
    from benchmarks.check_regression import check_hier
    ok, msgs = check_hier(_hier_doc(), _hier_doc(reduction=3.0))
    assert not ok and any("below the 4x bar" in m for m in msgs)


def test_hier_gate_fails_on_lost_bitmatch():
    from benchmarks.check_regression import check_hier
    ok, msgs = check_hier(_hier_doc(), _hier_doc(bitmatch=False))
    assert not ok and any("bit-match" in m for m in msgs)


def test_hier_gate_fails_on_parity_regression_all_legs():
    from benchmarks.check_regression import check_hier
    ok, msgs = check_hier(_hier_doc(parity=1.0), _hier_doc(parity=1.5))
    assert not ok and any("wall parity" in m for m in msgs)
    # single-leg noise does not flip the min-over-schemes statistic
    noisy = _hier_doc(parity={"average": 2.0, "delta": 1.0,
                              "async_delta": 1.0})
    ok, msgs = check_hier(_hier_doc(parity=1.0), noisy)
    assert ok, msgs


def test_hier_gate_rejects_config_mismatch_and_lost_cells():
    from benchmarks.check_regression import check_hier
    fresh = _hier_doc()
    fresh["results"][1]["kappa"] = 32
    with pytest.raises(ValueError, match="regenerate"):
        check_hier(_hier_doc(), fresh)
    lost = _hier_doc()
    lost["results"] = [r for r in lost["results"]
                       if r.get("variant") != "hier_sparse"]
    with pytest.raises(ValueError, match="missing baseline cells"):
        check_hier(_hier_doc(), lost)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_train_cli_hosts_smoke(capsys):
    from repro.launch import train
    rc = train.main(["--mode", "vq", "--executor", "mesh", "--scheme",
                     "delta", "--workers", "8", "--hosts", "2",
                     "--points", "100", "--kappa", "8", "--dim", "4"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "topology=2x4" in out and "transport=hier" in out
    assert "tier 0 (intra-host)" in out and "tier 1 (inter-host)" in out


def test_train_cli_hosts_validation(capsys):
    from repro.launch import train
    rc = train.main(["--mode", "vq", "--executor", "mesh", "--workers",
                     "8", "--hosts", "3", "--points", "50"])
    assert rc == 2
    assert "equal host groups" in capsys.readouterr().out
    rc = train.main(["--mode", "vq", "--executor", "sim", "--workers",
                     "8", "--hosts", "2", "--points", "50"])
    assert rc == 2
    assert "needs --executor mesh" in capsys.readouterr().out
    rc = train.main(["--mode", "vq", "--executor", "mesh", "--workers",
                     "8", "--hosts", "2", "--tier1-frac", "2.0",
                     "--points", "50"])
    assert rc == 2
    assert "compression frac" in capsys.readouterr().out
