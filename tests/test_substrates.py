"""Substrate tests: checkpointing, optimizers, compression, data pipeline,
serving parity (prefill-by-decode == forward)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image ships without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs import registry
from repro.data.pipeline import DataConfig, lm_batch
from repro.models.api import get_api
from repro.optim import optimizers, compression
from repro.training import steps as steps_lib

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        ck.save(step, jax.tree.map(lambda x: x + step, tree))
    assert ck.all_steps() == [2, 3]  # keep=2 retention
    restored = ck.restore(3, tree)
    np.testing.assert_allclose(np.asarray(restored["a"], np.float32),
                               np.arange(6).reshape(2, 3) + 3)


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.full((8, 8), 3.0)}
    ck.save_async(5, tree)
    ck.wait()
    assert ck.latest_step() == 5
    out = ck.restore(5, tree)
    np.testing.assert_allclose(np.asarray(out["w"]), 3.0)


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        ck.restore(1, {"w": jnp.zeros((5,))})


def test_checkpoint_partial_write_invisible(tmp_path):
    """A directory without a manifest (simulated crash) is not listed."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((2,))})
    os.makedirs(tmp_path / "step_000000002")  # crashed, no manifest
    assert ck.all_steps() == [1]


# ---------------------------------------------------------------------------
# optimizers + schedules
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = optimizers.adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state = opt.update(grads, state, params)
    assert abs(float(params["w"])) < 1e-2


def test_sgd_momentum_converges():
    opt = optimizers.sgd(0.05, momentum=0.9)
    params = {"w": jnp.asarray(4.0)}
    state = opt.init(params)
    for _ in range(200):
        params, state = opt.update({"w": 2 * params["w"]}, state, params)
    assert abs(float(params["w"])) < 2e-2


def test_cosine_schedule_shape():
    fn = optimizers.cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_rm_schedule_matches_paper():
    fn = optimizers.rm_schedule(0.5, 1.0)
    assert float(fn(jnp.asarray(0))) == 0.5
    assert float(fn(jnp.asarray(4))) == pytest.approx(0.1)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = optimizers.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(300), rel=1e-5)
    assert float(optimizers.global_norm(clipped)) == pytest.approx(1.0,
                                                                   rel=1e-4)


# ---------------------------------------------------------------------------
# gradient / delta compression
# ---------------------------------------------------------------------------

def test_topk_error_feedback_conserves_mass():
    """compressed + residual' == delta + residual (nothing is lost)."""
    delta = {"w": jax.random.normal(KEY, (64, 32))}
    ef = compression.init_error_feedback(delta)
    comp, ef2, frac = compression.topk_compress(delta, ef, frac=0.1)
    total_in = delta["w"].astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(comp["w"].astype(jnp.float32) + ef2.residual["w"]),
        np.asarray(total_in), atol=1e-5)
    kept = float(jnp.mean((comp["w"] != 0).astype(jnp.float32)))
    assert kept <= 0.15  # ~10% kept


@settings(max_examples=10, deadline=None)
@given(st.floats(0.01, 0.5), st.integers(0, 10_000))
def test_topk_keeps_largest(frac, seed):
    x = {"w": jax.random.normal(jax.random.PRNGKey(seed), (128,))}
    ef = compression.init_error_feedback(x)
    comp, _, _ = compression.topk_compress(x, ef, frac=frac)
    kept_vals = np.abs(np.asarray(comp["w"]))
    dropped = np.abs(np.asarray(x["w"]))[kept_vals == 0]
    if kept_vals.max() > 0 and dropped.size:
        assert dropped.max() <= kept_vals[kept_vals > 0].min() + 1e-6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_lm_batch_deterministic_and_shifted():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=9)
    b1 = lm_batch(cfg, 3)
    b2 = lm_batch(cfg, 3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(cfg, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted: labels[:, :-1] == tokens[:, 1:]
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


# ---------------------------------------------------------------------------
# serving parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ["granite_8b", "mamba2_2p7b",
                                     "hymba_1p5b", "whisper_tiny"])
def test_prefill_by_decode_matches_forward(arch_id):
    """Teacher-forcing T tokens through decode_step reproduces forward()
    logits — the KV/SSM cache math is exact."""
    cfg = registry.get_smoke_config(arch_id)
    api = get_api(cfg)
    params = api.init(KEY)
    B, T = 2, 8
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    ref = api.forward(params, batch)
    if cfg.family == "vlm":
        ref = ref[:, cfg.img_tokens:]
    cache = api.init_cache(params, batch, T)
    outs = []
    for t in range(T):
        lg, cache = api.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(dec, np.float32),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch_id", ["granite_8b", "mamba2_2p7b",
                                     "hymba_1p5b", "olmoe_1b_7b"])
def test_prefill_fills_cache_exactly(arch_id):
    """prefill(T) then G decode steps == T+G teacher-forced decode steps.

    MoE uses ample capacity here: capacity dropping is the one legitimate
    prefill/decode divergence (single-token decode is effectively dropless).
    """
    import dataclasses
    cfg = registry.get_smoke_config(arch_id)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    api = get_api(cfg)
    params = api.init(KEY)
    B, T, G = 2, 8, 4
    toks = jax.random.randint(KEY, (B, T + G), 0, cfg.vocab)
    cache = api.init_cache(params, {"tokens": toks}, T + G)
    ref = []
    for t in range(T + G):
        lg, cache = api.decode_step(params, cache, toks[:, t:t + 1])
        ref.append(lg[:, 0])
    logits0, cache2 = api.prefill(params, {"tokens": toks[:, :T]}, T + G)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(ref[T - 1]),
                               rtol=3e-3, atol=3e-3)
    for t in range(T, T + G):
        lg, cache2 = api.decode_step(params, cache2, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[t]),
                                   rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# int8 weight-only quantization (serving)
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_small():
    from repro.models import quantization
    cfg = registry.get_smoke_config("granite_8b")
    api = get_api(cfg)
    params = api.init(KEY)
    qp = quantization.quantize_tree(params, min_size=64)
    err = quantization.quantization_error(params, qp)
    assert 0 < err < 0.02  # per-channel int8: <2% relative error


def test_quantized_decode_close_to_full_precision():
    from repro.models import quantization
    cfg = registry.get_smoke_config("granite_8b")
    api = get_api(cfg)
    params = api.init(KEY)
    qp = quantization.quantize_tree(params, min_size=64)
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    cache = api.init_cache(params, {"tokens": toks}, 8)
    full = steps_lib.make_serve_step(cfg)
    quant = steps_lib.make_serve_step(cfg, quantized=True)
    lf, _ = jax.jit(full)(params, cache, toks)
    lq, _ = jax.jit(quant)(qp, cache, toks)
    # logits agree to quantization noise
    corr = np.corrcoef(np.asarray(lf).ravel(), np.asarray(lq).ravel())[0, 1]
    assert corr > 0.999
