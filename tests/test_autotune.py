"""Autotuner determinism + fused-kernel parity on ragged shapes.

Three contracts pinned here:

  * every kernel route (full-codebook, fused blocked, unfused comparator,
    autotuned default) matches the pure-jnp oracle on shapes that do NOT
    divide the tiles — batch not a multiple of bm, kappa not a multiple of
    bk, kappa < bk, batch < 8;
  * the tuner is deterministic: same shape => same config, a cache hit
    never re-searches, and the JSON file cache round-trips;
  * no module outside ``src/repro/kernels/`` passes literal tile sizes —
    tiles come from ``kernels.autotune`` or an explicit caller override,
    never from scattered hardcoded constants.
"""

import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.sparse import topk_count
from repro.core import vq
from repro.kernels import autotune, ops, ref

KEY = jax.random.PRNGKey(11)

# batch % bm != 0, kappa % bk != 0, kappa < bk, batch < 8 — all the ways a
# shape can disagree with a tile
RAGGED = [(100, 200, 16), (64, 300, 8), (7, 33, 5), (3, 4, 2), (130, 17, 3)]


@pytest.fixture(autouse=True)
def _fresh_tuner():
    """Each test sees a clean in-memory tuner and leaves one behind."""
    autotune.set_cache_path(None)
    autotune.reset("cache")
    yield
    autotune.set_cache_path(None)
    autotune.reset("cache")


def _case(batch, kappa, d):
    kz, kw = jax.random.split(jax.random.fold_in(KEY, batch * kappa + d))
    z = jax.random.normal(kz, (batch, d))
    w = jax.random.normal(kw, (kappa, d))
    return z, w


# -- ragged-shape parity: every route vs the oracle -------------------------

@pytest.mark.parametrize("batch,kappa,d", RAGGED)
def test_all_delta_routes_match_ref_on_ragged_shapes(batch, kappa, d):
    z, w = _case(batch, kappa, d)
    cr, sr = ref.vq_delta_ref(z, w)
    routes = {
        "full": {},                                   # fits-VMEM kernel
        "blocked_tuned": {"budget_bytes": 1024},      # fused, tuner tiles
        "blocked_forced": {"budget_bytes": 1024, "bm": 16, "bk": 128},
        "unfused": {"budget_bytes": 1024, "fused": False},
    }
    for name, kwargs in routes.items():
        c, s = ops.vq_delta_routed(z, w, **kwargs)
        np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                                   atol=1e-5, err_msg=name)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


@pytest.mark.parametrize("batch,kappa,d", RAGGED[:3])
def test_vq_assign_autotuned_matches_ref(batch, kappa, d):
    z, w = _case(batch, kappa, d)
    a, m = ops.vq_assign(z, w)                        # tiles from the tuner
    ar, mr = ref.vq_assign_ref(z, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr),
                               rtol=1e-4, atol=1e-4)


def test_window_kernel_bitwise_matches_per_step_scan():
    tau, kappa, d = 12, 16, 8
    kz, kw = jax.random.split(jax.random.fold_in(KEY, 99))
    zwin = jax.random.normal(kz, (tau, d))
    w0 = jax.random.normal(kw, (kappa, d))
    eps = vq.default_steps(1 + jnp.arange(tau, dtype=jnp.int32))
    w_fused = ops.vq_window(zwin, w0, eps)

    # the engine's pre-fusion per-step path, verbatim (mesh._local_window's
    # scan body) — the fused kernel replays these float ops exactly
    def scan_oracle(zwin, w0, eps):
        def body(w, ze):
            z, e = ze
            counts, zsum = ops.vq_delta(z[None, :], w)
            h = counts[:, None] * w - zsum
            return w - e * h, None
        return jax.lax.scan(body, w0, (zwin, eps))[0]

    w_ref = jax.jit(scan_oracle)(zwin, w0, eps)
    # fusion trades dispatches, not math: BITWISE equality, not allclose
    assert np.array_equal(np.asarray(w_fused), np.asarray(w_ref))


@pytest.mark.parametrize("budget", [None, 1024])
def test_vq_delta_topk_matches_sparse_transport_semantics(budget):
    batch, kappa, d, frac = 40, 24, 6, 0.1
    z, w = _case(batch, kappa, d)
    residual = jax.random.normal(jax.random.fold_in(KEY, 5), (kappa, d))
    vals, idx, new_res = ops.vq_delta_topk(z, w, residual, frac=frac,
                                           budget_bytes=budget)
    # oracle mirrors comm.sparse.sparse_allsum's per-leaf compress
    cr, sr = ref.vq_delta_ref(z, w)
    full = (np.asarray(cr)[:, None] * np.asarray(w, np.float32)
            - np.asarray(sr) + np.asarray(residual, np.float32))
    flat = full.reshape(-1)
    k = topk_count(kappa * d, frac)
    assert vals.shape == (k,) and idx.shape == (k,)
    order = np.argsort(-np.abs(flat), kind="stable")[:k]
    np.testing.assert_array_equal(np.sort(np.asarray(idx)), np.sort(order))
    np.testing.assert_allclose(np.asarray(vals),
                               flat[np.asarray(idx)], rtol=1e-4, atol=1e-4)
    kept = np.zeros_like(flat)
    kept[np.asarray(idx)] = flat[np.asarray(idx)]
    np.testing.assert_allclose(np.asarray(new_res).reshape(-1), flat - kept,
                               rtol=1e-4, atol=1e-4)


# -- tuner determinism ------------------------------------------------------

def test_same_shape_same_config_and_cache_hit_never_researches():
    c1 = autotune.pick_tiles(100, 200, 16)
    assert autotune.search_count() == 1
    c2 = autotune.pick_tiles(100, 200, 16)
    assert c1 == c2
    assert autotune.search_count() == 1          # hit: zero re-search
    # the pick must be feasible under the SAME formula the router uses
    assert ops.delta_vmem_bytes(200, 16, bm=c1.bm, bk=c1.bk) \
        <= ops.vmem_budget_bytes(None)
    # a different shape is a different key, not a collision
    c3 = autotune.pick_tiles(64, 300, 8)
    assert autotune.search_count() == 2
    assert autotune.tune_key("delta", 100, 200, 16) \
        != autotune.tune_key("delta", 64, 300, 8)


def test_off_mode_returns_legacy_tiles_without_caching():
    autotune.reset("off")
    cfg = autotune.pick_tiles(100, 200, 16)
    assert (cfg.bm, cfg.bk) == autotune.DEFAULT_TILES
    assert autotune.search_count() == 0


def test_json_cache_round_trips(tmp_path):
    path = tmp_path / "tiles.json"
    autotune.set_cache_path(str(path))
    autotune.reset("cache")
    c1 = autotune.pick_tiles(100, 200, 16)
    assert autotune.search_count() == 1
    assert path.exists()
    # a fresh process (reset) reloads the file: hit, zero re-search
    autotune.reset("cache")
    c2 = autotune.pick_tiles(100, 200, 16)
    assert c1 == c2
    assert autotune.search_count() == 0


def test_search_mode_result_is_cached_and_feasible():
    autotune.reset("search")
    cfg = autotune.pick_tiles(16, 16, 4)
    assert autotune.search_count() == 1
    assert ops.delta_vmem_bytes(16, 4, bm=cfg.bm, bk=cfg.bk) \
        <= ops.vmem_budget_bytes(None)
    assert autotune.pick_tiles(16, 16, 4) == cfg
    assert autotune.search_count() == 1          # measured once, cached


def test_tune_key_is_device_scoped():
    assert autotune.device_kind() in autotune.tune_key("delta", 8, 16, 4)


# -- the tile-hygiene pin ---------------------------------------------------

def test_no_literal_tile_sizes_outside_kernels():
    """Tiles are the tuner's (or an explicit caller's) to choose: no module
    outside ``src/repro/kernels/`` may pass literal ``bm=``/``bk=`` sizes."""
    import repro
    root = pathlib.Path(next(iter(repro.__path__)))
    pat = re.compile(r"\b(bm|bk)\s*=\s*\d")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        if p.relative_to(root).parts[0] == "kernels":
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{p.relative_to(root)}:{i}: {line.strip()}")
    assert not offenders, (
        "literal kernel tile sizes outside src/repro/kernels/ "
        "(route through kernels.autotune instead):\n" + "\n".join(offenders))
