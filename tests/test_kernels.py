"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image ships without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)

SWEEP = [
    (8, 8, 8), (64, 16, 4), (128, 128, 32), (256, 300, 64),
    (100, 17, 5), (512, 64, 128), (33, 129, 7),
]


@pytest.mark.parametrize("batch,kappa,d", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vq_assign_matches_ref(batch, kappa, d, dtype):
    kz, kw = jax.random.split(jax.random.fold_in(KEY, batch * kappa + d))
    z = jax.random.normal(kz, (batch, d), dtype)
    w = jax.random.normal(kw, (kappa, d), dtype)
    a, m = ops.vq_assign(z, w)
    ar, mr = ref.vq_assign_ref(z, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    # ties under bf16 rounding can flip the argmin: check distances instead
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr),
                               rtol=tol, atol=tol)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))


@pytest.mark.parametrize("batch,kappa,d", SWEEP)
def test_vq_delta_matches_ref(batch, kappa, d):
    kz, kw = jax.random.split(jax.random.fold_in(KEY, batch + kappa * d))
    z = jax.random.normal(kz, (batch, d))
    w = jax.random.normal(kw, (kappa, d))
    c, s = ops.vq_delta(z, w)
    cr, sr = ref.vq_delta_ref(z, w)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batch,kappa,d", SWEEP[:4])
def test_distortion_matches_ref(batch, kappa, d):
    kz, kw = jax.random.split(jax.random.fold_in(KEY, batch))
    z = jax.random.normal(kz, (batch, d))
    w = jax.random.normal(kw, (kappa, d))
    np.testing.assert_allclose(float(ops.distortion(z, w)),
                               float(ref.distortion_ref(z, w)), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 200), st.integers(2, 100), st.integers(1, 48),
       st.integers(0, 2**31 - 1))
def test_vq_delta_properties(batch, kappa, d, seed):
    """Invariants: counts sum to batch; zsum column sums == data column sums;
    delta == counts*w - zsum reproduces H_batch."""
    key = jax.random.PRNGKey(seed)
    kz, kw = jax.random.split(key)
    z = jax.random.normal(kz, (batch, d))
    w = jax.random.normal(kw, (kappa, d))
    c, s = ops.vq_delta(z, w)
    assert float(jnp.sum(c)) == pytest.approx(batch, abs=1e-3)
    np.testing.assert_allclose(np.asarray(jnp.sum(s, axis=0)),
                               np.asarray(jnp.sum(z, axis=0)),
                               rtol=1e-3, atol=1e-3)
    from repro.core import vq as vq_core
    delta = c[:, None] * w - s
    np.testing.assert_allclose(np.asarray(delta),
                               np.asarray(vq_core.H_batch(z, w)),
                               rtol=1e-3, atol=1e-3)


def test_block_size_invariance():
    """Same results regardless of BlockSpec tile sizes."""
    z = jax.random.normal(KEY, (512, 24))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (200, 24))
    a1, m1 = ops.vq_assign(z, w, bm=128, bk=128)
    a2, m2 = ops.vq_assign(z, w, bm=64, bk=32)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5)


def test_vmem_budget_routing():
    """The routing helper: explicit > env var > default; residency math."""
    assert ops.vmem_budget_bytes() == ops.DEFAULT_VMEM_BUDGET_BYTES
    assert ops.vmem_budget_bytes(1234) == 1234
    with pytest.raises(ValueError):
        ops.vmem_budget_bytes(0)
    # a 16x8 codebook trivially fits; a huge one cannot
    assert ops.delta_fits_vmem(16, 8)
    assert not ops.delta_fits_vmem(1 << 20, 512)
    assert ops.codebook_fits_vmem(16, 8)
    assert not ops.codebook_fits_vmem(16, 8, budget_bytes=64)
    # the fused kernel's residency grows with kappa*d
    assert (ops.delta_vmem_bytes(1024, 64)
            > ops.delta_vmem_bytes(128, 64))


@pytest.mark.parametrize("batch,kappa,d", [(100, 200, 16), (64, 300, 8)])
def test_vq_delta_routed_blocked_parity_kappa_gt_bk(batch, kappa, d):
    """kappa > bk forces the blocked-assign + segment-sum fallback; it must
    reproduce the fused kernel / oracle exactly (first step of the
    larger-than-VMEM-codebooks roadmap item, scoped to the lookup path)."""
    kz, kw = jax.random.split(jax.random.fold_in(KEY, batch * kappa))
    z = jax.random.normal(kz, (batch, d))
    w = jax.random.normal(kw, (kappa, d))
    assert kappa > 128  # the bk block size: the codebook IS streamed
    # tiny budget -> blocked path; default budget -> fused path
    c_blk, s_blk = ops.vq_delta_routed(z, w, bk=128, budget_bytes=1024)
    c_fus, s_fus = ops.vq_delta_routed(z, w)
    assert not ops.delta_fits_vmem(kappa, d, budget_bytes=1024)
    assert ops.delta_fits_vmem(kappa, d)
    cr, sr = ref.vq_delta_ref(z, w)
    for c, s in ((c_blk, s_blk), (c_fus, s_fus)):
        np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-5)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                   rtol=1e-4, atol=1e-4)


def test_minibatch_step_reduces_distortion():
    from repro.data import synthetic
    data = synthetic.mixture_data(KEY, n=4096, d=16, n_centers=8)
    w = synthetic.kmeanspp_init(jax.random.fold_in(KEY, 3), data, 32)
    d0 = float(ref.distortion_ref(data, w))
    for i in range(10):
        w = ops.vq_minibatch_step(data[i * 256:(i + 1) * 256], w,
                                  jnp.asarray(0.5))
    d1 = float(ref.distortion_ref(data, w))
    assert d1 < d0
