"""Roofline-attributed profiling tests (the PR-8 tentpole).

The profiler's contract is cross-checked three independent ways:

* the attribution terms (compute/memory/collective + host residual) must
  sum to the MEASURED per-window wall within the 15% acceptance bar;
* the collective bytes it reads out of the compiled program's HLO
  (trip-count-corrected) must match the transport's own CommLog
  logical-byte accounting of the same program near-exactly — two
  derivations of the same traffic, one from compiled-shape regexes and
  one from trace-time records;
* the while-loop trip counts inferred from the HLO must be the engine's
  real loop structure (outer = n_windows, inner = tau).
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)

import json  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.data import synthetic  # noqa: E402
from repro.distributed import roofline  # noqa: E402
from repro.engine import (ElasticMeshExecutor, InstantNetwork,  # noqa: E402
                          MeshExecutor)
from repro.obs import MetricsRegistry, Profiler  # noqa: E402

M, N, D, KAPPA, TAU = 4, 400, 8, 16, 50


def _data(m=M, n=N):
    key = jax.random.PRNGKey(0)
    kd, kw, ka = jax.random.split(key, 3)
    data = synthetic.replicate_stream(kd, m, n=n, d=D)
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)
    return w0, data, data[:, :100], ka


def _profiled_run(scheme, *, m=M):
    reg = MetricsRegistry()
    prof = Profiler(metrics=reg)
    ex = MeshExecutor(network=InstantNetwork(), profiler=prof, metrics=reg)
    w0, data, eval_data, key = _data(m=m)
    ex.run(scheme, w0, data, eval_data, tau=TAU, eps0=0.5, key=key)
    return prof, reg, ex


@pytest.mark.devices(4)
@pytest.mark.parametrize("scheme", ["average", "delta", "async_delta"])
def test_attribution_sums_to_measured_wall(scheme):
    prof, _, _ = _profiled_run(scheme)
    assert len(prof.attributions) == 1
    a = prof.attributions[0]
    assert a["scheme"] == scheme
    assert a["consistency"] <= 0.15
    total = sum(a[f"t_{t}_s"] for t in ("compute", "memory", "collective",
                                        "host"))
    assert total == pytest.approx(a["attributed_window_s"])
    assert a["window_wall_s"] > 0
    # compiled-in-run flagged: the first run pays the compile
    assert a["compiled_in_run"] is True


@pytest.mark.devices(4)
@pytest.mark.parametrize("scheme", ["average", "delta", "async_delta"])
def test_hlo_bytes_match_commlog_logical_bytes(scheme):
    """Two independent derivations of the merge traffic must agree."""
    prof, _, ex = _profiled_run(scheme)
    a = prof.attributions[0]
    by_tag = ex.transport.log.logical_bytes_by_tag()
    commlog_total = sum(by_tag.values())
    hlo_total = a["collective_bytes_per_window"] * a["n_windows"]
    assert hlo_total == pytest.approx(commlog_total, rel=1e-6)


@pytest.mark.devices(4)
def test_trip_counts_pin_the_window_scan():
    """Sync program: outer while = n_windows, inner while = tau."""
    prof, _, _ = _profiled_run("delta")
    (prog,) = prof.programs.values()
    trips = sorted(t for _, t in prog.loops)
    assert N // TAU in trips, trips        # outer window scan
    assert TAU in trips, trips             # inner step scan


@pytest.mark.devices(4)
def test_analytic_flops_cross_check_xla_cost_analysis():
    """The VqCell's analytic count must live within an order of magnitude
    of XLA's own cost_analysis for the same program (the analytic count
    is per logical worker and XLA counts the loop body once with fusion
    freedom, so this is a sanity band, not an equality)."""
    prof, _, _ = _profiled_run("delta")
    (prog,) = prof.programs.values()
    if prog.cost_flops is None:
        pytest.skip("backend exposes no cost_analysis")
    cell = roofline.VqCell(d=D, kappa=KAPPA, tau=TAU, n_eval=100)
    analytic_body = cell.window_flops()
    assert 0.05 < prog.cost_flops / analytic_body < 50.0


@pytest.mark.devices(4)
def test_metrics_emission_gauges_and_counters():
    prof, reg, _ = _profiled_run("average")
    for term in ("compute", "memory", "collective", "host"):
        g = reg.gauge("roofline_efficiency", term=term, scheme="average",
                      transport="xla")
        assert g.value >= 0.0
        c = reg.counter(f"attributed_{term}_ns", scheme="average",
                        transport="xla")
        assert c.value >= 0.0
    a = prof.attributions[0]
    host_ns = reg.counter("attributed_host_ns", scheme="average",
                          transport="xla").value
    assert host_ns == pytest.approx(
        a["t_host_s"] * a["n_windows"] * 1e9, rel=1e-6)


@pytest.mark.devices(4)
def test_second_run_reuses_compiled_program():
    """The profiler's AOT path must cache: run #2 compiles nothing and is
    flagged as warm (compiled_in_run=False)."""
    reg = MetricsRegistry()
    prof = Profiler(metrics=reg)
    ex = MeshExecutor(network=InstantNetwork(), profiler=prof, metrics=reg)
    w0, data, eval_data, key = _data()
    ex.run("delta", w0, data, eval_data, tau=TAU, eps0=0.5, key=key)
    n_programs = len(prof.programs)
    ex.run("delta", w0, data, eval_data, tau=TAU, eps0=0.5, key=key)
    assert len(prof.programs) == n_programs
    assert [a["compiled_in_run"] for a in prof.attributions] == [True, False]


@pytest.mark.devices(8)
def test_elastic_shares_one_profiler_across_segments():
    prof = Profiler()
    ex = ElasticMeshExecutor([(20, 4)], network=InstantNetwork(),
                             profiler=prof)
    w0, data, eval_data, key = _data(m=8)
    ex.run("delta", w0, data, eval_data, tau=10, eps0=0.5, key=key)
    # exactly ONE attribution (the wall-owning elastic run), built from
    # the per-M segment executors' notes
    assert len(prof.attributions) == 1
    a = prof.attributions[0]
    assert a["segments"] == 2
    assert a["consistency"] <= 0.15


@pytest.mark.devices(4)
def test_export_json_roundtrip(tmp_path):
    prof, _, _ = _profiled_run("delta")
    p = tmp_path / "prof.json"
    prof.export_json(str(p))
    doc = json.loads(p.read_text())
    assert doc["attributions"] == prof.attributions
    assert set(doc["programs"]) == set(map(str, prof.programs))
    table = prof.summary_table()
    assert "delta" in table and "consistency" in table


def test_profiler_empty_run_is_inert():
    prof = Profiler()
    assert prof.finish_run(1.0) is None
    assert prof.attributions == []


# ---------------------------------------------------------------------------
# regression-gate units (benchmarks/check_regression.py, profile suite)
# ---------------------------------------------------------------------------

def _attr(scheme, *, consistency=0.01, coll=520.0, eff=1e-7, wall=0.5):
    n_windows = 40
    return {
        "kind": "attribution", "scheme": scheme, "transport": "xla",
        "m": 8, "n": 2000, "d": 8, "kappa": 16, "tau": 50,
        "wall_s": wall, "commlog_logical_bytes_per_window": coll,
        "attribution": {
            "scheme": scheme, "transport": "xla", "n_windows": n_windows,
            "wall_s": wall, "window_wall_s": wall / n_windows,
            "t_compute_s": 1e-8, "t_memory_s": 1e-7,
            "t_collective_s": 1e-8, "t_host_s": wall / n_windows,
            "consistency": consistency,
            "collective_bytes_per_window": coll,
            "efficiency": {"compute": eff, "memory": 1e-6,
                           "collective": 1e-7, "host": 0.99},
        },
    }


def _doc(*records):
    return {"suite": "profile", "devices": 8, "backend": "cpu",
            "results": list(records)}


def test_check_profile_passes_clean_self_diff():
    from benchmarks.check_regression import check_profile
    doc = _doc(_attr("average"), _attr("delta"))
    gates = []
    ok, msgs = check_profile(doc, doc, gates=gates)
    assert ok
    assert all(m.startswith("ok") for m in msgs)
    assert {g["name"] for g in gates} == {
        "profile attribution consistency (worst)",
        "profile compute efficiency (min)"}


def test_check_profile_fails_consistency_and_prints_deltas():
    from benchmarks.check_regression import check_profile
    base = _doc(_attr("delta"))
    fresh = _doc(_attr("delta", consistency=0.4))
    ok, msgs = check_profile(base, fresh)
    assert not ok
    assert any("FAIL" in m and "consistency" not in m and "0.4" in m
               for m in msgs)
    # the failure is attributed: per-term deltas appear
    assert any(m.startswith("attribution [delta]") for m in msgs)


def test_check_profile_fails_on_byte_drift_and_commlog_mismatch():
    from benchmarks.check_regression import check_profile
    base = _doc(_attr("delta", coll=520.0))
    fresh = _doc(_attr("delta", coll=520.0))
    fresh["results"][0]["attribution"]["collective_bytes_per_window"] = 640.0
    ok, msgs = check_profile(base, fresh)
    assert not ok
    assert any("drifted 520" in m for m in msgs)
    assert any("CommLog" in m and "FAIL" in m for m in msgs)


def test_check_profile_fails_below_efficiency_floor():
    from benchmarks.check_regression import check_profile
    base = _doc(_attr("delta"))
    fresh = _doc(_attr("delta", eff=0.0))
    ok, msgs = check_profile(base, fresh)
    assert not ok
    assert any("efficiency" in m and "FAIL" in m for m in msgs)


def test_check_profile_config_mismatch_raises():
    from benchmarks.check_regression import check_profile
    base = _doc(_attr("delta"))
    fresh = _doc(_attr("delta"))
    fresh["results"][0]["tau"] = 10
    with pytest.raises(ValueError, match="config"):
        check_profile(base, fresh)


def test_check_profile_missing_scheme_raises():
    from benchmarks.check_regression import check_profile
    base = _doc(_attr("delta"), _attr("average"))
    fresh = _doc(_attr("delta"))
    with pytest.raises(ValueError, match="missing"):
        check_profile(base, fresh)


def test_gate_table_renders_values_and_status():
    from benchmarks.check_regression import gate_table
    gates = [{"name": "a", "value": 1.1, "bar": 1.25, "cmp": "<="},
             {"name": "b", "value": 2.0, "bar": 4.0, "cmp": ">="}]
    table = gate_table(gates)
    assert "a" in table and "1.25" in table
    assert "FAIL" in table and "ok" in table


def test_check_profile_cli_exit_codes(tmp_path, capsys):
    from benchmarks.check_regression import main as gate_main
    good = tmp_path / "base.json"
    good.write_text(json.dumps(_doc(_attr("delta"))))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_doc(_attr("delta", consistency=0.9))))
    assert gate_main(["--baseline", str(good), "--fresh", str(good)]) == 0
    out = capsys.readouterr().out
    assert "gate" in out and "PASS" in out
    assert gate_main(["--baseline", str(good), "--fresh", str(bad)]) == 1
    assert gate_main(["--baseline", str(good),
                      "--fresh", str(tmp_path / "nope.json")]) == 3


# ---------------------------------------------------------------------------
# the perf-trajectory report (obs/report.py)
# ---------------------------------------------------------------------------

def test_report_renders_self_contained_html(tmp_path):
    from repro.obs import report
    (tmp_path / "BENCH_profile.json").write_text(
        json.dumps(_doc(_attr("delta"), _attr("average"))))
    (tmp_path / "BENCH_engine.json").write_text(json.dumps({
        "suite": "engine", "devices": 8, "backend": "cpu",
        "results": [{"executor": "mesh", "m": 8, "wall_s": 1.25,
                     "curve": [0.5, 0.4, 0.3]}]}))
    (tmp_path / "BENCH_engine.fresh.json").write_text("{ not json")
    out = tmp_path / "perf_report.html"
    rc = report.main(["--dir", str(tmp_path), "--out", str(out)])
    assert rc == 0
    text = out.read_text()
    # self-contained: no external fetches of any kind
    for needle in ("http://", "https://", "<script", "<link", "@import"):
        assert needle not in text, needle
    # both suites render, attribution shows its stacked bars + sparkline
    assert "Roofline attribution" in text
    assert "engine" in text and "delta" in text
    assert "<svg" in text and "polyline" in text


def test_report_includes_profiler_exports(tmp_path):
    from repro.obs import report
    prof_doc = {"attributions": [_attr("delta")["attribution"]],
                "programs": {}}
    p = tmp_path / "prof.json"
    p.write_text(json.dumps(prof_doc))
    out = tmp_path / "r.html"
    rc = report.main(["--dir", str(tmp_path), "--out", str(out),
                      "--profile", str(p)])
    assert rc == 0
    text = out.read_text()
    assert "prof.json" in text and "Roofline attribution" in text


def test_report_empty_dir_still_writes(tmp_path):
    from repro.obs import report
    out = tmp_path / "r.html"
    assert report.main(["--dir", str(tmp_path), "--out", str(out)]) == 0
    assert "<html" in out.read_text()
