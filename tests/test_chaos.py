"""Chaos engineering (ISSUE 7): seeded fault injection, the
straggler-tolerant quorum merge, chaos-kill elasticity, preemption-safe
checkpointing, and the chaos CI regression gate.

The determinism pin: everything seeded here draws through host-side
numpy Philox, so the SAME schedule/late-matrix must come out on the
1-device and the 8-device CI legs — several tests below assert against
hard-coded event lists for exactly that reason.  Multi-device tests
carry ``@pytest.mark.devices(n)`` and skip themselves on the small leg.
"""

import json
import pathlib
import sys

from repro.xla_flags import force_host_devices

force_host_devices(8)

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.checkpoint.checkpointing import Checkpointer  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.engine import (ChaosEvent, ChaosNetwork,  # noqa: E402
                          ChaosSchedule, ElasticMeshExecutor,
                          InstantNetwork, MeshExecutor)
from repro.engine.network import GeometricDelayNetwork  # noqa: E402
from repro.launch import train as train_cli  # noqa: E402
from repro.obs.check import check_trace  # noqa: E402
from repro.serve.codebook_store import CodebookStore  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks import check_regression  # noqa: E402

KEY = jax.random.PRNGKey(42)
TAU = 10


def _setup(m, n=400, d=8, kappa=16):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    return data, eval_data, w0


# ---------------------------------------------------------------------------
# ChaosEvent / ChaosSchedule
# ---------------------------------------------------------------------------

def test_chaos_event_validation():
    with pytest.raises(ValueError, match="unknown chaos kind"):
        ChaosEvent(5, "meteor", 0)
    with pytest.raises(ValueError, match="window must be >= 1"):
        ChaosEvent(0, "kill", 0)
    with pytest.raises(ValueError, match="target must be >= 0"):
        ChaosEvent(5, "kill", -1)
    with pytest.raises(ValueError, match="duration must be >= 1"):
        ChaosEvent(5, "slow", 0, duration=0)


def test_chaos_schedule_generate_is_seed_deterministic():
    """Same seed => identical events, on EVERY device count: the schedule
    is drawn by host-side numpy Philox, never a jax key.  The hard-coded
    expectation is the committed BENCH_chaos.json config (seed 7), so the
    1- and 8-device CI legs both pin the exact same draw."""
    kw = dict(windows=40, m=8, kills=2, slows=1, partitions=1, hosts=2)
    a = ChaosSchedule.generate(7, **kw)
    b = ChaosSchedule.generate(7, **kw)
    assert [e.as_dict() for e in a] == [e.as_dict() for e in b]
    assert [e.as_dict() for e in a] == [
        {"window": 10, "kind": "slow", "target": 1, "duration": 3},
        {"window": 19, "kind": "partition", "target": 1, "duration": 2},
        {"window": 21, "kind": "kill", "target": 3, "duration": 1},
        {"window": 27, "kind": "kill", "target": 5, "duration": 1},
    ]
    assert a.describe() == ("seed=7: slow@10:1,partition@19:1,"
                            "kill@21:3,kill@27:5")
    # a different seed draws a different schedule
    c = ChaosSchedule.generate(8, **kw)
    assert [e.as_dict() for e in c] != [e.as_dict() for e in a]
    # faults land in the middle half with recovery room on both sides
    assert all(10 <= e.window < 30 for e in a)
    # kill targets are distinct workers
    kills = [e.target for e in a.kill_events]
    assert len(set(kills)) == len(kills) == 2


def test_chaos_schedule_generate_validation():
    with pytest.raises(ValueError, match="at least one must survive"):
        ChaosSchedule.generate(0, windows=40, m=2, kills=2)
    with pytest.raises(ValueError, match=">= 8 windows"):
        ChaosSchedule.generate(0, windows=4, m=8, kills=1)
    with pytest.raises(ValueError, match="do not fit"):
        # the fault span of an 8-window run is [2, 6) — 4 slots < 5 events
        ChaosSchedule.generate(0, windows=8, m=8, kills=2, slows=2,
                               partitions=1)
    with pytest.raises(ValueError, match="only die once"):
        ChaosSchedule([(5, "kill", 1), (7, "kill", 1)])
    assert len(ChaosSchedule.generate(0, windows=40, m=8)) == 0


def test_chaos_schedule_from_spec():
    s = ChaosSchedule.from_spec("7:kill=2,slow=1,part=1",
                                windows=40, m=8, hosts=2)
    g = ChaosSchedule.generate(7, windows=40, m=8, kills=2, slows=1,
                               partitions=1, hosts=2)
    assert [e.as_dict() for e in s] == [e.as_dict() for e in g]
    assert len(ChaosSchedule.from_spec("3:kill=1", windows=40, m=8)) == 1

    for bad in ("banana", ":kill=1", "7:boom=1", "7:kill=x"):
        with pytest.raises(ValueError, match="bad chaos"):
            ChaosSchedule.from_spec(bad, windows=40, m=8)


def test_chaos_schedule_late_matrix_semantics():
    s = ChaosSchedule([(3, "kill", 0), (2, "slow", 1, 2),
                       (4, "partition", 1, 2)], hosts=2)
    late = s.late_matrix(8, 8)
    # kill: target row late from its death window onward
    np.testing.assert_array_equal(late[0], [0, 0, 0, 1, 1, 1, 1, 1])
    # slow: target row late for `duration` windows
    np.testing.assert_array_equal(late[1], [0, 0, 1, 1, 0, 0, 0, 0])
    # partition: EVERY worker of host group 1 (workers 4..7) late at once
    for w in range(4, 8):
        np.testing.assert_array_equal(late[w], [0, 0, 0, 0, 1, 1, 0, 0])
    np.testing.assert_array_equal(late[2], np.zeros(8))
    # window0 offsets into the same global pattern (elastic segments)
    np.testing.assert_array_equal(s.late_matrix(8, 5, window0=3),
                                  late[:, 3:])
    # targets beyond the live worker count are ignored, not an error
    assert s.late_matrix(1, 8)[0].sum() == 5  # only the kill row survives


# ---------------------------------------------------------------------------
# GeometricDelayNetwork straggler tail
# ---------------------------------------------------------------------------

def test_geometric_late_matrix_deterministic_and_segment_aligned():
    g = GeometricDelayNetwork(0.3)
    a = g.late_matrix(8, 20, 2)
    b = g.late_matrix(8, 20, 2)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (8, 20) and a.dtype == np.float32
    # one Philox stream per GLOBAL window: a segment starting at window0=8
    # redraws exactly the columns the full run drew for windows 8..19, so
    # elastic segment boundaries cannot move the straggler pattern
    np.testing.assert_array_equal(g.late_matrix(8, 12, 2, window0=8),
                                  a[:, 8:])


def test_geometric_late_matrix_tail_quantile():
    """A worker is late when its geometric extra delay exceeds a window of
    slack: P(late) = (1-p)^(tau+1).  4096 draws pin the empirical rate."""
    p, tau = 0.3, 2
    frac = float(GeometricDelayNetwork(p).late_matrix(64, 64, tau).mean())
    theory = (1 - p) ** (tau + 1)
    assert abs(frac - theory) < 0.05
    # more slack => strictly rarer stragglers
    frac_slack = float(GeometricDelayNetwork(p).late_matrix(64, 64, 8).mean())
    assert frac_slack < frac


def test_base_network_late_matrix_is_zero():
    np.testing.assert_array_equal(InstantNetwork().late_matrix(4, 6, TAU),
                                  np.zeros((4, 6), np.float32))


# ---------------------------------------------------------------------------
# ChaosNetwork composition
# ---------------------------------------------------------------------------

def test_chaos_network_round_lengths_overlay():
    sched = ChaosSchedule([(5, "kill", 0), (3, "slow", 1, 2)], hosts=2)
    cn = ChaosNetwork(InstantNetwork(), sched, slow_factor=4)
    lengths = np.asarray(cn.round_lengths(jax.random.PRNGKey(0), 4, 10, TAU))
    # dead worker's post-death rounds never complete
    np.testing.assert_array_equal(lengths[0, 5:],
                                  np.full(5, ChaosNetwork.DEAD_TICKS))
    np.testing.assert_array_equal(lengths[0, :5], np.full(5, TAU))
    # slowed worker straggles by slow_factor for the fault's duration
    np.testing.assert_array_equal(lengths[1],
                                  [10, 10, 10, 40, 40, 10, 10, 10, 10, 10])
    # healthy workers see the inner model untouched
    np.testing.assert_array_equal(lengths[2], np.full(10, TAU))


def test_chaos_network_late_matrix_is_union_of_inner_and_schedule():
    sched = ChaosSchedule([(2, "slow", 0, 3)], hosts=2)
    inner = GeometricDelayNetwork(0.3)
    cn = ChaosNetwork(inner, sched)
    got = cn.late_matrix(8, 10, 2)
    np.testing.assert_array_equal(
        got, np.maximum(inner.late_matrix(8, 10, 2),
                        sched.late_matrix(8, 10)))
    # tick pricing passes through: a fault changes WHO arrives, not what
    # the healthy wire costs
    assert cn.window_ticks(TAU) == inner.window_ticks(TAU)


def test_chaos_network_validation():
    sched = ChaosSchedule([])
    with pytest.raises(ValueError, match="slow_factor"):
        ChaosNetwork(InstantNetwork(), sched, slow_factor=0)


# ---------------------------------------------------------------------------
# QuorumMerge through the mesh executor
# ---------------------------------------------------------------------------

@pytest.mark.devices(4)
def test_quorum_merge_without_lateness_is_exactly_delta():
    """With nobody late every delta lands, the quorum is met every window,
    and the carry stays zero: quorum must reduce to the plain eq.-8 delta
    merge BIT-EXACTLY (the default-path protection)."""
    data, eval_data, w0 = _setup(4)
    r_d = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, eval_data, tau=TAU)
    r_q = MeshExecutor(network=InstantNetwork(), merge="quorum").run(
        "delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(r_d.w_shared),
                                  np.asarray(r_q.w_shared))
    np.testing.assert_array_equal(np.asarray(r_d.distortion),
                                  np.asarray(r_q.distortion))


def test_quorum_merge_validation():
    with pytest.raises(ValueError, match="merge"):
        MeshExecutor(merge="bogus")
    with pytest.raises(ValueError, match="quorum_frac"):
        MeshExecutor(merge="quorum", quorum_frac=0.0)


@pytest.mark.devices(4)
def test_quorum_merge_rejects_non_delta_scheme():
    data, eval_data, w0 = _setup(4, n=200)
    ex = MeshExecutor(network=InstantNetwork(), merge="quorum")
    with pytest.raises(ValueError, match="delta"):
        ex.run("average", w0, data, eval_data, tau=TAU)


@pytest.mark.devices(4)
def test_quorum_merge_survives_injected_stragglers():
    """Slow + partition faults on a static mesh: late deltas fold in via
    the stale-window rule instead of stalling the barrier, and the run
    still converges."""
    sched = ChaosSchedule.generate(11, windows=40, m=4, slows=1,
                                   partitions=1, hosts=2)
    data, eval_data, w0 = _setup(4)
    ex = MeshExecutor(network=ChaosNetwork(InstantNetwork(), sched),
                      merge="quorum")
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    assert float(res.distortion[-1]) < float(res.distortion[0])


# ---------------------------------------------------------------------------
# chaos kills through the elastic executor
# ---------------------------------------------------------------------------

@pytest.mark.devices(4)
def test_elastic_chaos_kill_is_unscheduled_resize():
    """Injected deaths become unscheduled shrink-by-one resizes at the
    next window barrier, tagged cause='chaos_kill'."""
    sched = ChaosSchedule([(10, "kill", 1), (15, "kill", 2)], hosts=2)
    data, eval_data, w0 = _setup(4)
    ex = ElasticMeshExecutor([], network=ChaosNetwork(InstantNetwork(),
                                                      sched),
                             chaos=sched, merge="quorum")
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    assert [(e.window, e.old_m, e.new_m, e.cause)
            for e in ex.resize_events] == [(10, 4, 3, "chaos_kill"),
                                           (15, 3, 2, "chaos_kill")]
    assert float(res.distortion[-1]) < float(res.distortion[0])


@pytest.mark.devices(4)
def test_elastic_chaos_composes_with_scheduled_resizes():
    sched = ChaosSchedule([(20, "kill", 0)], hosts=2)
    data, eval_data, w0 = _setup(4)
    ex = ElasticMeshExecutor([(10, 2)], network=ChaosNetwork(
        InstantNetwork(), sched), chaos=sched, merge="quorum")
    ex.run("delta", w0, data, eval_data, tau=TAU)
    assert [(e.window, e.cause) for e in ex.resize_events] == [
        (10, "schedule"), (20, "chaos_kill")]


@pytest.mark.devices(4)
def test_elastic_periodic_checkpoint_and_resume(tmp_path):
    """checkpoint_every saves full state between resizes, so a preempted
    run resumes mid-stream bit-identically — the serve-while-train
    preemption-safety contract."""
    data, eval_data, w0 = _setup(4)
    ck = Checkpointer(str(tmp_path))
    ex1 = ElasticMeshExecutor([], network=InstantNetwork(),
                              checkpointer=ck, checkpoint_every=5)
    r1 = ex1.run("delta", w0, data, eval_data, tau=TAU)
    ck.wait()
    last = ck.latest_step()
    assert last > 0 and last % 5 == 0

    ex2 = ElasticMeshExecutor([], network=InstantNetwork(),
                              checkpointer=ck, checkpoint_every=5,
                              resume=True)
    r2 = ex2.run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(r1.w_shared),
                                  np.asarray(r2.w_shared))
    # the resumed run replays only the windows after the last checkpoint
    assert len(r2.distortion) < len(r1.distortion)
    np.testing.assert_array_equal(
        np.asarray(r1.distortion[-len(r2.distortion):]),
        np.asarray(r2.distortion))


def test_elastic_checkpoint_every_validation():
    with pytest.raises(ValueError, match="checkpoint_every"):
        ElasticMeshExecutor([], checkpoint_every=0,
                            checkpointer=object())
    with pytest.raises(ValueError, match="checkpointer"):
        ElasticMeshExecutor([], checkpoint_every=5)


# ---------------------------------------------------------------------------
# preemption-safe serving (stale publishes on resume)
# ---------------------------------------------------------------------------

def test_publisher_skip_stale_drops_replayed_windows():
    store = CodebookStore()
    w = np.zeros((4, 2), np.float32)
    pub = store.publisher(skip_stale=True)
    pub(5, w)
    assert (store.version, store.latest().step) == (1, 5)
    # a resumed trainer replaying the checkpointed prefix must NOT march
    # the served codebook backward
    pub(3, w)
    pub(5, w)
    assert store.version == 1
    pub(6, w)
    assert (store.version, store.latest().step) == (2, 6)
    # default publisher keeps the old always-publish behaviour
    store.publisher()(3, w)
    assert store.version == 3


# ---------------------------------------------------------------------------
# obs: chaos spans in the trace checker
# ---------------------------------------------------------------------------

def _trace_meta():
    return [
        {"ph": "M", "name": "process_name", "pid": 1, "args": {"name": "p"}},
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
         "args": {"name": "t"}},
    ]


def test_check_trace_expect_spans():
    span = {"ph": "X", "name": "chaos_kill", "pid": 1, "tid": 1,
            "ts": 0.0, "dur": 5.0, "args": {"window": 3}}
    assert check_trace(_trace_meta() + [span],
                       expect_spans=["chaos_kill"]) == []
    errors = check_trace(_trace_meta() + [span],
                         expect_spans=["chaos_slow"])
    assert len(errors) == 1 and "chaos_slow" in errors[0]


# ---------------------------------------------------------------------------
# chaos regression gate (the CI satellite)
# ---------------------------------------------------------------------------

def _chaos_doc(ratio=1.05, final_c=0.05, wire=1000, events=None, **over):
    rec = {
        "kind": "chaos", "seed": 7, "m": 8, "n": 400, "d": 8, "kappa": 16,
        "tau": 10, "hosts": 2, "quorum_frac": 0.6,
        "events": events if events is not None else [
            {"window": 10, "kind": "kill", "target": 3, "duration": 1}],
        "final_C": final_c, "final_C_oracle": final_c / ratio,
        "distortion_ratio": ratio, "merge_wire_bytes": wire,
        "merge_logical_bytes": wire, "wall_s": 0.1, "recovery_wall_s": 0.0,
        "resizes": [], "trace_ok": True, "trace_errors": [],
    }
    rec.update(over)
    return {"suite": "chaos", "results": [rec]}


def test_chaos_gate_passes_on_identical_runs():
    ok, msgs = check_regression.check_chaos(_chaos_doc(), _chaos_doc())
    assert ok, msgs


def test_chaos_gate_fails_on_distortion_above_bound():
    ok, msgs = check_regression.check_chaos(_chaos_doc(),
                                            _chaos_doc(ratio=1.30))
    assert not ok and any("distortion ratio" in m and m.startswith("FAIL")
                          for m in msgs)


def test_chaos_gate_fails_on_schedule_drift():
    drifted = _chaos_doc(events=[
        {"window": 11, "kind": "kill", "target": 3, "duration": 1}])
    ok, msgs = check_regression.check_chaos(_chaos_doc(), drifted)
    assert not ok and any("schedule drifted" in m for m in msgs)


def test_chaos_gate_fails_on_wire_byte_drift():
    ok, msgs = check_regression.check_chaos(_chaos_doc(),
                                            _chaos_doc(wire=1001))
    assert not ok and any("wire bytes drifted" in m for m in msgs)


def test_chaos_gate_fails_on_trace_violation():
    bad = _chaos_doc(trace_ok=False, trace_errors=["span unclosed"])
    ok, msgs = check_regression.check_chaos(_chaos_doc(), bad)
    assert not ok and any("trace violated" in m for m in msgs)


def test_chaos_gate_rejects_config_mismatch():
    with pytest.raises(ValueError, match="config mismatch"):
        check_regression.check_chaos(_chaos_doc(), _chaos_doc(seed=8))


def test_chaos_gate_absolute_mode_needs_no_baseline():
    ok, msgs = check_regression.check_chaos(None, _chaos_doc())
    assert ok
    # absolute mode still enforces the distortion bound + trace invariants
    ok, _ = check_regression.check_chaos(None, _chaos_doc(ratio=1.5))
    assert not ok


def test_chaos_gate_cli_exit_codes(tmp_path):
    """0 = pass, 1 = regression, 2 = config mismatch, 3 = missing file —
    the satellite bugfix: a missing baseline is a SETUP failure, not a
    regression and not a pass."""
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_chaos_doc()))
    fresh.write_text(json.dumps(_chaos_doc()))
    argv = ["--baseline", str(base), "--fresh", str(fresh)]
    assert check_regression.main(argv) == 0

    fresh.write_text(json.dumps(_chaos_doc(ratio=1.5)))
    assert check_regression.main(argv) == 1

    fresh.write_text(json.dumps(_chaos_doc(seed=9)))
    assert check_regression.main(argv) == 2

    assert check_regression.main(
        ["--baseline", str(tmp_path / "MISSING.json"),
         "--fresh", str(fresh)]) == 3
    base.write_text("{truncated")
    assert check_regression.main(argv) == 3

    # --absolute gates the fresh file alone (the cron seed sweep)
    fresh.write_text(json.dumps(_chaos_doc()))
    assert check_regression.main(["--absolute", "--fresh", str(fresh)]) == 0
    fresh.write_text(json.dumps(_chaos_doc(ratio=1.5)))
    assert check_regression.main(["--absolute", "--fresh", str(fresh)]) == 1
    fresh.write_text(json.dumps({"suite": "engine", "results": []}))
    assert check_regression.main(["--absolute", "--fresh", str(fresh)]) == 2


# ---------------------------------------------------------------------------
# launch CLI
# ---------------------------------------------------------------------------

@pytest.mark.devices(4)
def test_train_cli_chaos_run(tmp_path, capsys):
    rc = train_cli.main([
        "--mode", "vq", "--executor", "mesh", "--scheme", "delta",
        "--workers", "4", "--points", "300",
        "--chaos", "3:kill=1,slow=1", "--ckpt-dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "chaos: seed=3" in out
    assert "executor=elastic" in out  # kills imply elastic recovery


def test_train_cli_chaos_rejects_bad_spec(capsys):
    rc = train_cli.main(["--mode", "vq", "--executor", "mesh",
                         "--chaos", "banana"])
    assert rc == 2
    assert "bad chaos spec" in capsys.readouterr().out


def test_train_cli_chaos_requires_delta_scheme(capsys):
    rc = train_cli.main(["--mode", "vq", "--executor", "mesh",
                         "--scheme", "average", "--chaos", "3:kill=1"])
    assert rc == 2
    assert "delta" in capsys.readouterr().out
