"""Tests for the real-thread async runtime (paper Section 4's architecture)."""

import jax
import numpy as np

from repro.core import async_runtime
from repro.data import synthetic

KEY = jax.random.PRNGKey(11)


def _setup(m=4, n=1500, d=6, kappa=12):
    data = np.asarray(synthetic.replicate_stream(KEY, m, n=n, d=d))
    w0 = np.asarray(synthetic.kmeanspp_init(
        jax.random.fold_in(KEY, 1),
        jax.numpy.asarray(data.reshape(-1, d)), kappa))
    return data, w0


def test_async_runtime_converges():
    data, w0 = _setup()
    w, stats, trace = async_runtime.run_async_vq(
        data, w0, tau=10, duration_s=1.5)
    assert trace[-1][1] < trace[0][1]          # distortion decreased
    assert all(s.pushes > 0 for s in stats)    # every worker participated
    assert sum(s.points for s in stats) > 100


def test_async_runtime_tolerates_straggler():
    """One 50x-slow worker must not stop global progress (the paper's
    'strong straggler issues' motivation for removing the barrier)."""
    data, w0 = _setup()
    w, stats, trace = async_runtime.run_async_vq(
        data, w0, tau=10, duration_s=1.5, straggler={0: 50.0})
    assert trace[-1][1] < trace[0][1]
    fast = [s.points for i, s in enumerate(stats) if i != 0]
    assert max(fast) > stats[0].points          # others ran ahead
    assert min(fast) > 0


def test_async_runtime_with_comm_delays():
    data, w0 = _setup()
    w, stats, trace = async_runtime.run_async_vq(
        data, w0, tau=10, duration_s=1.5, comm_delay_s=0.01)
    assert trace[-1][1] < trace[0][1]


def test_blob_store_versioning():
    store = async_runtime.BlobStore(np.zeros((2, 2), np.float32))
    v0, _ = store.get()
    v1 = store.put(np.ones((2, 2), np.float32))
    assert v1 == v0 + 1
    v, val = store.get()
    assert v == v1 and float(val[0, 0]) == 1.0


def test_blob_store_apply_is_atomic_under_contention():
    """The reducer's merge is a read-modify-write: a bare get()->put() pair
    drops concurrent updates.  ``apply`` must lose NONE of them."""
    import threading

    store = async_runtime.BlobStore(np.zeros((4,), np.float32))
    writers, per_writer = 8, 200

    def hammer():
        for _ in range(per_writer):
            store.apply(lambda w: w + 1.0)

    threads = [threading.Thread(target=hammer) for _ in range(writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    version, value = store.get()
    assert version == writers * per_writer
    np.testing.assert_array_equal(
        value, np.full((4,), writers * per_writer, np.float32))
