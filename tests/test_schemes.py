"""Paper-claim tests: the three parallelization schemes (Sections 2-4).

These validate the REPRODUCTION itself:
  * eq. (3) averaging brings no speed-up over sequential (Fig. 1);
  * eq. (8) delta-merge converges faster in wall time (Fig. 2);
  * eq. (9) async with geometric delays stays close to eq. (8) (Fig. 3);
  * algebraic identities: M=1 delta == sequential; one window of eq. (8)
    telescopes to eq. (5).
"""

import jax
import numpy as np

from repro.core import async_vq, schemes, vq
from repro.data import synthetic

KEY = jax.random.PRNGKey(42)


def _setup(m=10, n=3000, d=8, kappa=16):
    kd, kw = jax.random.split(KEY, 2)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    # eq. (2) evaluates the distortion over the dataset itself
    eval_data = data[:, :500]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    return data, eval_data, w0


def _value_at(res, tick):
    i = int(np.searchsorted(np.asarray(res.wall_ticks), tick))
    i = min(i, len(res.distortion) - 1)
    return float(res.distortion[i])


def test_delta_with_one_worker_equals_sequential():
    data, eval_data, w0 = _setup(m=1, n=400)
    seq = schemes.scheme_sequential(w0, data[0], eval_data, tau=10)
    dlt = schemes.scheme_delta(w0, data, eval_data, tau=10)
    np.testing.assert_allclose(np.asarray(seq.w_shared),
                               np.asarray(dlt.w_shared), rtol=1e-5, atol=1e-6)


def test_delta_window_telescopes_to_sequential_vq():
    """One eq.-(8) window with M=1 is exactly tau steps of eq. (1)."""
    data, _, w0 = _setup(m=1, n=64)
    final = vq.vq_run(w0, data[0, :10])
    res = schemes.scheme_delta(w0, data[:, :10], data, tau=10)
    np.testing.assert_allclose(np.asarray(res.w_shared),
                               np.asarray(final.w), rtol=1e-5, atol=1e-6)


def test_averaging_no_speedup_delta_speedup():
    """The paper's central claim, as an inequality at a fixed wall tick."""
    data, eval_data, w0 = _setup(m=10, n=3000)
    tick = 1500
    seq = schemes.scheme_sequential(w0, data[0], eval_data, tau=10)
    avg = schemes.scheme_average(w0, data, eval_data, tau=10)
    dlt = schemes.scheme_delta(w0, data, eval_data, tau=10)
    c_seq, c_avg, c_dlt = (_value_at(r, tick) for r in (seq, avg, dlt))
    # averaging buys little: within 15% of sequential (paper: "no speed-ups")
    assert c_avg > 0.85 * c_seq
    # delta-merge is a clear win (paper Fig. 2 shows ~M-fold acceleration)
    assert c_dlt < 0.7 * c_seq
    assert c_dlt < 0.7 * c_avg


def test_async_close_to_delta():
    data, eval_data, w0 = _setup(m=10, n=3000)
    dlt = schemes.scheme_delta(w0, data, eval_data, tau=10)
    asy = async_vq.scheme_async(w0, data, eval_data,
                                jax.random.fold_in(KEY, 9), tau=10,
                                p_delay=0.5)
    c_dlt = float(dlt.distortion[-1])
    c_asy = float(asy.distortion[-1])
    # "small delays and asynchronism only slightly impacts performances"
    assert c_asy < 2.0 * c_dlt
    # and it still clearly beats sequential
    seq = schemes.scheme_sequential(w0, data[0], eval_data, tau=10)
    assert c_asy < 0.7 * float(seq.distortion[-1])


def test_async_zero_delay_matches_delta_trend():
    """p_delay ~ 1 (rounds take exactly tau): async reduces to a staled
    delta-merge; distortion should land in the same ballpark."""
    data, eval_data, w0 = _setup(m=4, n=2000)
    dlt = schemes.scheme_delta(w0, data, eval_data, tau=10)
    asy = async_vq.scheme_async(w0, data, eval_data,
                                jax.random.fold_in(KEY, 10), tau=10,
                                p_delay=0.999)
    assert float(asy.distortion[-1]) < 2.5 * float(dlt.distortion[-1])


def test_more_workers_converge_faster_with_delta():
    data, eval_data, w0 = _setup(m=10, n=2000)
    tick = 1000
    r1 = schemes.scheme_delta(w0, data[:1], eval_data, tau=10)
    r10 = schemes.scheme_delta(w0, data, eval_data, tau=10)
    assert _value_at(r10, tick) < _value_at(r1, tick)


def test_large_tau_slows_consensus():
    """Paper Section 3: 'if tau is large then more autonomy has been granted
    to the concurrent executions ... that would slow down the consensus and
    the convergence.'  We verify the claim's direction (tau=25 beats
    tau=100).  Nuance found while reproducing (EXPERIMENTS.md §Paper): at
    VERY small tau the summed displacement of M near-identical workers acts
    like an Mx learning rate and overshoots — tau=2 is *worse* than tau=25
    at M=10, eps0=0.5; the paper's 'frequent is better' holds only below
    the decorrelation scale."""
    data, eval_data, w0 = _setup(m=10, n=2000)
    r25 = schemes.scheme_delta(w0, data, eval_data, tau=25)
    r100 = schemes.scheme_delta(w0, data, eval_data, tau=100)
    tick = 1000
    assert _value_at(r25, tick) < _value_at(r100, tick)
