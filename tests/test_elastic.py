"""Elastic resharding (ISSUE 2): ResizeSchedule, ElasticMeshExecutor,
plan_remesh edge cases, and the CI benchmark regression gate.

The acceptance test: an 8->4->8 mid-stream resize must end within rtol 1e-2
of the fixed-M sim oracle on the same total sample budget, without a
restart.  Multi-device tests carry ``@pytest.mark.devices(n)`` so the
1-device CI leg skips them.
"""

import pathlib
import sys

from repro.xla_flags import force_host_devices

force_host_devices(8)

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.checkpoint.checkpointing import Checkpointer  # noqa: E402
from repro.core import schemes  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.distributed import elastic as elastic_lib  # noqa: E402
from repro.engine import (ElasticMeshExecutor, InstantNetwork,  # noqa: E402
                          ResizeSchedule, get_executor)
from repro.launch import train as train_cli  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks import check_regression  # noqa: E402

KEY = jax.random.PRNGKey(42)
TAU = 10


def _setup(m, n=600, d=8, kappa=16):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    return data, eval_data, w0


# ---------------------------------------------------------------------------
# ResizeSchedule
# ---------------------------------------------------------------------------

def test_resize_schedule_parse_and_validate():
    s = ResizeSchedule.parse("20:4, 40:8")
    assert [(e.window, e.new_m) for e in s] == [(20, 4), (40, 8)]
    assert len(s) == 2
    assert len(ResizeSchedule([(5, 2)])) == 1  # tuple form

    with pytest.raises(ValueError, match="bad resize spec"):
        ResizeSchedule.parse("20-4")
    with pytest.raises(ValueError, match="empty resize spec"):
        ResizeSchedule.parse(" , ")
    with pytest.raises(ValueError, match="strictly increasing"):
        ResizeSchedule([(20, 4), (20, 8)])
    with pytest.raises(ValueError, match="strictly increasing"):
        ResizeSchedule([(40, 4), (20, 8)])
    with pytest.raises(ValueError, match="window must be >= 1"):
        ResizeSchedule([(0, 4)])
    with pytest.raises(ValueError, match="M must be >= 1"):
        ResizeSchedule([(10, 0)])


def test_elastic_factory_and_validation():
    ex = get_executor("elastic", schedule="10:2")
    assert ex.name == "elastic"
    assert [(e.window, e.new_m) for e in ex.schedule] == [(10, 2)]
    with pytest.raises(ValueError, match="schedule"):
        get_executor("elastic")
    with pytest.raises(ValueError, match="late_policy"):
        ElasticMeshExecutor([(10, 2)], late_policy="teleport")
    with pytest.raises(ValueError, match="resume=True needs a checkpointer"):
        ElasticMeshExecutor([(10, 2)], resume=True)

    data, eval_data, w0 = _setup(1, n=100)
    with pytest.raises(ValueError, match="async_delta"):
        ex.run("async_delta", w0, data, eval_data, tau=TAU)
    with pytest.raises(ValueError, match="unknown scheme"):
        ex.run("gossip", w0, data, eval_data, tau=TAU)
    with pytest.raises(ValueError, match=r"\(M, n, d\)"):
        ex.run("delta", w0, data[0], eval_data, tau=TAU)
    with pytest.raises(ValueError, match="at least one"):
        ex.run("delta", w0, data[:, :5], eval_data, tau=TAU)


# ---------------------------------------------------------------------------
# plan_remesh edge cases (satellite)
# ---------------------------------------------------------------------------

def test_plan_remesh_shrink_to_one():
    p = elastic_lib.plan_remesh(1, prev_data=8, prev_model=1)
    assert (p.data, p.model) == (1, 1) and p.tp_preserved
    # fewer survivors than the TP width AND only one device: degenerate mesh
    p = elastic_lib.plan_remesh(1, prev_data=2, prev_model=4)
    assert (p.data, p.model) == (1, 1) and not p.tp_preserved


def test_plan_remesh_non_power_of_two_survivors():
    p = elastic_lib.plan_remesh(6, prev_data=8, prev_model=1)
    assert (p.data, p.model) == (6, 1) and p.dropped_hosts == 0
    p = elastic_lib.plan_remesh(7, prev_data=4, prev_model=2)
    assert p.model == 2 and p.data == 3 and p.dropped_hosts == 1
    assert p.tp_preserved


def test_plan_remesh_tp_axis_preservation():
    # enough survivors: TP width survives, data axis shrinks
    p = elastic_lib.plan_remesh(12, prev_data=4, prev_model=4)
    assert p.model == 4 and p.data == 3 and p.tp_preserved
    # not enough: largest power-of-two TP that fits, flagged not preserved
    p = elastic_lib.plan_remesh(3, prev_data=2, prev_model=4)
    assert not p.tp_preserved and p.model == 2 and p.data == 1


@pytest.mark.devices(2)
def test_worker_mesh_from_plan():
    from repro.engine import make_worker_mesh
    plan = elastic_lib.plan_remesh(2, prev_data=4, prev_model=1)
    mesh = make_worker_mesh(plan.data * plan.model, "workers")
    assert mesh.axis_names == ("workers",) and mesh.devices.shape == (2,)
    with pytest.raises(ValueError, match="devices"):
        make_worker_mesh(4096)


# ---------------------------------------------------------------------------
# elastic execution vs the fixed-M oracle
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_elastic_without_events_is_the_mesh_oracle():
    """A schedule that never fires must reproduce scheme_delta exactly —
    the elastic pool/reshard plumbing is a no-op at fixed M."""
    data, eval_data, w0 = _setup(8)
    oracle = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
    ex = ElasticMeshExecutor([(10_000, 4)], network=InstantNetwork())
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_allclose(np.asarray(res.distortion),
                               np.asarray(oracle.distortion),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res.w_shared),
                               np.asarray(oracle.w_shared),
                               rtol=1e-4, atol=1e-6)
    assert ex.resize_events == []


@pytest.mark.devices(8)
def test_elastic_8_4_8_matches_fixed_oracle():
    """ISSUE 2 acceptance: mid-stream 8->4->8 ends within rtol 1e-2 of the
    fixed-M oracle on the same total sample budget, without a restart."""
    data, eval_data, w0 = _setup(8)
    oracle = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
    ex = ElasticMeshExecutor([(20, 4), (40, 8)], network=InstantNetwork())
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_allclose(float(res.distortion[-1]),
                               float(oracle.distortion[-1]), rtol=1e-2)
    assert [(e.old_m, e.new_m) for e in ex.resize_events] == [(8, 4), (4, 8)]
    assert ex.resize_events[0].late_points == 4 * TAU  # 4 departing workers
    # M=4 windows consume half the points, so the elastic run has MORE
    # windows than the fixed-M oracle on the same budget
    assert len(res.distortion) > len(oracle.distortion)
    # wall ticks stay strictly increasing across the resize boundaries
    ticks = np.asarray(res.wall_ticks)
    assert (np.diff(ticks) > 0).all()


@pytest.mark.devices(4)
def test_elastic_shrink_to_single_worker():
    data, eval_data, w0 = _setup(4, n=400)
    ex = ElasticMeshExecutor([(10, 1)], network=InstantNetwork())
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    assert float(res.distortion[-1]) < float(res.distortion[0])
    assert ex.resize_events[0].new_m == 1
    # after the shrink, each window consumes 1*tau of the pool
    assert len(res.distortion) == 10 + (4 * 400 - 10 * 4 * TAU
                                        - 3 * TAU) // TAU


@pytest.mark.devices(8)
def test_elastic_grow_clamps_to_available_devices():
    data, eval_data, w0 = _setup(4, n=400)
    ex = ElasticMeshExecutor([(10, 64)], network=InstantNetwork())
    res = ex.run("delta", w0, data, eval_data, tau=TAU)
    assert ex.resize_events[0].new_m == len(jax.devices())
    assert float(res.distortion[-1]) < float(res.distortion[0])


@pytest.mark.devices(4)
def test_elastic_late_delta_merge_vs_drop():
    """'merge' integrates the departing workers' stale-window deltas
    (damped eq. 8), 'drop' discards them — the prototypes must differ, and
    only 'merge' consumes the late pool points."""
    data, eval_data, w0 = _setup(4, n=400)
    ex_m = ElasticMeshExecutor([(10, 2)], network=InstantNetwork())
    ex_d = ElasticMeshExecutor([(10, 2)], network=InstantNetwork(),
                               late_policy="drop")
    r_m = ex_m.run("delta", w0, data, eval_data, tau=TAU)
    r_d = ex_d.run("delta", w0, data, eval_data, tau=TAU)
    assert ex_m.resize_events[0].late_points == 2 * TAU
    assert ex_d.resize_events[0].late_points == 0
    assert not np.allclose(np.asarray(r_m.w_shared), np.asarray(r_d.w_shared))
    # both still converge
    assert float(r_m.distortion[-1]) < float(r_m.distortion[0])
    assert float(r_d.distortion[-1]) < float(r_d.distortion[0])


@pytest.mark.devices(4)
def test_elastic_average_scheme_runs():
    data, eval_data, w0 = _setup(4, n=300)
    ex = ElasticMeshExecutor([(10, 2)], network=InstantNetwork())
    res = ex.run("average", w0, data, eval_data, tau=TAU)
    assert float(res.distortion[-1]) < float(res.distortion[0])


# ---------------------------------------------------------------------------
# checkpoint / resume (the elastic restore path)
# ---------------------------------------------------------------------------

@pytest.mark.devices(4)
def test_elastic_checkpoint_and_resume_bit_identical(tmp_path):
    """A run killed after the resize event and resumed from its checkpoint
    continues bit-identically: same final prototypes, same curve suffix."""
    data, eval_data, w0 = _setup(4, n=400)
    ck = Checkpointer(str(tmp_path))
    ex1 = ElasticMeshExecutor([(10, 2)], network=InstantNetwork(),
                              checkpointer=ck)
    r1 = ex1.run("delta", w0, data, eval_data, tau=TAU)
    ck.wait()
    assert ex1.resize_events[0].checkpoint_step == 10
    assert ck.latest_step() == 10

    ex2 = ElasticMeshExecutor([(10, 2)], network=InstantNetwork(),
                              checkpointer=ck, resume=True)
    r2 = ex2.run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(r1.w_shared),
                                  np.asarray(r2.w_shared))
    # the resumed run re-executes only the post-resize windows
    assert len(r2.distortion) < len(r1.distortion)
    np.testing.assert_array_equal(
        np.asarray(r1.distortion[-len(r2.distortion):]),
        np.asarray(r2.distortion))
    np.testing.assert_array_equal(
        np.asarray(r1.wall_ticks[-len(r2.wall_ticks):]),
        np.asarray(r2.wall_ticks))
    # the resize already happened before the checkpoint: none fire on resume
    assert ex2.resize_events == []


@pytest.mark.devices(4)
def test_elastic_resume_of_completed_run_returns_result(tmp_path):
    """A resize at the last consumable window checkpoints with the pool
    exhausted; resuming such a run must report the restored state, not
    raise 'produced no windows'."""
    data, eval_data, w0 = _setup(4, n=100)  # budget = 400 = 10 windows of 40
    ck = Checkpointer(str(tmp_path))
    ex1 = ElasticMeshExecutor([(10, 2)], network=InstantNetwork(),
                              checkpointer=ck)
    r1 = ex1.run("delta", w0, data, eval_data, tau=TAU)
    ck.wait()
    assert ck.latest_step() == 10  # checkpointed at the pool's last window

    ex2 = ElasticMeshExecutor([(10, 2)], network=InstantNetwork(),
                              checkpointer=ck, resume=True)
    r2 = ex2.run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(r1.w_shared),
                                  np.asarray(r2.w_shared))
    assert len(r2.distortion) == 1 and np.isfinite(float(r2.distortion[0]))


# ---------------------------------------------------------------------------
# launch/train.py --resize CLI (acceptance path)
# ---------------------------------------------------------------------------

@pytest.mark.devices(4)
def test_train_cli_elastic_run(tmp_path, capsys):
    rc = train_cli.main([
        "--mode", "vq", "--executor", "mesh", "--workers", "4",
        "--points", "300", "--resize", "10:2,20:4",
        "--ckpt-dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "executor=elastic" in out and "resize=10:2,20:4" in out
    assert "resize @window 10: M 4 -> 2" in out
    assert "resize @window 20: M 2 -> 4" in out
    assert "ckpt@" in out


def test_train_cli_resize_rejects_non_mesh(capsys):
    rc = train_cli.main(["--mode", "vq", "--executor", "sim",
                         "--resize", "10:2"])
    assert rc == 2
    assert "mesh-executor feature" in capsys.readouterr().out


def test_train_cli_resize_rejects_bad_spec(capsys):
    rc = train_cli.main(["--mode", "vq", "--executor", "mesh",
                         "--resize", "banana"])
    assert rc == 2
    assert "bad resize spec" in capsys.readouterr().out


def test_train_cli_vq_resume_requires_resize(capsys):
    """A plain VQ run has no checkpoint to restore — silently restarting
    would be the non-resume the elastic executor refuses."""
    rc = train_cli.main(["--mode", "vq", "--executor", "mesh", "--resume"])
    assert rc == 2
    assert "needs --resize" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# benchmark regression gate (CI satellite)
# ---------------------------------------------------------------------------

def _bench_doc(wall, curve_shift=0.0):
    results = []
    for m, w in wall.items():
        for ex, ws in (("sim", w[0]), ("mesh", w[1])):
            results.append({
                "executor": ex, "m": m, "scheme": "delta", "n": 400, "d": 8,
                "kappa": 16, "tau": 10, "wall_s": ws,
                "distortion": [0.5 - 0.01 * i + curve_shift
                               for i in range(5)]})
    return {"suite": "engine", "results": results}


def test_regression_gate_passes_on_identical_runs():
    doc = _bench_doc({1: (0.001, 0.002), 8: (0.002, 0.03)})
    ok, msgs = check_regression.check(doc, doc)
    assert ok and any("wall ratio" in m for m in msgs)


def test_regression_gate_ignores_single_leg_noise():
    base = _bench_doc({1: (0.001, 0.002), 8: (0.002, 0.03)})
    noisy = _bench_doc({1: (0.001, 0.002), 8: (0.002, 0.09)})  # one 3x blip
    ok, _ = check_regression.check(base, noisy)
    assert ok  # min-over-M: a single slow leg is noise, not a regression


def test_regression_gate_fails_on_uniform_slowdown():
    base = _bench_doc({1: (0.001, 0.002), 8: (0.002, 0.03)})
    slow = _bench_doc({1: (0.001, 0.004), 8: (0.002, 0.06)})  # all legs 2x
    ok, msgs = check_regression.check(base, slow)
    assert not ok and any("FAIL" in m and "wall ratio" in m for m in msgs)


def test_regression_gate_fails_on_curve_divergence():
    base = _bench_doc({1: (0.001, 0.002)})
    drift = _bench_doc({1: (0.001, 0.002)}, curve_shift=0.2)
    ok, msgs = check_regression.check(base, drift)
    assert not ok and any("curve diverged" in m for m in msgs)


def test_regression_gate_rejects_config_mismatch():
    base = _bench_doc({1: (0.001, 0.002)})
    other = _bench_doc({1: (0.001, 0.002)})
    for r in other["results"]:
        r["tau"] = 20
    with pytest.raises(ValueError, match="config"):
        check_regression.check(base, other)
    with pytest.raises(ValueError, match="nothing to compare"):
        check_regression.check(base, {"results": []})


def test_regression_gate_cli(tmp_path):
    import json
    base = _bench_doc({1: (0.001, 0.002), 8: (0.002, 0.03)})
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(base))
    assert check_regression.main(["--baseline", str(bp),
                                  "--fresh", str(fp)]) == 0
    # missing/truncated files are exit 3 (setup failure), distinct from
    # 1 = regression and 2 = config mismatch, so CI can route the blame
    assert check_regression.main(["--baseline", str(bp),
                                  "--fresh", str(tmp_path / "nope.json")]) == 3
    trunc = tmp_path / "trunc.json"
    trunc.write_text('{"suite": "engine", "resu')
    assert check_regression.main(["--baseline", str(bp),
                                  "--fresh", str(trunc)]) == 3
