"""Adaptive-communication tests (ISSUE 10): divergence-triggered dynamic
merges, the quantized delta wire format, and the bandwidth-adaptive sparse
tier.

The anchors: thresh=0 + quantization off must reproduce the plain fixed-tau
delta merge BITWISE; identity quantization over any transport is
bit-transparent; quantized wire bytes are exact integer arithmetic the gate
pins; and the dynamic merge's honest accounting (post-run record re-pricing
+ every-window probe) keeps dynamic total wire at or under fixed.
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro import comm  # noqa: E402
from repro.comm import (QUANT_WIDTH, QuantizedTransport,  # noqa: E402
                        SparseTransport, get_transport, quantize_leaf,
                        ring_wire_bytes)
from repro.comm.sparse import topk_count  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.engine import (InstantNetwork, MeshExecutor,  # noqa: E402
                          Tier1BudgetController, get_network)
from repro.topology import Topology  # noqa: E402

KEY = jax.random.PRNGKey(42)
TAU = 10
D, KAPPA = 8, 16
FRAC_Q = (KAPPA // 4) / (KAPPA * D)   # k/kappa = 0.25 acceptance point


def _setup(m, n=400):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=D)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, D), KAPPA)
    return data, eval_data, w0


def _run(m, transport, n=400, **ex_kw):
    data, eval_data, w0 = _setup(m, n=n)
    ex = MeshExecutor(network=InstantNetwork(), transport=transport, **ex_kw)
    res = ex.run("delta", w0, data, eval_data, tau=TAU,
                 key=jax.random.fold_in(KEY, 9))
    return res, ex


# ---------------------------------------------------------------------------
# quantize_leaf codecs
# ---------------------------------------------------------------------------

def test_quantize_leaf_identity_is_exact():
    x = jax.random.normal(KEY, (KAPPA, D))
    assert np.array_equal(np.asarray(quantize_leaf(x, "identity")),
                          np.asarray(x))


def test_quantize_leaf_bf16_error_bound():
    x = jax.random.normal(KEY, (KAPPA, D)) * 3.0
    deq = np.asarray(quantize_leaf(x, "bf16"))
    # bf16 keeps 8 significand bits: relative error <= 2^-8 per entry
    rel = np.abs(deq - np.asarray(x)) / np.maximum(np.abs(np.asarray(x)),
                                                   1e-12)
    assert rel.max() <= 2.0 ** -8


def test_quantize_leaf_int8_error_bound():
    x = jax.random.normal(KEY, (KAPPA, D)) * 5.0
    deq = np.asarray(quantize_leaf(x, "int8"))
    # symmetric max-abs scaling: |err| <= scale/2 = amax/254 per entry
    amax = float(np.abs(np.asarray(x)).max())
    assert np.abs(deq - np.asarray(x)).max() <= amax / 254 + 1e-7


def test_quantize_leaf_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown quantization mode"):
        quantize_leaf(jnp.zeros((2,)), "fp4")


def test_quant_transport_rejects_nesting_and_kwargs():
    with pytest.raises(ValueError, match="double"):
        QuantizedTransport(inner=QuantizedTransport())
    with pytest.raises(ValueError, match="string inner spec"):
        QuantizedTransport(inner=get_transport("sparse", frac=0.1),
                           frac=0.2)
    with pytest.raises(ValueError, match="unknown quantization mode"):
        get_transport("quant", mode="fp4")


# ---------------------------------------------------------------------------
# identity quantization is bit-transparent (numerics AND accounting)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
@pytest.mark.parametrize("inner", ["xla", "sparse"])
def test_identity_quant_bitwise_transparent(m, inner):
    kw = {"frac": FRAC_Q} if inner == "sparse" else {}
    ref, ex_ref = _run(m, get_transport(inner, **kw))
    out, ex_out = _run(m, get_transport("quant", inner=inner, mode="identity",
                                        **kw))
    assert np.array_equal(np.asarray(ref.distortion),
                          np.asarray(out.distortion))
    assert np.array_equal(np.asarray(ref.w_shared), np.asarray(out.w_shared))
    assert (ex_ref.last_comm["by_tag"]["merge"]["wire_bytes"]
            == ex_out.last_comm["by_tag"]["merge"]["wire_bytes"])


# ---------------------------------------------------------------------------
# quantized wire accounting: exact integer pins
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_quant_dense_wire_bytes_exact(mode):
    n, m = 400, 8
    res, ex = _run(m, get_transport("quant", inner="xla", mode=mode), n=n)
    n_windows = n // TAU
    dense = ring_wire_bytes(KAPPA * D * 4, m)        # per-window f32 ring
    per_window = dense * QUANT_WIDTH[mode] // 4
    if mode == "int8":
        per_window += 4                               # one leaf's scale
    assert (ex.last_comm["by_tag"]["merge"]["wire_bytes"]
            == per_window * n_windows)
    # eval reduces ride op='mean': unquantized, same as the dense run
    _, ex_ref = _run(m, get_transport("xla"), n=n)
    assert (ex.last_comm["by_tag"]["eval"]["wire_bytes"]
            == ex_ref.last_comm["by_tag"]["eval"]["wire_bytes"])


@pytest.mark.devices(8)
@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_quant_sparse_wire_bytes_exact(mode):
    n, m = 400, 8
    res, ex = _run(m, get_transport("quant", inner="sparse", mode=mode,
                                    frac=FRAC_Q), n=n)
    n_windows = n // TAU
    k = topk_count(KAPPA * D, FRAC_Q)
    sparse = (m - 1) * k * 8                 # (value f32, index i32) pairs
    per_window = sparse * (QUANT_WIDTH[mode] + 4) // 8
    if mode == "int8":
        per_window += 4
    assert (ex.last_comm["by_tag"]["merge"]["wire_bytes"]
            == per_window * n_windows)
    rec = next(r for r in ex.transport.log.records if r.tag == "merge")
    assert rec.transport == f"sparse+{mode}"


@pytest.mark.devices(8)
def test_quant_over_hier_preserves_tiers():
    topo = Topology.from_spec(8, hosts=2)
    hier = comm.HierarchicalTransport(
        tier0="xla", tier1="sparse", tier1_frac=FRAC_Q,
        host_axis=topo.host_axis, worker_axis=topo.worker_axis)
    data, eval_data, w0 = _setup(8)
    ex = MeshExecutor(network=InstantNetwork(), topology=topo,
                      transport=get_transport("quant", inner=hier,
                                              mode="int8"))
    res = ex.run("delta", w0, data, eval_data, tau=TAU,
                 key=jax.random.fold_in(KEY, 9))
    by_tier = ex.last_comm["by_tag"]["merge"]["by_tier"]
    assert set(by_tier) == {0, 1}
    n_windows = 400 // TAU
    # tier 0: dense ring over the 4 workers of each host, int8 width
    t0_dense = ring_wire_bytes(KAPPA * D * 4, 4)
    assert by_tier[0]["wire_bytes"] == (t0_dense // 4 + 4) * n_windows
    # tier 1: sparse gather across the 2 hosts, only values narrow
    k = topk_count(KAPPA * D, FRAC_Q)
    t1_sparse = (2 - 1) * k * 8
    assert by_tier[1]["wire_bytes"] == (t1_sparse * 5 // 8 + 4) * n_windows
    assert np.isfinite(float(res.distortion[-1]))


# ---------------------------------------------------------------------------
# error feedback: the rounding mass is delayed, not lost
# ---------------------------------------------------------------------------

def test_error_feedback_residual_telescopes():
    # across calls, sum(dequantized payloads) + final residual ==
    # sum(raw deltas): nothing is lost, only delayed
    t = QuantizedTransport(inner="xla", mode="int8")
    key = KEY
    deltas = [jax.random.normal(jax.random.fold_in(key, i), (KAPPA, D))
              for i in range(4)]
    residual = jnp.zeros((KAPPA, D), jnp.float32)
    shipped = jnp.zeros((KAPPA, D), jnp.float32)
    for d in deltas:
        deq, residual = t._encode(d, residual, None)
        shipped = shipped + deq
    total = sum(np.asarray(d) for d in deltas)
    np.testing.assert_allclose(np.asarray(shipped + residual), total,
                               rtol=0, atol=1e-5)
    # and the residual is genuinely nonzero mid-stream (int8 rounds)
    assert float(jnp.abs(residual).max()) > 0


@pytest.mark.devices(8)
def test_error_feedback_tracks_dense_distortion():
    ref, _ = _run(8, get_transport("xla"))
    out, _ = _run(8, get_transport("quant", inner="xla", mode="int8"))
    np.testing.assert_allclose(np.asarray(out.distortion),
                               np.asarray(ref.distortion), rtol=5e-3)


# ---------------------------------------------------------------------------
# dynamic merge: bitwise anchor, skipping, staleness cap, honest accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
def test_dynamic_thresh0_bitmatches_delta(m):
    ref, ex_ref = _run(m, get_transport("xla"))
    dyn, ex_dyn = _run(m, get_transport("xla"), merge="dynamic",
                       divergence_thresh=0.0)
    assert np.array_equal(np.asarray(ref.distortion),
                          np.asarray(dyn.distortion))
    assert np.array_equal(np.asarray(ref.w_shared), np.asarray(dyn.w_shared))
    # every window triggered: merge wire matches the fixed-tau run exactly
    assert (ex_dyn.last_comm["by_tag"]["merge"]["wire_bytes"]
            == ex_ref.last_comm["by_tag"]["merge"]["wire_bytes"])


@pytest.mark.devices(8)
def test_dynamic_high_thresh_skips_and_reprices():
    n, m = 400, 8
    n_windows = n // TAU
    _, ex_ref = _run(m, get_transport("xla"), n=n)
    dyn, ex = _run(m, get_transport("xla"), n=n, merge="dynamic",
                   divergence_thresh=1e-3, max_stale=8)
    merge = ex.last_comm["by_tag"]["merge"]
    probe = ex.last_comm["by_tag"]["probe"]
    n_trig = merge["calls"]
    assert 0 < n_trig < n_windows
    # honest accounting: merge wire re-priced to the triggered windows,
    # the probe paid on every window
    per_window = ring_wire_bytes(KAPPA * D * 4, m)
    assert merge["wire_bytes"] == per_window * n_trig
    assert probe["calls"] == n_windows
    assert (merge["wire_bytes"] + probe["wire_bytes"]
            < ex_ref.last_comm["by_tag"]["merge"]["wire_bytes"])
    assert np.isfinite(float(dyn.distortion[-1]))


@pytest.mark.devices(8)
def test_dynamic_max_stale_forces_syncs():
    # with an unreachable threshold, the staleness cap is the only trigger:
    # exactly every max_stale-th window syncs
    n, max_stale = 400, 4
    n_windows = n // TAU
    _, ex = _run(8, get_transport("xla"), n=n, merge="dynamic",
                 divergence_thresh=1e9, max_stale=max_stale)
    assert ex.last_comm["by_tag"]["merge"]["calls"] == n_windows // max_stale


def test_dynamic_rejects_bad_params():
    with pytest.raises(ValueError, match="divergence_thresh"):
        MeshExecutor(network=InstantNetwork(), merge="dynamic",
                     divergence_thresh=-1.0)
    with pytest.raises(ValueError, match="max_stale"):
        MeshExecutor(network=InstantNetwork(), merge="dynamic", max_stale=0)
    data, eval_data, w0 = _setup(1)
    ex = MeshExecutor(network=InstantNetwork(), merge="dynamic")
    with pytest.raises(ValueError, match="delta"):
        ex.run("average", w0, data, eval_data, tau=TAU)


@pytest.mark.devices(8)
def test_dynamic_composes_with_quant():
    dyn, ex = _run(8, get_transport("quant", inner="xla", mode="int8"),
                   merge="dynamic", divergence_thresh=1e-3)
    merge = ex.last_comm["by_tag"]["merge"]
    n_trig = merge["calls"]
    assert 0 < n_trig < 400 // TAU
    per_window = ring_wire_bytes(KAPPA * D * 4, 8) // 4 + 4
    assert merge["wire_bytes"] == per_window * n_trig
    assert np.isfinite(float(dyn.distortion[-1]))


# ---------------------------------------------------------------------------
# observability: counters, gauge, span tags
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_dynamic_obs_counters_and_span_tags():
    from repro.obs import MetricsRegistry, Tracer
    from repro.obs.check import check_trace

    n = 400
    n_windows = n // TAU
    data, eval_data, w0 = _setup(8, n=n)
    tr, mt = Tracer(), MetricsRegistry()
    ex = MeshExecutor(network=InstantNetwork(),
                      transport=get_transport("xla"), merge="dynamic",
                      divergence_thresh=1e-3, tracer=tr, metrics=mt)
    ex.run("delta", w0, data, eval_data, tau=TAU,
           key=jax.random.fold_in(KEY, 9))
    n_trig = ex.last_comm["by_tag"]["merge"]["calls"]
    assert mt.counter("divergence_trigger", scheme="delta").value == n_trig
    assert (mt.counter("merge_skipped_total", scheme="delta").value
            == n_windows - n_trig)
    # merge spans carry the per-window trigger bit; the trace passes the
    # checker with the new counter series expected
    merges = tr.spans("merge")
    assert merges and all("triggered" in s.attrs for s in merges)
    assert (sum(s.attrs["triggered"] for s in merges) == n_trig)
    errs = check_trace(tr.chrome_events(),
                       expect_counters=["divergence_trigger"])
    assert errs == []


@pytest.mark.devices(8)
def test_quant_metrics_mirror_matches_log():
    # the registry mirror must agree with the log AFTER the dynamic-merge
    # rewrite backs out trace-time counts (sign=-1 re-accounting)
    from repro.obs import MetricsRegistry
    mt = MetricsRegistry()
    t = get_transport("quant", inner="xla", mode="int8")
    t.log.attach_metrics(mt)
    _, ex = _run(8, t, merge="dynamic", divergence_thresh=1e-3)
    logged = sum(r.wire_bytes * r.calls for r in t.log.records)
    mirrored = sum(c.value for (name, _), c in mt._metrics.items()
                   if name == "comm_wire_bytes")
    assert mirrored == logged


# ---------------------------------------------------------------------------
# Tier1BudgetController: factor-2 ladder + target resolution
# ---------------------------------------------------------------------------

def test_tier1_controller_ladder():
    net = get_network("fixed", latency_ticks=1, dcn_bytes_per_tick=100)
    ctl = Tier1BudgetController(net, budget_ticks=2, min_frac=1 / 64,
                                max_frac=1.0)
    sp = SparseTransport(frac=0.25)
    # 1000 B/window -> 10 ticks > 2: halve
    assert ctl.update(sp, 1000) == pytest.approx(0.125)
    # overshoot repeatedly: clamp at min_frac
    for _ in range(10):
        ctl.update(sp, 1000)
    assert sp.frac == pytest.approx(1 / 64)
    # 50 B/window -> 1 tick <= low_water * budget: double back up
    assert ctl.update(sp, 50) == pytest.approx(1 / 32)
    # dead zone (> low_water, <= budget): hold
    assert ctl.update(sp, 150) == pytest.approx(1 / 32)
    # free wire relaxes to max_frac
    for _ in range(10):
        ctl.update(sp, 0)
    assert sp.frac == pytest.approx(1.0)


def test_tier1_controller_target_resolution():
    net = get_network("fixed", dcn_bytes_per_tick=100)
    ctl = Tier1BudgetController(net)
    # dense transports expose no frac knob: no-op
    assert ctl.update(get_transport("xla"), 1000) is None
    # quant decorator is transparent
    q = get_transport("quant", inner="sparse", mode="bf16", frac=0.5)
    assert ctl.update(q, 10_000) == pytest.approx(0.25)
    assert q.inner.frac == pytest.approx(0.25)


def test_tier1_controller_rejects_bad_params():
    net = InstantNetwork()
    with pytest.raises(ValueError, match="budget_ticks"):
        Tier1BudgetController(net, budget_ticks=0)
    with pytest.raises(ValueError, match="min_frac"):
        Tier1BudgetController(net, min_frac=0.5, max_frac=0.25)
    with pytest.raises(ValueError, match="low_water"):
        Tier1BudgetController(net, low_water=1.5)


@pytest.mark.devices(8)
def test_tier1_controller_mesh_integration():
    # a slow DCN drives the sparse tier-1 frac DOWN across published
    # chunks; the frac lands on the controller and is mirrored to the
    # gauge, and the per-frac programs recompile cleanly (cache keyed on
    # the live frac)
    from repro.obs import MetricsRegistry
    topo = Topology.from_spec(8, hosts=2)
    hier = comm.HierarchicalTransport(
        tier0="xla", tier1="sparse", tier1_frac=0.5,
        host_axis=topo.host_axis, worker_axis=topo.worker_axis)
    net = get_network("fixed", latency_ticks=1, dcn_bytes_per_tick=8)
    ctl = Tier1BudgetController(net, budget_ticks=2)
    mt = MetricsRegistry()
    data, eval_data, w0 = _setup(8)
    ex = MeshExecutor(network=net, topology=topo, transport=hier,
                      tier1_controller=ctl, publish_every=8, metrics=mt)
    res = ex.run("delta", w0, data, eval_data, tau=TAU,
                 key=jax.random.fold_in(KEY, 9))
    assert ctl.last_frac is not None and ctl.last_frac < 0.5
    assert hier.tier1.frac == ctl.last_frac
    assert mt.gauge("tier1_frac").value == ctl.last_frac
    assert np.isfinite(float(res.distortion[-1]))


# ---------------------------------------------------------------------------
# the adapt bench gate (unit-level, toy docs)
# ---------------------------------------------------------------------------

def _adapt_doc():
    cells = []
    for quant, fixed_w, dyn_w in (("dense", 21504, 16296),
                                  ("bf16", 10752, 8136),
                                  ("int8", 5472, 4224)):
        base = {"kind": "cell", "quant": quant, "m": 8, "n": 240, "d": 8,
                "kappa": 16, "tau": 10, "wall_s": 0.01, "n_windows": 24,
                "final_C": 0.0207}
        cells.append({**base, "merge": "fixed", "thresh": None,
                      "max_stale": None, "merge_wire_bytes": fixed_w,
                      "probe_wire_bytes": 0, "total_wire_bytes": fixed_w,
                      "n_triggered": 24})
        cells.append({**base, "merge": "dynamic", "thresh": 2e-5,
                      "max_stale": 8, "merge_wire_bytes": dyn_w - 168,
                      "probe_wire_bytes": 168, "total_wire_bytes": dyn_w,
                      "n_triggered": 18, "final_C": 0.0208})
    legs = [{"kind": "fixed_leg", "tau": t, "total_wire_bytes": w,
             "n_windows": 240 // t, "final_C": c}
            for t, w, c in ((5, 43008, 0.0211), (10, 21504, 0.0207),
                            (20, 10752, 0.0208))]
    summary = {"kind": "adapt_summary", "bitmatch": True, "best_tau": 10,
               "best_final_C": 0.0207, "best_wire_bytes": 21504,
               "dyn_dense_final_C": 0.0208, "dyn_dense_wire_bytes": 16296,
               "dyn_int8_final_C": 0.0208, "dyn_int8_wire_bytes": 4224,
               "dynamic_wire_ok": True}
    return {"suite": "adapt", "results": cells + legs + [summary]}


def test_check_adapt_passes_identical():
    from benchmarks.check_regression import check_adapt
    ok, _ = check_adapt(_adapt_doc(), _adapt_doc())
    assert ok


def test_check_adapt_catches_wire_drift():
    from benchmarks.check_regression import check_adapt
    fresh = _adapt_doc()
    cell = next(r for r in fresh["results"]
                if r.get("merge") == "dynamic" and r.get("quant") == "int8")
    cell["total_wire_bytes"] += 8
    ok, msgs = check_adapt(_adapt_doc(), fresh)
    assert not ok and any("drifted" in m for m in msgs)


def test_check_adapt_catches_bitmatch_and_wire_bars():
    from benchmarks.check_regression import check_adapt
    fresh = _adapt_doc()
    s = next(r for r in fresh["results"] if r["kind"] == "adapt_summary")
    s["bitmatch"] = False
    ok, msgs = check_adapt(_adapt_doc(), fresh)
    assert not ok and any("bit-match" in m for m in msgs)
    fresh = _adapt_doc()
    s = next(r for r in fresh["results"] if r["kind"] == "adapt_summary")
    s["dyn_dense_wire_bytes"] = s["best_wire_bytes"]      # not strictly under
    ok, msgs = check_adapt(_adapt_doc(), fresh)
    assert not ok and any("strictly" in m for m in msgs)


def test_check_adapt_rejects_lost_cell_and_config_drift():
    from benchmarks.check_regression import check_adapt
    fresh = _adapt_doc()
    fresh["results"] = [r for r in fresh["results"]
                        if not (r.get("merge") == "dynamic"
                                and r.get("quant") == "bf16")]
    with pytest.raises(ValueError, match="missing baseline cells"):
        check_adapt(_adapt_doc(), fresh)
    fresh = _adapt_doc()
    next(r for r in fresh["results"]
         if r.get("merge") == "dynamic")["thresh"] = 1e-3
    with pytest.raises(ValueError, match="config"):
        check_adapt(_adapt_doc(), fresh)


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_train_cli_dynamic_int8(capsys):
    from repro.launch import train
    rc = train.main([
        "--mode", "vq", "--executor", "mesh", "--workers", "8",
        "--points", "200", "--scheme", "delta", "--merge", "dynamic",
        "--divergence-thresh", "0.001", "--wire-quant", "int8"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "quant[int8:xla]" in out and "done:" in out


def test_train_cli_rejects_bad_combos(capsys):
    from repro.launch import train
    assert train.main(["--mode", "vq", "--executor", "sim",
                       "--merge", "dynamic"]) == 2
    assert train.main(["--mode", "vq", "--executor", "mesh",
                       "--merge", "dynamic", "--scheme", "average"]) == 2
    assert train.main(["--mode", "vq", "--executor", "mesh",
                       "--merge", "dynamic", "--resize", "10:4"]) == 2
    assert train.main(["--mode", "vq", "--executor", "mesh",
                       "--hosts", "2", "--tier1-frac", "bogus"]) == 2
    assert train.main(["--mode", "vq", "--executor", "mesh",
                       "--tier1-frac", "auto"]) == 2
    capsys.readouterr()
