"""Deterministic fallback for ``hypothesis`` when it isn't installed.

The baked CPU image ships without hypothesis; rather than losing the
property tests (or pip-installing into the image), this shim replays each
``@given`` test over a fixed number of seeded-RNG samples from the declared
strategies.  Coverage is a deterministic subset of what hypothesis would
explore — no shrinking, no example database — but every invariant still
runs.  Test modules import it as:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample: Callable[[np.random.Generator], object],
                 boundaries: Sequence = ()):
        self._sample = sample
        self.boundaries = list(boundaries)  # tried before random draws

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


class st:  # noqa: N801 — mimics `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            boundaries=[min_value, max_value])

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)),
                         boundaries=[min_value, max_value])

    @staticmethod
    def sampled_from(elements: Sequence) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.integers(len(elements))],
                         boundaries=elements[:1])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)),
                         boundaries=[False, True])


def settings(*, max_examples: int = _DEFAULT_EXAMPLES, **_kw):
    """Records max_examples on the (already given-wrapped) test."""

    def deco(f):
        f._max_examples = max_examples
        return f

    return deco


def given(*strategies: _Strategy):
    """Replays the test over seeded samples; boundary samples come first.

    The first examples pin every strategy to its k-th boundary value (all
    minima, then all maxima — the off-by-one habitats); remaining examples
    are random draws from a fixed seed, so failures reproduce identically
    run to run.
    """

    def deco(f):
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(12345)
            n_boundary = min(n, max(len(s.boundaries) for s in strategies))
            for k in range(n_boundary):
                f(*[s.boundaries[min(k, len(s.boundaries) - 1)]
                    if s.boundaries else s.sample(rng) for s in strategies])
            for _ in range(n - n_boundary):
                f(*[s.sample(rng) for s in strategies])

        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper

    return deco
