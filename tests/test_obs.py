"""Observability layer tests (ISSUE 6).

``repro.obs`` is host-side plumbing — tracer, metrics registry, trace
checker — so most tests are pure-Python unit tests; the integration
tests pin the two contracts the rest of the repo relies on:

* an observed mesh run produces the SAME distortion curve as a bare run
  (instrumentation must not perturb numerics), and its exported trace
  passes every ``check_trace`` invariant;
* hierarchical comm accounting stays single-counted when mirrored into
  metrics (the ``_delegate`` re-tag-exactly-once guard).
"""

from repro.xla_flags import force_host_devices

force_host_devices(8)

import concurrent.futures  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import threading  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro import comm  # noqa: E402
from repro.comm.api import CommRecord  # noqa: E402
from repro.comm.hier import HierarchicalTransport  # noqa: E402
from repro.comm.xla import XlaTransport  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.engine import InstantNetwork, MeshExecutor  # noqa: E402
from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer,  # noqa: E402
                       check_trace, format_metric, load_jsonl, load_trace)
from repro.obs import check as obs_check  # noqa: E402
from repro.serve.loadgen import run_load  # noqa: E402
from repro.topology import Topology  # noqa: E402

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_wall_spans_nest_and_time_monotonically():
    tr = Tracer()
    with tr.span("outer", kind="test"):
        with tr.span("inner"):
            time.sleep(0.002)
        assert tr.open_spans == 1
    assert tr.open_spans == 0
    outer, = tr.spans("outer")
    inner, = tr.spans("inner")
    assert outer.attrs == {"kind": "test"}
    assert inner.start_us >= outer.start_us
    assert inner.dur_us >= 2_000 * 0.5          # slept 2ms (timer slack)
    assert outer.dur_us >= inner.dur_us
    assert inner.start_us + inner.dur_us <= outer.start_us + outer.dur_us + 1


def test_modeled_spans_and_counters():
    tr = Tracer()
    tr.add_span("compute", 0.0, 10.0, track="worker 0", window=0)
    tr.add_span("merge", 10.0, -3.0, track="worker 0")   # clamped to 0
    tr.counter("distortion", 1.5, ts_us=10.0)
    assert tr.spans("merge")[0].dur_us == 0.0
    assert tr.spans("compute")[0].process == Tracer.TICK_PROCESS
    c, = tr.counters("distortion")
    assert (c.value, c.ts_us) == (1.5, 10.0)


def test_null_tracer_is_inert():
    with NULL_TRACER.span("x") as ev:
        assert ev is None
    NULL_TRACER.add_span("y", 0.0, 1.0, track="t")
    NULL_TRACER.counter("z", 1.0)
    assert NULL_TRACER.spans() == [] and NULL_TRACER.counters() == []


def test_wall_spans_use_thread_name_as_track():
    tr = Tracer()

    def work():
        with tr.span("threaded"):
            pass

    t = threading.Thread(target=work, name="worker-7")
    t.start()
    t.join()
    assert tr.spans("threaded")[0].track == "worker-7"


def test_chrome_export_roundtrip_names_every_lane(tmp_path):
    tr = Tracer()
    with tr.span("run"):
        pass
    tr.add_span("window", 0.0, 5.0, track="worker 0")
    tr.add_span("merge", 2.0, 3.0, track="merge flat", tier="flat",
                wire_bytes=64)
    tr.counter("distortion", 2.0, ts_us=5.0)
    path = tmp_path / "out.trace.json"
    tr.export_chrome(str(path))

    events = load_trace(str(path))
    assert check_trace(events, expect_merge_tiers={"flat"},
                       expect_counters=["distortion"]) == []
    # every pid/tid any X event references is named by M metadata
    phs = {e["ph"] for e in events}
    assert phs == {"M", "X", "C"}
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"


def test_unclosed_span_is_marked_and_flagged():
    tr = Tracer()
    cm = tr.span("dangling")
    cm.__enter__()                       # never exited
    events = tr.chrome_events()
    (x,) = [e for e in events if e["ph"] == "X"]
    assert x["args"]["unclosed"] is True
    errs = check_trace(events)
    assert any("never closed" in e for e in errs)


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_counter_and_gauge_semantics():
    reg = MetricsRegistry()
    reg.counter("wire", tier=0).inc(10)
    reg.counter("wire", tier=0).inc(5)          # same instrument
    reg.counter("wire", tier=1).inc(1)          # distinct by label
    assert reg.counter("wire", tier=0).value == 15
    g = reg.gauge("depth")
    for v in (3.0, 1.0, 2.0):
        g.set(v)
    snap = g.snapshot()
    assert (snap["value"], snap["min"], snap["max"], snap["n"]) == \
        (2.0, 1.0, 3.0, 3)


def test_histogram_quantiles_track_numpy_within_bucket_error():
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(0.0, 1.0, size=4000))
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in samples:
        h.observe(float(v))
    for q in (0.50, 0.99):
        approx = h.quantile(q)
        exact = float(np.quantile(samples, q))
        # geometric buckets with ratio 2**(1/8) bound relative error ~4.5%
        assert abs(approx - exact) / exact < 0.06, (q, approx, exact)
    assert h.count == len(samples)
    np.testing.assert_allclose(h.mean, samples.mean(), rtol=1e-6)


def test_histogram_edge_cases():
    h = MetricsRegistry().histogram("x")
    assert h.quantile(0.5) == 0.0                # empty
    h.observe(7.0)
    assert h.quantile(0.0) == h.quantile(1.0) == 7.0   # single sample clamps
    h2 = MetricsRegistry().histogram("y")
    h2.observe(0.0)
    h2.observe(-1.0)                             # non-positive -> zero bucket
    assert h2.quantile(0.5) == 0.0               # zero-bucket representative
    assert (h2.min, h2.max) == (-1.0, 0.0)       # range stays exact
    with pytest.raises(ValueError):
        h2.quantile(1.5)


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("m", a=1)
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("m", a=1)
    reg.gauge("m", a=2)                          # other labels are fine


def test_format_metric_and_summary_table():
    assert format_metric("wire", {}) == "wire"
    assert format_metric("wire", {"tier": 1, "tag": "merge"}) == \
        "wire{tag=merge, tier=1}"
    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    reg.gauge("g").set(0.5)
    reg.histogram("h").observe(1.0)
    table = reg.summary_table()
    for needle in ("metric", "c", "g", "h", "p50", "p99"):
        assert needle in table


def test_jsonl_sink_appends_and_roundtrips(tmp_path):
    path = tmp_path / "metrics.jsonl"
    reg = MetricsRegistry()
    reg.counter("n").inc(1)
    assert reg.dump_jsonl(str(path), run="a") == 1
    reg.counter("n").inc(1)
    assert reg.dump_jsonl(str(path), run="b") == 1
    rows = load_jsonl(str(path))
    assert [(r["run"], r["value"]) for r in rows] == [("a", 1.0), ("b", 2.0)]
    reg.dump_jsonl(str(path), append=False)      # truncate mode
    assert len(load_jsonl(str(path))) == 1


# ---------------------------------------------------------------------------
# check_trace invariants
# ---------------------------------------------------------------------------

def _meta(pid, tid=None):
    if tid is None:
        return {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": f"p{pid}"}}
    return {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": f"t{tid}"}}


def _x(name, ts, dur, pid=1, tid=1, **args):
    return {"ph": "X", "name": name, "ts": ts, "dur": dur, "pid": pid,
            "tid": tid, "args": args}


def test_check_trace_accepts_clean_nesting():
    events = [_meta(1), _meta(1, 1),
              _x("outer", 0.0, 10.0),
              _x("inner", 2.0, 3.0),
              _x("later", 6.0, 4.0)]            # shares outer's end: nested
    assert check_trace(events) == []


def test_check_trace_flags_each_violation():
    # merge without tier / with bad wire_bytes
    errs = check_trace([_meta(1), _meta(1, 1),
                        _x("merge", 0.0, 1.0, wire_bytes=8),
                        _x("merge", 2.0, 1.0, tier=0, wire_bytes=-4)])
    assert any("missing 'tier'" in e for e in errs)
    assert any("wire_bytes" in e for e in errs)
    # same-track straddle
    errs = check_trace([_meta(1), _meta(1, 1),
                        _x("a", 0.0, 5.0), _x("b", 3.0, 5.0)])
    assert any("straddles" in e for e in errs)
    # unnamed pid/tid
    errs = check_trace([_x("a", 0.0, 1.0, pid=9, tid=9)])
    assert any("no process_name" in e for e in errs)
    assert any("no thread_name" in e for e in errs)
    # begin/end pairs are banned (exporter emits complete spans only)
    errs = check_trace([{"ph": "B", "name": "a", "ts": 0, "pid": 1, "tid": 1}])
    assert any("begin/end" in e for e in errs)
    # negative duration
    errs = check_trace([_meta(1), _meta(1, 1), _x("a", 0.0, -1.0)])
    assert any("bad dur" in e for e in errs)
    # counter without a numeric timestamp
    errs = check_trace([{"ph": "C", "name": "c", "pid": 1, "tid": 0,
                         "args": {"c": 1.0}}])
    assert any("no numeric ts" in e for e in errs)


def test_check_trace_expectations():
    events = [_meta(1), _meta(1, 1),
              _x("merge", 0.0, 1.0, tier=0, wire_bytes=8),
              {"ph": "C", "name": "distortion", "ts": 1.0, "pid": 1,
               "tid": 0, "args": {"distortion": 2.0}}]
    assert check_trace(events, expect_merge_tiers={"0"},
                       expect_counters=["distortion"]) == []
    errs = check_trace(events, expect_merge_tiers={"0", "1"},
                       expect_counters=["codebook_divergence"])
    assert any("expected merge tiers ['1']" in e for e in errs)
    assert any("codebook_divergence" in e for e in errs)


def test_check_cli_exit_codes(tmp_path, capsys):
    tr = Tracer()
    tr.add_span("merge", 0.0, 1.0, track="t", tier="flat", wire_bytes=0)
    good = tmp_path / "good.json"
    tr.export_chrome(str(good))
    assert obs_check.main([str(good), "--expect-merge-tiers", "flat"]) == 0
    assert "OK" in capsys.readouterr().out

    assert obs_check.main([str(good), "--expect-merge-tiers", "0,1",
                           "--expect-counter", "distortion"]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "distortion" in out

    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert obs_check.main([str(bad)]) == 1


# ---------------------------------------------------------------------------
# engine integration: observing must not perturb numerics
# ---------------------------------------------------------------------------

def _setup(m, n=400, d=8, kappa=16):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    return data, eval_data, w0


@pytest.mark.devices(4)
@pytest.mark.parametrize("scheme", ["delta", "async_delta"])
def test_observed_mesh_run_matches_bare_and_trace_is_clean(scheme):
    m = 4
    data, eval_data, w0 = _setup(m)
    kw = {"tau": 10, "key": jax.random.fold_in(KEY, 1)}
    bare = MeshExecutor(network=InstantNetwork()).run(
        scheme, w0, data, eval_data, **kw)
    tr, reg = Tracer(), MetricsRegistry()
    obs = MeshExecutor(network=InstantNetwork(), tracer=tr,
                       metrics=reg).run(scheme, w0, data, eval_data, **kw)

    np.testing.assert_allclose(np.asarray(obs.distortion),
                               np.asarray(bare.distortion),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(obs.w_shared),
                               np.asarray(bare.w_shared),
                               rtol=1e-5, atol=1e-7)

    # async merges are masked per-tick sums; divergence-vs-consensus only
    # exists on the windowed sync timeline
    expect = (["distortion"] if scheme == "async_delta"
              else ["distortion", "codebook_divergence"])
    errs = check_trace(tr.chrome_events(), expect_merge_tiers={"flat"},
                       expect_counters=expect)
    assert errs == []
    if scheme == "async_delta":
        assert reg.counter("async_rounds_total", scheme=scheme).value > 0
    else:
        assert reg.counter("windows_total", scheme=scheme).value > 0
        assert reg.gauge("codebook_divergence", scheme=scheme).n > 0
        # per-worker modeled tracks exist for every worker
        tracks = {s.track for s in tr.spans("window")}
        assert tracks == {f"worker {w}" for w in range(m)}


@pytest.mark.devices(8)
def test_hier_metrics_mirror_is_single_counted():
    """Satellite: CommLog metrics attach at the top level only, so the
    mirrored wire-byte counters equal the log summary (no double count
    from the sub-transports' own logs)."""
    m = 8
    data, eval_data, w0 = _setup(m)
    topo = Topology.from_spec(m, hosts=2)
    reg = MetricsRegistry()
    ex = MeshExecutor(
        topology=topo,
        transport=comm.HierarchicalTransport(
            tier0="xla", tier1="xla", host_axis=topo.host_axis,
            worker_axis=topo.worker_axis),
        network=InstantNetwork(), metrics=reg)
    ex.run("delta", w0, data, eval_data, tau=10)

    merge = ex.last_comm["by_tag"]["merge"]
    by_tier = merge["by_tier"]
    assert set(by_tier) == {0, 1}
    # summary total == sum of its tiers (the accounting identity)
    assert merge["wire_bytes"] == sum(t["wire_bytes"]
                                      for t in by_tier.values())
    # and the metrics mirror saw exactly the same per-tier totals
    for tier, t in by_tier.items():
        c = reg.counter("comm_wire_bytes", tag="merge", tier=tier,
                        transport="xla")
        assert c.value == t["wire_bytes"]


# ---------------------------------------------------------------------------
# satellite: hier re-tag-exactly-once guards
# ---------------------------------------------------------------------------

class _PokingTransport(XlaTransport):
    """Test double of a sub-transport whose call logs one record."""

    def poke(self, rec_tier=None):
        self.log.append(CommRecord(
            op="sum", transport=self.name, axis="workers", participants=2,
            logical_bytes=8, wire_bytes=8, tier=rec_tier))
        return 42


def test_hier_rejects_nested_hier_tiers():
    # hier-over-sparse: the default composition (dense tier 0, sparse
    # top-k tier 1) must not itself become a tier of an outer hier
    inner = HierarchicalTransport()
    with pytest.raises(ValueError, match="tier0=.*nest"):
        HierarchicalTransport(tier0=inner, tier1="xla")
    with pytest.raises(ValueError, match="tier1=.*nest"):
        HierarchicalTransport(tier0="xla", tier1=inner)


def test_delegate_retags_exactly_once():
    sub = _PokingTransport()
    hier = HierarchicalTransport(tier0=sub, tier1="xla")
    assert hier._delegate(sub, 1, "poke") == 42
    # outer log got the tier-tagged copy; the sub's record is untouched
    assert [r.tier for r in hier.log.records] == [1]
    assert [r.tier for r in sub.log.records] == [None]
    assert hier.log.records[0].wire_bytes == 8


def test_delegate_refuses_already_tiered_records():
    sub = _PokingTransport()
    hier = HierarchicalTransport(tier0=sub, tier1="xla")
    with pytest.raises(RuntimeError, match="already carries"):
        hier._delegate(sub, 1, "poke", rec_tier=0)
    # the poisoned record was NOT copied into the outer log
    assert hier.log.records == []


# ---------------------------------------------------------------------------
# satellite: loadgen percentile semantics
# ---------------------------------------------------------------------------

class _Resp:
    def __init__(self, version):
        self.version = version


class _StubStore:
    def __init__(self, version=3):
        self.version = version


class _StubService:
    """Duck-typed service: synchronous submit with optional service time."""

    def __init__(self, service_s=0.0, fail=False, version=3):
        self.store = _StubStore(version)
        self.service_s = service_s
        self.fail = fail

    def submit(self, q):
        fut = concurrent.futures.Future()
        if self.fail:
            fut.set_exception(RuntimeError("stub refusal"))
            return fut
        if self.service_s:
            time.sleep(self.service_s)
        fut.set_result(_Resp(self.store.version))
        return fut


def test_loadgen_measures_from_scheduled_arrival():
    """Open loop: a slow service cannot hide queueing delay.  With all
    arrivals scheduled at t0 and a fixed per-request service time, the
    i-th latency grows ~linearly, so p99 >> p50 — a closed-loop
    (coordinated-omission) measurement would report them nearly equal."""
    svc = _StubService(service_s=0.002)
    rep = run_load(svc, n_requests=20, d=4, tick_s=0.0)
    assert rep.failed == 0 and rep.requests == 20
    assert rep.p99_ms > 1.5 * rep.p50_ms > 0.0
    # the last request waited behind ~all the others
    assert rep.p99_ms >= 0.5 * 20 * 2.0


def test_loadgen_all_failed_reports_zero_percentiles():
    reg = MetricsRegistry()
    rep = run_load(_StubService(fail=True), n_requests=5, d=4, metrics=reg)
    assert rep.failed == 5
    assert rep.p50_ms == rep.p99_ms == rep.mean_ms == 0.0
    assert rep.qps == 0.0
    assert reg.counter("serve_load_failed").value == 5
    assert reg.histogram("serve_latency_ms").count == 0


def test_loadgen_single_sample_percentiles_coincide():
    rep = run_load(_StubService(version=9), n_requests=1, d=4)
    assert rep.p50_ms == rep.p99_ms == rep.mean_ms
    assert rep.versions_min == rep.versions_max == 9
    assert rep.versions_monotonic and rep.n_versions == 1
    assert rep.staleness_max == 0


# ---------------------------------------------------------------------------
# satellite: span timing must use the monotonic clock
# ---------------------------------------------------------------------------

def test_no_wall_clock_timing_under_src():
    """``time.time()`` jumps with NTP adjustments; span math and latency
    measurements must use ``time.monotonic*``/``time.perf_counter``.
    (Mirrored as a ruff TID251 banned-api pin for environments with ruff.)
    """
    src = pathlib.Path(__file__).resolve().parent.parent / "src"
    offenders = [
        str(p) for p in sorted(src.rglob("*.py"))
        if "time.time(" in p.read_text()
    ]
    assert offenders == [], f"time.time() used in {offenders}"


# ---------------------------------------------------------------------------
# bounded buffers + exit flush (crash-surviving exports)
# ---------------------------------------------------------------------------

def test_tracer_buffers_drop_oldest_with_count():
    t = Tracer(max_spans=5, max_counters=3)
    for i in range(12):
        t.add_span(f"s{i}", 0.0, 1.0, track="tk")
        t.counter("c", float(i))
    spans = t.spans()
    assert len(spans) == 5 and t.dropped_spans == 7
    # drop-oldest: the survivors are the NEWEST five
    assert [e.name for e in spans] == [f"s{i}" for i in range(7, 12)]
    assert len(t.counters()) == 3 and t.dropped_counters == 9
    assert [c.value for c in t.counters()] == [9.0, 10.0, 11.0]
    # wall spans ride the same bound
    with t.span("w"):
        pass
    assert len(t.spans()) == 5 and t.dropped_spans == 8
    assert t.spans()[-1].name == "w"


def test_tracer_bounds_validate():
    with pytest.raises(ValueError):
        Tracer(max_spans=0)
    with pytest.raises(ValueError):
        Tracer(max_counters=0)


def test_exit_flush_requires_a_sink():
    from repro.obs import ExitFlush
    with pytest.raises(ValueError):
        ExitFlush()


def test_exit_flush_writes_once_and_is_idempotent(tmp_path):
    from repro.obs import ExitFlush
    t = Tracer()
    t.add_span("a", 0.0, 1.0, track="tk")
    reg = MetricsRegistry()
    reg.counter("x").inc(3)
    tp, mp = str(tmp_path / "t.json"), str(tmp_path / "m.jsonl")
    fl = ExitFlush(tracer=t, trace_path=tp, metrics=reg, metrics_path=mp,
                   run="r1")
    written = fl.flush()
    assert written == {"trace": tp, "metrics": mp}
    spans = [e for e in load_trace(tp) if e.get("ph") == "X"]
    assert [s["name"] for s in spans] == ["a"]
    rows = load_jsonl(mp)
    assert rows[-1]["name"] == "x" and rows[-1]["value"] == 3
    # second flush is a no-op: metrics JSONL must not double-append
    assert fl.flush() == {}
    assert len(load_jsonl(mp)) == len(rows)


def test_exit_flush_context_manager_flushes_on_exception(tmp_path):
    from repro.obs import ExitFlush
    t = Tracer()
    t.add_span("died", 0.0, 1.0, track="tk")
    tp = str(tmp_path / "t.json")
    with pytest.raises(RuntimeError):
        with ExitFlush(tracer=t, trace_path=tp):
            raise RuntimeError("chaos kill")
    spans = [e for e in load_trace(tp) if e.get("ph") == "X"]
    assert [s["name"] for s in spans] == ["died"]
