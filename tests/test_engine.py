"""Engine/oracle equivalence + mesh validation (ISSUE 1 acceptance tests).

``MeshExecutor`` runs the schemes as real SPMD programs over an
8-way forced-host-platform device mesh; every distortion curve must match
the single-device oracles in ``core.schemes`` / ``core.async_vq`` to
tolerance, on a 1-device mesh and on the full 8-way mesh.
"""

from repro.xla_flags import force_host_devices

# Flag must be set before jax initializes (the keras distribution_lib_test
# idiom); tests/conftest.py also sets it, but keep the module standalone.
force_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core import async_vq, schemes  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro import engine  # noqa: E402
from repro.engine import (GeometricDelayNetwork, InstantNetwork,  # noqa: E402
                          MeshExecutor, SimExecutor, ThreadExecutor,
                          get_executor, get_network, make_worker_mesh)

KEY = jax.random.PRNGKey(42)
TAU = 10


def _setup(m, n=600, d=8, kappa=16):
    kd, kw = jax.random.split(KEY)
    data = synthetic.replicate_stream(kd, m, n=n, d=d)
    eval_data = data[:, :200]
    w0 = synthetic.kmeanspp_init(kw, data.reshape(-1, d), kappa)
    return data, eval_data, w0


def _assert_curves_match(a, b, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(a.wall_ticks),
                               np.asarray(b.wall_ticks))
    np.testing.assert_allclose(np.asarray(a.distortion),
                               np.asarray(b.distortion), rtol=rtol, atol=1e-6)


# ---------------------------------------------------------------------------
# engine/oracle equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
def test_mesh_delta_matches_oracle(m):
    """Acceptance: MeshExecutor delta curves == scheme_delta, M=1 and M=8."""
    data, eval_data, w0 = _setup(m)
    oracle = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
    mesh_ex = MeshExecutor(network=InstantNetwork())
    res = mesh_ex.run("delta", w0, data, eval_data, tau=TAU)
    _assert_curves_match(res, oracle)
    np.testing.assert_allclose(np.asarray(res.w_shared),
                               np.asarray(oracle.w_shared),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize(
    "m", [1, pytest.param(8, marks=pytest.mark.devices(8))])
def test_mesh_average_matches_oracle(m):
    data, eval_data, w0 = _setup(m)
    oracle = schemes.scheme_average(w0, data, eval_data, tau=TAU)
    res = MeshExecutor(network=InstantNetwork()).run(
        "average", w0, data, eval_data, tau=TAU)
    _assert_curves_match(res, oracle)


@pytest.mark.devices(8)
def test_mesh_async_matches_oracle_with_shared_delays():
    """Same NetworkModel draw => the mesh masked-merge protocol replays the
    eq.-(9) tick simulation exactly."""
    m = 8
    data, eval_data, w0 = _setup(m)
    key = jax.random.fold_in(KEY, 9)
    net = GeometricDelayNetwork(p_delay=0.5)
    sim = SimExecutor(network=net).run("async_delta", w0, data, eval_data,
                                       tau=TAU, key=key)
    res = MeshExecutor(network=net).run("async_delta", w0, data, eval_data,
                                        tau=TAU, key=key)
    _assert_curves_match(res, sim)
    np.testing.assert_allclose(np.asarray(res.w_shared),
                               np.asarray(sim.w_shared), rtol=1e-4, atol=1e-6)


@pytest.mark.devices(4)
def test_mesh_pallas_and_reference_inner_loops_agree():
    data, eval_data, w0 = _setup(4)
    a = MeshExecutor(network=InstantNetwork(), use_pallas=True).run(
        "delta", w0, data, eval_data, tau=TAU)
    b = MeshExecutor(network=InstantNetwork(), use_pallas=False).run(
        "delta", w0, data, eval_data, tau=TAU)
    _assert_curves_match(a, b)


def test_sim_executor_is_the_oracle():
    data, eval_data, w0 = _setup(4)
    oracle = schemes.scheme_delta(w0, data, eval_data, tau=TAU)
    res = SimExecutor().run("delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_array_equal(np.asarray(res.distortion),
                                  np.asarray(oracle.distortion))


def test_sim_async_lengths_roundtrip():
    """Passing a NetworkModel draw into scheme_async reproduces the default
    geometric sampling bit-for-bit (same key, same sampler)."""
    data, eval_data, w0 = _setup(4)
    key = jax.random.fold_in(KEY, 3)
    default = async_vq.scheme_async(w0, data, eval_data, key, tau=TAU,
                                    p_delay=0.5)
    m, n, _ = data.shape
    lengths = GeometricDelayNetwork(0.5).round_lengths(
        key, m, n // TAU + 2, TAU)
    explicit = async_vq.scheme_async(w0, data, eval_data, key, tau=TAU,
                                     p_delay=0.5, lengths=lengths)
    np.testing.assert_array_equal(np.asarray(default.distortion),
                                  np.asarray(explicit.distortion))


def test_thread_executor_smoke():
    data, eval_data, w0 = _setup(4, n=1000)
    ex = ThreadExecutor(duration_s=1.0)
    res = ex.run("async_delta", w0, data, eval_data, tau=TAU)
    assert float(res.distortion[-1]) < float(res.distortion[0])
    assert all(s.points > 0 for s in ex.last_stats)
    with pytest.raises(ValueError, match="async_delta"):
        ex.run("delta", w0, data, eval_data, tau=TAU)


# ---------------------------------------------------------------------------
# mesh / axis validation
# ---------------------------------------------------------------------------

@pytest.mark.devices(8)
def test_make_worker_mesh_validates():
    with pytest.raises(ValueError, match="non-empty"):
        make_worker_mesh(2, axis="")
    with pytest.raises(ValueError, match="devices"):
        make_worker_mesh(len(jax.devices()) + 1)
    mesh = make_worker_mesh(8)
    assert mesh.devices.shape == (8,)
    assert mesh.axis_names == ("workers",)


def test_mesh_executor_rejects_empty_axis_names():
    with pytest.raises(ValueError, match="non-empty"):
        MeshExecutor(axis="")
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), ("",))
    with pytest.raises(ValueError, match="non-empty"):
        MeshExecutor(mesh=mesh, axis="workers")


@pytest.mark.devices(2)
def test_mesh_executor_rejects_missing_axis():
    mesh = make_worker_mesh(2, axis="workers")
    with pytest.raises(ValueError, match="not in mesh axes"):
        MeshExecutor(mesh=mesh, axis="pods")


@pytest.mark.devices(4)
def test_mesh_executor_rejects_device_count_mismatch():
    data, eval_data, w0 = _setup(4)
    mesh = make_worker_mesh(2)  # 2 devices for 4 worker streams
    with pytest.raises(ValueError, match="one worker per device"):
        MeshExecutor(mesh=mesh).run("delta", w0, data, eval_data, tau=TAU)


def test_mesh_executor_rejects_bad_shapes():
    data, eval_data, w0 = _setup(2)
    ex = MeshExecutor()
    with pytest.raises(ValueError, match=r"\(M, n, d\)"):
        ex.run("delta", w0, data[0], eval_data, tau=TAU)
    with pytest.raises(ValueError, match="same M"):
        ex.run("delta", w0, data, eval_data[:1], tau=TAU)


# ---------------------------------------------------------------------------
# factories and pluggable pieces
# ---------------------------------------------------------------------------

def test_get_executor_factory():
    assert get_executor("sim").name == "sim"
    assert get_executor("mesh").name == "mesh"
    assert get_executor("thread").name == "thread"
    with pytest.raises(ValueError, match="unknown executor"):
        get_executor("quantum")
    with pytest.raises(ValueError, match="unknown scheme"):
        get_executor("sim").run("gossip", *(jnp.zeros((2, 2)),) * 1,
                                jnp.zeros((1, 4, 2)), jnp.zeros((1, 4, 2)),
                                tau=2)


def test_network_models():
    inst = get_network("instant")
    assert inst.window_ticks(10) == 10
    lengths = inst.round_lengths(KEY, 4, 5, 10)
    assert lengths.shape == (4, 5) and int(lengths.min()) == 10

    fixed = get_network("fixed", latency_ticks=3)
    assert fixed.window_ticks(10) == 13
    assert int(fixed.round_lengths(KEY, 2, 3, 10).max()) == 13

    geom = get_network("geometric", p_delay=0.5)
    g = geom.round_lengths(KEY, 16, 64, 10)
    assert int(g.min()) >= 10 and int(g.max()) > 10

    with pytest.raises(ValueError, match="unknown network"):
        get_network("wormhole")
    with pytest.raises(ValueError, match="p_delay"):
        GeometricDelayNetwork(p_delay=0.0)


@pytest.mark.devices(4)
def test_fixed_latency_network_stretches_wall_clock():
    """Same merges, same curve VALUES — but each window costs more ticks, so
    convergence in wall time is slower (the paper's communication tax)."""
    data, eval_data, w0 = _setup(4)
    free = MeshExecutor(network=InstantNetwork()).run(
        "delta", w0, data, eval_data, tau=TAU)
    taxed = MeshExecutor(network=get_network("fixed", latency_ticks=5)).run(
        "delta", w0, data, eval_data, tau=TAU)
    np.testing.assert_allclose(np.asarray(free.distortion),
                               np.asarray(taxed.distortion), rtol=1e-6)
    assert int(taxed.wall_ticks[0]) == TAU + 5
    assert int(taxed.wall_ticks[-1]) > int(free.wall_ticks[-1])


def test_executor_protocol_runtime_checkable():
    assert isinstance(SimExecutor(), engine.Executor)
    assert isinstance(MeshExecutor(), engine.Executor)
    assert isinstance(ThreadExecutor(), engine.Executor)
