import os

# Tests run on the default single CPU device (smoke realism); ONLY the
# dry-run module forces 512 placeholder devices.  A couple of distribution
# tests want a handful of devices — they get 8.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
