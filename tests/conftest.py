import os

# Tests run on the default single CPU device (smoke realism); ONLY the
# dry-run module forces 512 placeholder devices.  A couple of distribution
# tests want a handful of devices — they get 8.  CI overrides XLA_FLAGS to
# run the whole suite under BOTH 1 and 8 forced devices (the 1-device leg
# catches degenerate-mesh bugs the 8-device leg hides); tests that
# intrinsically need a multi-device mesh declare it with
# ``@pytest.mark.devices(n)`` and are skipped on smaller legs.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "devices(n): test needs at least n JAX devices (skipped on the "
        "1-device CI leg)")


def pytest_collection_modifyitems(config, items):
    n_avail = len(jax.devices())
    for item in items:
        mark = item.get_closest_marker("devices")
        if mark and mark.args and mark.args[0] > n_avail:
            item.add_marker(pytest.mark.skip(
                reason=f"needs {mark.args[0]} devices, have {n_avail} "
                       f"(--xla_force_host_platform_device_count)"))
