"""Property-based tests on model invariants (hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # image ships without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.models.api import get_api

KEY = jax.random.PRNGKey(5)


@pytest.mark.parametrize("arch_id", ["granite_8b", "mamba2_2p7b",
                                     "hymba_1p5b"])
def test_causality(arch_id):
    """Changing token t+1.. must not change logits at positions <= t."""
    cfg = registry.get_smoke_config(arch_id)
    api = get_api(cfg)
    params = api.init(KEY)
    B, T, t_cut = 2, 12, 5
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    toks2 = toks.at[:, t_cut + 1:].set(
        (toks[:, t_cut + 1:] + 7) % cfg.vocab)
    l1 = api.forward(params, {"tokens": toks})
    l2 = api.forward(params, {"tokens": toks2})
    np.testing.assert_allclose(
        np.asarray(l1[:, : t_cut + 1], np.float32),
        np.asarray(l2[:, : t_cut + 1], np.float32), rtol=2e-3, atol=2e-3)
    # and the suffix MUST differ (the change is visible causally)
    assert float(jnp.max(jnp.abs(l1[:, t_cut + 1:]
                                 - l2[:, t_cut + 1:]))) > 1e-4


def test_batch_independence():
    """Row b's logits don't depend on other rows (no cross-batch leaks)."""
    cfg = registry.get_smoke_config("granite_8b")
    api = get_api(cfg)
    params = api.init(KEY)
    toks = jax.random.randint(KEY, (3, 10), 0, cfg.vocab)
    full = api.forward(params, {"tokens": toks})
    solo = api.forward(params, {"tokens": toks[1:2]})
    np.testing.assert_allclose(np.asarray(full[1:2], np.float32),
                               np.asarray(solo, np.float32),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
def test_loss_finite_any_tokens(seed, t):
    """CE stays finite for arbitrary token patterns (incl. repeats)."""
    cfg = registry.get_smoke_config("granite_8b")
    api = get_api(cfg)
    params = api.init(KEY)
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (2, t), 0, cfg.vocab)
    loss = api.loss_fn(params, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(loss))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_moe_capacity_monotone(seed):
    """Higher capacity_factor keeps strictly more (or equal) routed mass:
    the MoE output moves toward the dropless limit monotonically."""
    base = registry.get_smoke_config("olmoe_1b_7b")
    api = get_api(base)
    params = api.init(jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 16),
                              0, base.vocab)
    outs = {}
    for cf in (0.5, 1.25, 8.0):
        cfg = dataclasses.replace(base, capacity_factor=cf)
        outs[cf] = get_api(cfg).forward(params, {"tokens": toks})
    # distance to the dropless (cf=8) output shrinks as cf grows
    d_low = float(jnp.mean(jnp.abs(outs[0.5] - outs[8.0])))
    d_mid = float(jnp.mean(jnp.abs(outs[1.25] - outs[8.0])))
    assert d_mid <= d_low + 1e-6


def test_decode_deterministic():
    cfg = registry.get_smoke_config("hymba_1p5b")
    api = get_api(cfg)
    params = api.init(KEY)
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    c1 = api.init_cache(params, {"tokens": toks}, 4)
    c2 = api.init_cache(params, {"tokens": toks}, 4)
    l1, _ = api.decode_step(params, c1, toks)
    l2, _ = api.decode_step(params, c2, toks)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
